"""Encrypted 32-bit integers from multi-bit TFHE digits.

    PYTHONPATH=src python examples/encrypted_int32.py

The paper's multi-bit message space (up to 10 bits per ciphertext) turns
into wide integers by the radix construction: a 32-bit value is a vector
of digits, linear ops are bootstrap-free, and every carry-propagation
round is ONE batched PBS through the round-robin engine.
"""
import jax

from repro.core.engine import TaurusEngine
from repro.core.integer import IntegerContext
from repro.core.params import TEST_PARAMS_4BIT
from repro.core.pbs import TFHEContext


def main():
    params = TEST_PARAMS_4BIT            # 4-bit window: 2 msg + 2 carry bits
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    ic = IntegerContext.create(ctx, TaurusEngine.from_context(ctx))

    # --- 32-bit round trip ------------------------------------------------
    x = 0xDEADBEEF
    ct = ic.encrypt(jax.random.PRNGKey(1), x, 32)
    print(f"encrypt(0x{x:08X}) -> {ct.spec.n_digits} digit ciphertexts "
          f"({ct.spec.msg_bits} msg bits each)")
    print(f"decrypt            -> 0x{ic.decrypt(ct):08X}")

    # --- 16-bit arithmetic: every carry round is one lut_batch -------------
    a, b = 51234, 17777
    ca = ic.encrypt(jax.random.PRNGKey(2), a, 16)
    cb = ic.encrypt(jax.random.PRNGKey(3), b, 16)

    ic.reset_stats()
    s = ic.add(ca, cb)
    print(f"dec(a+b) = {ic.decrypt(s):5d}   (expect {(a + b) % 2**16}; "
          f"{ic.stats['lut_batches']} PBS batches, "
          f"min batch {min(ic.stats['batch_sizes'])} of "
          f"{ca.spec.n_digits} digits)")

    ic.reset_stats()
    m = ic.mul(ca, cb)
    print(f"dec(a*b) = {ic.decrypt(m):5d}   (expect {(a * b) % 2**16}; "
          f"{ic.stats['lut_batches']} PBS batches, {ic.stats['pbs']} PBS)")

    d = ic.sub(cb, ca)                     # wraps mod 2^16
    print(f"dec(b-a) = {ic.decrypt(d):5d}   (expect {(b - a) % 2**16})")

    # --- signed ReLU clamp --------------------------------------------------
    neg = ic.encrypt(jax.random.PRNGKey(4), -1234, 16)
    r = ic.relu_clamp(neg)
    print(f"relu(-1234) = {ic.decrypt(r)}   (expect 0)")
    r2 = ic.relu_clamp(ic.encrypt(jax.random.PRNGKey(5), 1234, 16))
    print(f"relu(+1234) = {ic.decrypt(r2)}   (expect 1234)")

    # --- encrypted comparison ----------------------------------------------
    verdict = int(ctx.decrypt(ic.compare(ca, cb)))
    print(f"compare(a, b) = {verdict}   (0 eq / 1 lt / 2 gt; expect 2)")

    # --- the same arithmetic, traced once through the api front door -------
    # Python operators record the radix IR; the compiled program runs
    # identically on the eager debugger and the serving interpreter.
    from repro.api import IntSpec, Session

    prog = None
    for backend in ("eager", "local"):
        sess = Session(ctx, ic.engine, backend=backend)
        prog = prog or sess.trace(lambda x, y: (x + y, x * y, x < y),
                                  IntSpec(16), IntSpec(16))
        s2, m2, lt = sess(prog, jax.random.PRNGKey(9), a, b)
        print(f"traced/{backend:5s}: a+b={s2}, a*b={m2}, "
              f"[a<b]={int(lt[0])}   (expect {(a + b) % 2**16}, "
              f"{(a * b) % 2**16}, {int(a < b)})")


if __name__ == "__main__":
    main()
