"""Traffic simulation + SLO evaluation, narrated.

    PYTHONPATH=src python examples/sim_scenario.py

Builds one bursty scenario — six tenants, an MMPP arrival process that
steps calm -> 2.5x burst -> calm, a workload mix of cheap const-op
analytics and PBS-heavy radix arithmetic — then runs it twice:

  1. `simulate_scenario`: the deterministic virtual-time replay.  Same
     scenario, same seed => the report is identical field for field, so
     a scheduler change that moves the p99 shows up as a diff, not as
     noise.  Run here twice to demonstrate the contract.
  2. `run_scenario`: the same Scenario object paced onto the wall clock
     against a REAL `ServeRuntime` — every request a compiled radix
     program over big-key ciphertexts, every completed payload
     decrypted and checked against the workload's integer oracle.

Both runners publish the same `serve.*` metric names, so the SLO
evaluator reads either without knowing which produced the numbers.
"""
import json

import jax

from repro.core.engine import TaurusEngine
from repro.core.params import TEST_PARAMS_4BIT
from repro.core.pbs import TFHEContext
from repro.sim import (MMPP, Phase, Scenario, SLOTargets, WorkloadMix,
                       run_scenario, simulate_scenario)


def show(tag, report):
    o = report["overall"]
    print(f"  [{tag}] requests={o['requests']} done={o['done']} "
          f"timeout={o['timeout']} abandoned={o['abandoned']} "
          f"p99={o['p99_s']} goodput={o['goodput_rps']} rps "
          f"slo={'PASS' if report['ok'] else 'FAIL'}")
    for ph in report["phases"]:
        print(f"    phase {ph['phase']:8s} requests={ph['requests']:3d} "
              f"p99={ph['p99_s']} ok={ph['ok']}")


def main():
    mix = WorkloadMix.of({"analytics_const": 2.0, "radix_add": 2.0,
                          "radix_mul": 1.0}, bits=8, msg_bits=2)
    third = 4.0
    sc = Scenario(
        "bursty_tenants",
        MMPP(((0.5, third), (2.5, third), (0.5, third))),
        mix, duration_s=3 * third, population=6, deadline_s=10.0,
        slo=SLOTargets(p99_s=20.0, abandon_rate=0.25), seed=42,
        phases=(Phase("calm", third), Phase("burst", third),
                Phase("recover", third)))

    print("== virtual replay (deterministic, no crypto) ==")
    v1 = simulate_scenario(sc, max_inflight=4)
    v2 = simulate_scenario(sc, max_inflight=4)
    assert v1.report == v2.report, "seeded replay must be identical"
    show("virtual", v1.report)
    print("  replayed twice: reports identical field for field")

    print("== real runtime (big-key ciphertexts, wall clock) ==")
    ctx = TFHEContext.create(jax.random.PRNGKey(0), TEST_PARAMS_4BIT)
    engine = TaurusEngine.from_context(ctx)
    real = run_scenario(sc, ctx, engine, max_inflight=4, validate=True)
    bad = [r.record.client_id for r in real.records
           if r.record.ok_payload is False]
    assert not bad, f"decrypted payloads diverged from oracle: {bad}"
    show("real", real.report)
    print("  every completed payload decrypted == integer oracle")

    with open("sim_scenario_report.json", "w") as f:
        json.dump({"virtual": v1.report, "real": real.report}, f,
                  indent=1, default=float)
    print("full reports -> sim_scenario_report.json")


if __name__ == "__main__":
    main()
