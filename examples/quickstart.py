"""Quickstart: multi-bit TFHE in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's Figure-2(b) programming model: linear ops are
bootstrap-free; arbitrary functions are LUTs evaluated by programmable
bootstrapping (PBS).
"""
import numpy as np
import jax

from repro.core.params import TEST_PARAMS_4BIT
from repro.core.pbs import TFHEContext


def main():
    params = TEST_PARAMS_4BIT            # 4-bit messages, fast on CPU
    print(f"params: n={params.n} N={params.N} k={params.k} "
          f"width={params.width}")

    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    key = jax.random.PRNGKey(1)

    # --- encrypt two 4-bit integers ---------------------------------------
    a, b = 5, 9
    k1, k2 = jax.random.split(key)
    ct_a = ctx.encrypt(k1, a)
    ct_b = ctx.encrypt(k2, b)
    print(f"encrypt({a}), encrypt({b})  ->  {ct_a.shape[-1]}-element LWE cts")

    # --- linear ops: no bootstrapping, thousands of times faster ----------
    ct_sum = ct_a + ct_b                 # homomorphic addition
    ct_lin = ct_a * np.uint64(2) + ct_b  # 2a + b with a plaintext scalar
    print(f"dec(a+b)    = {int(ctx.decrypt(ct_sum))}   (expect {(a + b) % 16})")
    print(f"dec(2a+b)   = {int(ctx.decrypt(ct_lin))}   (expect {(2 * a + b) % 16})")

    # --- a LUT via programmable bootstrapping ------------------------------
    square_mod16 = [(i * i) % 16 for i in range(16)]
    ct_sq = ctx.lut(ct_a, square_mod16)
    print(f"dec(a^2)    = {int(ctx.decrypt(ct_sq))}   (expect {(a * a) % 16})")

    # PBS also REFRESHES noise — chain as many as you like
    relu_shift = [max(i - 8, 0) for i in range(16)]
    ct_relu = ctx.lut(ct_sum, relu_shift)
    print(f"relu(a+b-8) = {int(ctx.decrypt(ct_relu))}   "
          f"(expect {max((a + b) % 16 - 8, 0)})")


if __name__ == "__main__":
    main()
