"""Multi-tenant FHE serving: concurrent clients, fused PBS rounds.

    PYTHONPATH=src python examples/serve_requests.py

Three clients submit encrypted wide-integer programs (add / sub / relu)
to one `ServeRuntime`; one client retries a request, submitting the
identical ciphertexts twice.  The runtime executes all of them
concurrently: every PBS round that is ready across the in-flight
requests fuses into ONE `TaurusEngine.lut_batch` (the bootstrapping key
streams once per round for the whole fleet), and the retried request's
rounds dedup against its twin — zero marginal bootstraps.
"""
import jax

from repro.core.engine import TaurusEngine
from repro.core.integer import IntegerContext
from repro.core.params import TEST_PARAMS_4BIT
from repro.core.pbs import TFHEContext
from repro.serve import (ServeRuntime, decrypt_radix_output,
                         encrypt_request_inputs, radix_binop_program,
                         radix_unop_program)

BITS = 8


def main():
    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    ic = IntegerContext.create(ctx, engine)      # client-side crypto

    add = radix_binop_program("radix_add", BITS, ic.spec(BITS).msg_bits)
    sub = radix_binop_program("radix_sub", BITS, ic.spec(BITS).msg_bits)
    relu = radix_unop_program("radix_relu", BITS, ic.spec(BITS).msg_bits)

    enc = lambda key, vals: encrypt_request_inputs(ic, key, vals, BITS)
    k = jax.random.split(jax.random.PRNGKey(1), 4)
    jobs = [
        ("alice", add, enc(k[0], [173, 209]), (173 + 209) % 256),
        ("bob",   sub, enc(k[1], [60, 77]),   (60 - 77) % 256),
        ("carol", relu, enc(k[2], [-5]),      0),
    ]
    # alice's client retries her request: identical ciphertexts resubmitted
    jobs.append(("alice", add, jobs[0][2], jobs[0][3]))

    rt = ServeRuntime(ctx, engine, max_inflight=4, start_paused=True)
    handles = [rt.submit(g, e, client_id=c) for c, g, e, _ in jobs]
    rt.resume()                                   # serve the whole wave
    rt.drain()

    for h, (client, _, _, want) in zip(handles, jobs):
        got = decrypt_radix_output(ic, h.outputs()[0], BITS)[0]
        ok = "ok" if got == want else "WRONG"
        print(f"  {client:6s} request {h.request.request_id}: "
              f"dec = {got:3d} (expect {want:3d}) {ok}")

    s = rt.scheduler.stats
    print(f"\n[serve] {rt.stats['completed']} requests, "
          f"{s['fused_rounds']} fused PBS rounds, "
          f"{s['logical_luts']} logical LUTs -> "
          f"{s['dispatched_luts']} dispatched "
          f"(dedup hit-rate {rt.scheduler.dedup_hit_rate:.0%}, "
          f"mean occupancy {rt.scheduler.mean_occupancy:.0%})")


if __name__ == "__main__":
    main()
