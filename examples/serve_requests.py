"""Multi-tenant FHE serving through the `repro.api` front door.

    PYTHONPATH=src python examples/serve_requests.py

Three clients trace encrypted wide-integer programs (add / sub / relu)
with ONE `Session` and submit them to its `ServeBackend`; one client
retries a request, submitting the identical ciphertexts twice.  The
runtime executes all of them concurrently: every PBS round that is
ready across the in-flight requests fuses into ONE
`TaurusEngine.lut_batch` (the bootstrapping key streams once per round
for the whole fleet), and the retried request's rounds dedup against
its twin — zero marginal bootstraps.  The same traced programs run
unchanged on `backend="eager"` or `"local"` for debugging.
"""
import jax

from repro.api import IntSpec, Session
from repro.core.engine import TaurusEngine
from repro.core.params import TEST_PARAMS_4BIT
from repro.core.pbs import TFHEContext

BITS = 8


def main():
    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    sess = Session(ctx, engine, backend="serve",
                   max_inflight=4, start_paused=True)

    add = sess.trace(lambda a, b: a + b, IntSpec(BITS), IntSpec(BITS))
    sub = sess.trace(lambda a, b: a - b, IntSpec(BITS), IntSpec(BITS))
    relu = sess.trace(lambda a: a.relu(), IntSpec(BITS))

    k = jax.random.split(jax.random.PRNGKey(1), 4)
    jobs = [
        ("alice", add, sess.encrypt_inputs(k[0], [173, 209], add),
         (173 + 209) % 256),
        ("bob", sub, sess.encrypt_inputs(k[1], [60, 77], sub),
         (60 - 77) % 256),
        ("carol", relu, sess.encrypt_inputs(k[2], [-5], relu), 0),
    ]
    # alice's client retries her request: identical ciphertexts resubmitted
    jobs.append(("alice", add, jobs[0][2], jobs[0][3]))

    handles = [sess.submit(prog, enc, client_id=c)
               for c, prog, enc, _ in jobs]
    rt = sess.backend.runtime
    rt.resume()                                   # serve the whole wave
    rt.drain()

    for h, (client, prog, _, want) in zip(handles, jobs):
        got = sess.decrypt_outputs(prog, h.outputs())[0]
        ok = "ok" if got == want else "WRONG"
        print(f"  {client:6s} request {h.request.request_id}: "
              f"dec = {got:3d} (expect {want:3d}) {ok}")

    s = rt.scheduler.stats
    print(f"\n[serve] {rt.stats['completed']} requests, "
          f"{s['fused_rounds']} fused PBS rounds, "
          f"{s['logical_luts']} logical LUTs -> "
          f"{s['dispatched_luts']} dispatched "
          f"(dedup hit-rate {rt.scheduler.dedup_hit_rate:.0%}, "
          f"mean occupancy {rt.scheduler.mean_occupancy:.0%})")
    sess.close()


if __name__ == "__main__":
    main()
