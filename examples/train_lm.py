"""End-to-end driver: train a ~100M-class LM for a few hundred steps with
checkpoint/restart and an injected mid-run failure.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the qwen3-0.6b family at reduced width (the full config is exercised
by the dry-run); demonstrates the production loop: sharded params, AdamW
+ cosine, synthetic data, atomic checkpoints, automatic restore after a
simulated node failure.
"""
import argparse
import shutil
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        print("== phase 1: train with a failure injected at step 120 ==")
        try:
            train("qwen3-0.6b", steps=args.steps, batch=args.batch,
                  seq=args.seq, ckpt_dir=ckpt_dir, fail_at_step=120)
        except RuntimeError as e:
            print(f"(driver-level failure escaped retries: {e})")

        print("\n== phase 2: resume from the latest checkpoint ==")
        losses, stats = train("qwen3-0.6b", steps=args.steps,
                              batch=args.batch, seq=args.seq,
                              ckpt_dir=ckpt_dir, resume=True)
        print(f"\nfinal loss {losses[-1]:.3f}; fault stats {stats}")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
