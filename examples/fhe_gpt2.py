"""Encrypted GPT-2 block inference — the paper's flagship demonstration
at laptop scale, in both activation representations.

    PYTHONPATH=src python examples/fhe_gpt2.py

Part 1 (narrow-LUT): quantizes a single-head GPT-2-style block to 3-bit
affine activations, lowers it to requant-LUT FHE IR, runs attention
(ct*ct via square-trick LUTs) + GELU MLP under REAL TFHE on the JAX
engine, and checks the decrypted output against the plaintext integer
oracle bit-for-bit.  Also reports what the same graph costs on the
Taurus accelerator model.

Part 2 (quantize-to-radix, ISSUE 4): the same block shape on 16-bit
two's-complement radix activations — exact `radix_linear` projections,
exact ct*ct attention (`radix_mul`), ReLU MLP, no requant LUTs — traced
into ONE program that runs identically on the eager debugging backend
and through `Session(ctx, backend="serve")`, i.e. submitted to the
multi-tenant `ServeRuntime` as real encrypted-LLM traffic whose radix
rounds fuse with every other in-flight request.  Reports the fused-
round occupancy the serving scheduler measured while executing it.

docs/fhe_gpt2_walkthrough.md narrates this file line by line.
"""
import numpy as np
import jax

from repro.api import Session
from repro.core.params import TEST_PARAMS_4BIT, TEST_PARAMS_6BIT, PAPER_PARAMS
from repro.core.pbs import TFHEContext
from repro.fhe_ml import lower, executor
from repro.fhe_ml.quantize import (QuantSpec, RadixQuantSpec,
                                   calibrate_radix, dequantize_radix,
                                   quantize_to_radix)
from repro.compiler import passes, build_schedule, TaurusModel


def narrow_lut_demo():
    d = 4
    print("== encrypted GPT-2 block (narrow-LUT, 3-bit activations) ==")
    print(f"scheme: n={TEST_PARAMS_6BIT.n} N={TEST_PARAMS_6BIT.N} "
          f"width={TEST_PARAMS_6BIT.width}")

    g, meta = lower.lower_gpt2_block(d, QuantSpec(3, 0.25, 4),
                                     TEST_PARAMS_6BIT.width, seed=1)
    n_lut = sum(n.n_elements for n in g.nodes if n.op == "lut")
    print(f"graph: {len(g.nodes)} nodes, {n_lut} PBS applications")

    ctx = TFHEContext.create(jax.random.PRNGKey(42), TEST_PARAMS_6BIT)
    # the api front door: adopt the lowered graph as a Program and run it
    # on the eager debugging backend
    sess = Session(ctx, backend="eager")
    prog = sess.compile(g)
    x = np.random.default_rng(0).integers(0, 8, (d,))
    print(f"input (3-bit quantized): {x}")

    ref = executor.interpret(g, [x], ctx.params.width)
    enc = sess.encrypt_inputs(jax.random.PRNGKey(7), [x], prog)
    got = sess.decrypt_outputs(prog, sess.run(prog, enc))[0]
    print(f"decrypted output: {got}")
    print(f"plaintext oracle: {ref[g.outputs[0]]}")
    assert np.array_equal(got, ref[g.outputs[0]]), "FHE != oracle!"
    print(f"bit-exact ✓   engine stats: {sess.backend.stats}")

    # what would Taurus do with this graph?
    ops, stats = passes.lower_to_physical(g)
    sched = build_schedule(ops)
    t, util = TaurusModel(PAPER_PARAMS["gpt2"]).bandwidth_bound_runtime(sched)
    print(f"\nTaurus model @ paper GPT-2 params: {t * 1e3:.2f} ms "
          f"({sched.total_pbs} PBS, util {util:.0%}, "
          f"KS-dedup saved {stats.ks_saved_frac:.0%})")


def radix_serve_demo():
    d, bits, m = 2, 16, 2
    print("\n== encrypted GPT-2 block (quantize-to-radix, "
          f"{bits}-bit activations) on the serve path ==")
    print(f"scheme: n={TEST_PARAMS_4BIT.n} N={TEST_PARAMS_4BIT.N} "
          f"width={TEST_PARAMS_4BIT.width} "
          f"(digits of {m} message bits, D={bits // m})")

    # lower once: the graph is quantization-agnostic (no LUT tables bake
    # in a scale) and carries its own range certificate + IntSpecs
    g, meta = lower.lower_gpt2_block_radix(d, bits=bits, msg_bits=m, seed=1)
    print(f"graph: {len(g.nodes)} nodes "
          f"({[n.op for n in g.nodes if n.op != 'input']}), "
          f"{g.lut_applications()} planned PBS applications, "
          f"input_qmax={meta['input_qmax']}")

    # quantize a float activation vector against the certificate
    xf = np.random.default_rng(3).uniform(-1, 1, size=(d,))
    rq = calibrate_radix(xf, bits, m, qmax=meta["input_qmax"])
    q = quantize_to_radix(xf, rq)
    print(f"input (float): {xf}\ninput (radix-quantized): {q}  "
          f"scale={rq.scale:.4g}")

    ctx = TFHEContext.create(jax.random.PRNGKey(42), TEST_PARAMS_4BIT)
    want = meta["int_fn"](q) % (1 << bits)

    # eager reference run
    with Session(ctx, backend="eager") as sess:
        prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
        eager_out = np.asarray(sess(prog, jax.random.PRNGKey(7), q)[0])

    # the same program as encrypted-LLM traffic through the multi-tenant
    # runtime: radix rounds barrier through the FusedLutScheduler
    with Session(ctx, backend="serve") as sess:
        prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
        serve_out = np.asarray(sess(prog, jax.random.PRNGKey(7), q)[0])
        sched = sess.backend.scheduler
        print(f"serve scheduler: {sched.stats['fused_rounds']} fused "
              f"rounds, occupancy {sched.mean_occupancy:.0%}, "
              f"{sched.stats['logical_luts']} logical LUTs")

    print(f"decrypted (eager): {eager_out}\ndecrypted (serve): {serve_out}")
    assert np.array_equal(eager_out % (1 << bits), want), "FHE != oracle!"
    assert np.array_equal(eager_out, serve_out), "serve != eager!"

    # two ct*ct products => output values carry scale^3 (meta says so)
    out_rq = RadixQuantSpec(bits, m, rq.scale ** meta["out_scale_pow"])
    yhat = dequantize_radix(eager_out, out_rq)
    yf = meta["float_fn"](xf)
    print(f"dequantized: {yhat}\nfloat model: {yf}")
    print("bit-exact across backends ✓ "
          f"(max |dequant - float| = {np.max(np.abs(yhat - yf)):.3g})")

    # the radix graph on the accelerator model
    ops, stats = passes.lower_to_physical(g)
    sched_m = build_schedule(ops)
    t, util = TaurusModel(PAPER_PARAMS["gpt2"]).bandwidth_bound_runtime(
        sched_m)
    print(f"Taurus model @ paper GPT-2 params: {t * 1e3:.2f} ms "
          f"({sched_m.total_pbs} PBS, util {util:.0%})")


def main():
    narrow_lut_demo()
    radix_serve_demo()


if __name__ == "__main__":
    main()
