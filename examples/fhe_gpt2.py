"""Encrypted GPT-2 block inference — the paper's flagship demonstration
at laptop scale.

    PYTHONPATH=src python examples/fhe_gpt2.py

Quantizes a single-head GPT-2-style block, lowers it to the FHE IR,
encrypts an input vector, runs attention (ct*ct via square-trick LUTs) +
GELU MLP under REAL TFHE on the JAX engine, and checks the decrypted
output against the plaintext integer oracle bit-for-bit.  Also reports
what the same graph costs on the Taurus accelerator model.
"""
import numpy as np
import jax

from repro.api import Session
from repro.core.params import TEST_PARAMS_6BIT, PAPER_PARAMS
from repro.core.pbs import TFHEContext
from repro.fhe_ml import lower, executor
from repro.fhe_ml.quantize import QuantSpec
from repro.compiler import passes, build_schedule, TaurusModel


def main():
    d = 4
    print("== encrypted GPT-2 block (reduced) ==")
    print(f"scheme: n={TEST_PARAMS_6BIT.n} N={TEST_PARAMS_6BIT.N} "
          f"width={TEST_PARAMS_6BIT.width}")

    g, meta = lower.lower_gpt2_block(d, QuantSpec(3, 0.25, 4),
                                     TEST_PARAMS_6BIT.width, seed=1)
    n_lut = sum(n.n_elements for n in g.nodes if n.op == "lut")
    print(f"graph: {len(g.nodes)} nodes, {n_lut} PBS applications")

    ctx = TFHEContext.create(jax.random.PRNGKey(42), TEST_PARAMS_6BIT)
    # the api front door: adopt the lowered graph as a Program and run it
    # on the eager debugging backend (swap backend="serve" to put this
    # block behind the multi-tenant runtime, unchanged)
    sess = Session(ctx, backend="eager")
    prog = sess.compile(g)
    x = np.random.default_rng(0).integers(0, 8, (d,))
    print(f"input (3-bit quantized): {x}")

    ref = executor.interpret(g, [x], ctx.params.width)
    enc = sess.encrypt_inputs(jax.random.PRNGKey(7), [x], prog)
    got = sess.decrypt_outputs(prog, sess.run(prog, enc))[0]
    print(f"decrypted output: {got}")
    print(f"plaintext oracle: {ref[g.outputs[0]]}")
    assert np.array_equal(got, ref[g.outputs[0]]), "FHE != oracle!"
    print(f"bit-exact ✓   engine stats: {sess.backend.stats}")

    # what would Taurus do with this graph?
    ops, stats = passes.lower_to_physical(g)
    sched = build_schedule(ops)
    t, util = TaurusModel(PAPER_PARAMS["gpt2"]).bandwidth_bound_runtime(sched)
    print(f"\nTaurus model @ paper GPT-2 params: {t * 1e3:.2f} ms "
          f"({sched.total_pbs} PBS, util {util:.0%}, "
          f"KS-dedup saved {stats.ks_saved_frac:.0%})")


if __name__ == "__main__":
    main()
