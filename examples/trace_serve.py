"""Trace a mixed serving run and write Chrome-trace JSON.

    PYTHONPATH=src python examples/trace_serve.py [--out trace_serve.json]

Two radix-add clients and one encrypted-GPT-2-block client (the
quantize-to-radix lowering from `repro.fhe_ml`) run concurrently
through `ServeRuntime` with a tracing `Telemetry` attached.  Every
layer records spans: per-request `submit -> queue_wait -> admit ->
pbs_round (fused batch id, dedup hits) -> completed`, the scheduler's
leader-side `fused_round` dispatches, and the engine's `lut_batch`
calls.  The script writes the trace, validates it (JSON shape, span
nesting, per-request coverage), and prints the metrics snapshot
headlines — open the file at https://ui.perfetto.dev or
chrome://tracing to see the fleet's rounds barrier into shared
batches.

The CI smoke lane runs this end-to-end and uploads the trace as a
workflow artifact.
"""
from __future__ import annotations

import argparse
import sys
import time

BITS = 16
MSG_BITS = 2
D_MODEL = 2


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace_serve.json",
                    help="Chrome-trace output path")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.api import IntSpec, Session
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext
    from repro.fhe_ml import lower
    from repro.fhe_ml.quantize import calibrate_radix, quantize_to_radix
    from repro.obs import Telemetry, validate_chrome_trace

    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    tel = Telemetry(trace=True)
    engine.telemetry = tel          # engine-level lut_batch spans too

    client = Session(ctx, engine, backend="local")
    add_prog = client.trace(lambda a, b: a + b, IntSpec(BITS), IntSpec(BITS))
    g, meta = lower.lower_gpt2_block_radix(D_MODEL, bits=BITS,
                                           msg_bits=MSG_BITS, seed=1)
    block_prog = client.compile(g, meta["in_specs"], meta["out_specs"])

    rng = np.random.default_rng(3)
    reqs = []                        # (client, program, enc_inputs, want)
    for i, name in enumerate(("alice", "bob")):
        a = int(rng.integers(0, 1 << BITS))
        b = int(rng.integers(0, 1 << BITS))
        enc = client.encrypt_inputs(jax.random.key(10 + i), [a, b], add_prog)
        reqs.append((name, add_prog, enc, (a + b) % (1 << BITS)))
    xf = rng.uniform(-1, 1, D_MODEL)
    rq = calibrate_radix(xf, BITS, MSG_BITS, qmax=meta["input_qmax"])
    q = quantize_to_radix(xf, rq)
    enc = client.encrypt_inputs(jax.random.key(99), [q], block_prog)
    reqs.append(("carol", block_prog, enc, meta["int_fn"](q) % (1 << BITS)))

    print(f"== traced serving run: 2 radix-add + 1 GPT-2-block clients "
          f"({BITS}-bit radix, {params.name}) ==")
    sess = Session(ctx, engine, backend="serve", telemetry=tel,
                   max_inflight=len(reqs), start_paused=True)
    handles = [sess.submit(p, e, client_id=c) for c, p, e, _ in reqs]
    rt = sess.backend.runtime
    t0 = time.perf_counter()
    rt.resume()
    rt.drain()
    dt = time.perf_counter() - t0
    for h, (c, p, _, want) in zip(handles, reqs):
        got = np.asarray(sess.decrypt_outputs(p, h.outputs())[0])
        assert np.array_equal(got % (1 << BITS), want), f"{c}: FHE != oracle"
    sess.close()

    path = tel.write_chrome_trace(args.out)
    n_events = validate_chrome_trace(path)

    # per-request coverage: a submit instant, the request span, at least
    # one pbs_round span nested inside it (same worker lane), a complete
    # marker — the trace is only useful if every request's whole journey
    # is on it
    events = tel.recorder.events()
    for h in handles:
        rid = h.request.request_id
        mine = [e for e in events if e.args.get("request") == rid]
        names = {e.name for e in mine}
        for needed in ("submit", "admit", "queue_wait", "request",
                       "completed"):
            assert needed in names, f"request {rid} missing {needed!r} event"
        req_span = next(e for e in mine if e.name == "request")
        rounds = [e for e in events
                  if e.name == "pbs_round" and e.tid == req_span.tid
                  and e.ts >= req_span.ts
                  and e.ts + e.dur <= req_span.ts + req_span.dur]
        assert rounds, f"request {rid}: no pbs_round span inside its span"
        assert all(r.args.get("round") is not None for r in rounds), (
            f"request {rid}: pbs_round missing its fused batch id")

    snap = rt.metrics()
    lat = snap["histograms"]["serve.request_latency_s"]
    bw = snap["bandwidth"]
    occ = snap["histograms"]["sched.occupancy"]
    print(f"   {len(reqs)} requests in {dt:5.1f}s "
          f"(includes XLA compilation of the block's shapes)")
    print(f"   latency p50 {lat['p50']:.2f}s p99 {lat['p99']:.2f}s; "
          f"{snap['counters']['sched.fused_rounds']} fused rounds, "
          f"mean occupancy {occ['mean']:.0%}")
    print(f"   BSK streamed {bw['bsk_bytes_streamed'] / 1e6:.1f} MB vs "
          f"{bw['bsk_bytes_unfused'] / 1e6:.1f} MB unfused "
          f"(saved {bw['bsk_bytes_saved'] / 1e6:.1f} MB)")
    print(f"[trace_serve] {n_events} events -> {path} "
          f"(open in https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
