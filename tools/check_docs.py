#!/usr/bin/env python
"""Execute the fenced `python` blocks of markdown docs so documented
examples can't bit-rot.

    PYTHONPATH=src python tools/check_docs.py [FILE.md ...]

Defaults to the files whose snippets are the repo's executable
contract: ROADMAP.md and docs/ARCHITECTURE.md (the CI `docs` job runs
exactly these; docs/fhe_gpt2_walkthrough.md is narrative — its
fragments reference the example's namespace and are covered by running
`examples/fhe_gpt2.py` itself).

All blocks within one file share a namespace: they are concatenated in
order into one script and executed in a subprocess, so later snippets
can build on earlier ones.  Only fences whose info string is exactly
``python`` run; ```text fences and annotated fences are
documentation-only.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

DEFAULT_FILES = ["ROADMAP.md", os.path.join("docs", "ARCHITECTURE.md")]


def extract_python_blocks(path: str) -> list:
    blocks: list = []
    cur: list = []
    in_block = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            stripped = line.strip()
            if in_block:
                if stripped == "```":
                    in_block = False
                    blocks.append("".join(cur))
                    cur = []
                else:
                    cur.append(line)
            elif stripped == "```python":
                in_block = True
    assert not in_block, f"{path}: unterminated ```python fence"
    return blocks


def run_file_snippets(path: str) -> bool:
    blocks = extract_python_blocks(path)
    if not blocks:
        print(f"[docs] {path}: no python blocks, skipped")
        return True
    script = "\n\n".join(blocks)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    with tempfile.NamedTemporaryFile(
            "w", suffix=".py", delete=False, encoding="utf-8") as tf:
        tf.write(script)
        tmp = tf.name
    try:
        t0 = time.time()
        proc = subprocess.run([sys.executable, tmp], env=env)
        dt = time.time() - t0
        ok = proc.returncode == 0
        print(f"[docs] {path}: {len(blocks)} block(s) "
              f"{'ok' if ok else 'FAILED'} in {dt:.1f}s")
        return ok
    finally:
        os.unlink(tmp)


def main(argv=None) -> int:
    files = list(argv) if argv else DEFAULT_FILES
    bad = [f for f in files if not run_file_snippets(f)]
    if bad:
        print(f"[docs] FAILED: {bad}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
