"""Compiler unit tests: IR, dedup passes, scheduler, cost model."""
import numpy as np
import pytest

from repro.compiler import (workloads, passes, build_schedule, TaurusModel,
                            CpuModel, trace)
from repro.compiler.cost import xpu_model, ROUND_ROBIN
from repro.core.params import PAPER_PARAMS


def test_trace_builds_graph():
    t = np.arange(16, dtype=np.uint64)

    def f(x):
        y = x + x
        return y.lut(t).linear(np.ones((4, 2), np.int64))
    g = trace(f, (4,))
    assert g.count("add") == 1 and g.count("lut") == 1 and g.count("linear") == 1
    assert g.nodes[-1].shape == (2,)


def test_ks_dedup_counts_fanout():
    t1 = np.arange(16, dtype=np.uint64)
    t2 = t1[::-1].copy()

    def f(x):
        return x.lut(t1), x.lut(t2), x.lut(t1)
    g = trace(f, (8,))
    ops, stats = passes.lower_to_physical(g)
    assert stats.ks_before == 24 and stats.ks_after == 8
    assert stats.ks_saved_frac == pytest.approx(2 / 3)
    # ACC-dedup: t1 reused across two nodes -> 2 unique tables
    assert stats.acc_after == 2


def test_dedup_disabled_is_identity():
    t = np.arange(16, dtype=np.uint64)

    def f(x):
        return x.lut(t), x.lut(t)
    g = trace(f, (4,))
    _, s0 = passes.lower_to_physical(g, ks_dedup=False, acc_dedup=False)
    assert s0.ks_after == s0.ks_before
    assert s0.acc_after == s0.acc_before


def test_schedule_levels_respect_dependencies():
    t = np.arange(16, dtype=np.uint64)

    def f(x):
        return x.lut(t).lut(t).lut(t)      # strictly serial chain
    g = trace(f, (1,))
    ops, _ = passes.lower_to_physical(g)
    sched = build_schedule(ops)
    assert sched.total_pbs == 3
    levels = [b.level for b in sched.batches if b.n_br]
    assert levels == sorted(levels) and len(set(levels)) == 3


def test_pbs_latency_matches_paper():
    """The calibration anchor: GPT-2 params -> 6.16 ms; CNN-20 -> 0.28 ms."""
    assert TaurusModel(PAPER_PARAMS["gpt2"]).pbs_latency == \
        pytest.approx(6.16e-3, rel=0.02)
    assert TaurusModel(PAPER_PARAMS["cnn20"]).pbs_latency == \
        pytest.approx(0.283e-3, rel=0.02)


def test_round_robin_shrinks_at_large_N():
    m_small = TaurusModel(PAPER_PARAMS["cnn20"])      # N=2048
    m_big = TaurusModel(PAPER_PARAMS["decision_tree"])  # N=65536
    assert m_small.round_robin_eff == ROUND_ROBIN
    assert m_big.round_robin_eff < ROUND_ROBIN


def test_acc_buffer_default_matches_paper():
    """9216 KB holds exactly 12 round-robin cts at GPT-2 params."""
    m = TaurusModel(PAPER_PARAMS["gpt2"])
    assert 12 * m.acc_bytes_per_ct == 9216 * 1024


def test_xpu_slower_everywhere():
    for name, w in workloads.build_all().items():
        ops, _ = passes.lower_to_physical(w.graph)
        sched = build_schedule(ops)
        t, _ = TaurusModel(w.params).bandwidth_bound_runtime(sched)
        tx, _ = xpu_model(w.params).bandwidth_bound_runtime(sched)
        assert tx > 2.5 * t, (name, tx / t)


def test_workload_model_within_3x_of_paper():
    for name, w in workloads.build_all().items():
        ops, _ = passes.lower_to_physical(w.graph)
        sched = build_schedule(ops)
        t, _ = TaurusModel(w.params).bandwidth_bound_runtime(sched)
        ratio = (t * 1e3) / w.paper_taurus_ms
        assert 1 / 3 < ratio < 3, (name, ratio)


def test_grouped_sync_bandwidth_doubles():
    """Observation 5: grouped synchronization nearly doubles bandwidth."""
    m1 = TaurusModel(PAPER_PARAMS["gpt2"], sync_groups=1)
    m2 = TaurusModel(PAPER_PARAMS["gpt2"], sync_groups=2)
    bw1 = m1.batch_bandwidth()["bsk"]
    bw2 = m2.batch_bandwidth()["bsk"]
    assert bw2 == pytest.approx(2 * bw1)


def test_radix_lowering_dedup_and_schedule():
    """Wide-integer workloads flow through the whole compiler pipeline:
    per-round KS-dedup (msg/carry fanout shares key-switches), two shared
    accumulator tables for the add rounds, and a schedule whose levels
    serialize the carry rounds."""
    from repro.compiler.ir import radix_round_plan
    for name, (g, p) in workloads.build_wide().items():
        ops, stats = passes.lower_to_physical(g)
        assert stats.ks_after < stats.ks_before, name
        _, s0 = passes.lower_to_physical(g, ks_dedup=False, acc_dedup=False)
        assert s0.ks_after == s0.ks_before
        assert s0.acc_after == s0.acc_before
        sched = build_schedule(ops)
        t, util = TaurusModel(p).bandwidth_bound_runtime(sched)
        tx, _ = xpu_model(p).bandwidth_bound_runtime(sched)
        assert 0 < t < tx, name              # key reuse must win
    # exact counts for one op: 32-bit add over 4-bit digits (D=8)
    g = workloads.wide_add_graph(32, 4)
    ops, stats = passes.lower_to_physical(g)
    plan = radix_round_plan("radix_add", 8)
    assert stats.ks_before == sum(r["luts"] for r in plan)
    assert stats.ks_after == sum(r["sources"] for r in plan)
    assert stats.acc_after == 3              # msg, sigma, combine tables
    assert g.lut_applications() == sum(r["luts"] for r in plan)
    br_levels = [op.level for op in ops if op.kind == "BR"]
    assert br_levels == sorted(br_levels) and len(set(br_levels)) == len(plan)


def test_radix_linear_plan_and_lowering():
    """`radix_linear` (the quantize-to-radix linear layer) flows through
    the round-plan model and physical lowering: carry-save compress
    rounds, then exactly an add-style propagation tail, plus a leading
    LIN op for the weight combine."""
    from repro.compiler.ir import radix_round_plan, trace

    d, m = 8, 2
    # four unit-weight terms + the complement-constant term
    plan = radix_round_plan("radix_linear", d, m,
                            term_maxes=(3, 3, 3, 3, 3))
    tail = radix_round_plan("radix_add", d, m)
    assert len(plan) > len(tail)
    assert plan[-len(tail):] == tail
    for r in plan[:-len(tail)]:              # compress rounds: msg+carry
        assert r["tables"] == ("radix/msg", "radix/carry")
    # a single pre-reduced term is just the propagation tail
    assert radix_round_plan("radix_linear", d, m, term_maxes=(3,)) == tail
    # regression: ceilings too large to pair must converge through solo
    # extraction of the largest term (previously looped forever)
    assert len(radix_round_plan("radix_linear", d, m,
                                term_maxes=(12, 12))) > len(tail)
    # regression: round count is the MAX over per-column simulations —
    # a many-term unit-weight column must not mask a heavy column that
    # compresses in fewer, bigger steps (or vice versa)
    both = radix_round_plan("radix_linear", d, m,
                            term_maxes=((12, 12), (3,) * 8))
    c0 = radix_round_plan("radix_linear", d, m, term_maxes=((12, 12),))
    c1 = radix_round_plan("radix_linear", d, m, term_maxes=((3,) * 8,))
    assert len(both) >= max(len(c0), len(c1))

    rng = np.random.default_rng(2)
    W = rng.integers(-1, 2, (3, 2))
    g = trace(lambda x: x.radix_linear(W, m), (3, d))
    ops, stats = passes.lower_to_physical(g)
    lin = [op for op in ops if op.kind == "LIN"]
    assert lin and lin[0].macs == int(np.count_nonzero(W)) * d
    assert stats.ks_after < stats.ks_before      # msg/carry fanout dedups
    assert g.lut_applications() > 0
    sched = build_schedule(ops)
    assert sched.total_pbs > 0


def test_interpret_matches_numpy_linear():
    from repro.fhe_ml.executor import interpret
    rng = np.random.default_rng(0)
    W = rng.integers(-2, 3, (4, 3))

    def f(x):
        return x.linear(W) + 8
    g = trace(f, (4,))
    x = rng.integers(0, 4, (4,))
    out = interpret(g, [x], 6)
    np.testing.assert_array_equal(out[g.outputs[0]], (x @ W + 8) % 64)


def test_radix_round_plan_degenerate_and_width_override():
    """Review follow-ups: a single-digit vector is ONE ripple extraction
    round for every strategy hint (matching IntegerContext.propagate),
    and an explicit `width` overrides the standard width = 2*msg_bits
    assumption when the caller knows the parameter set."""
    from repro.compiler.ir import radix_round_plan
    for m in (None, 1, 2):
        plan = radix_round_plan("radix_add", 1, m)
        assert len(plan) == 1 and plan[0]["luts"] == 2
    # msg_bits=1 under a 4-bit window: the runtime takes the prefix scan
    assert (radix_round_plan("radix_add", 16, 1, width=4)
            == radix_round_plan("radix_add", 16, 2))
    # and the standard base-2 layout stays on the lookahead plan
    assert len(radix_round_plan("radix_add", 16, 1)) == 10
