"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps per the deliverable contract; tolerances account for
the f32 kernel vs f64 oracle gap (documented in DESIGN.md §3).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fourstep_fft import factor_m

jax.config.update("jax_enable_x64", True)


# --- four-step FFT -----------------------------------------------------------

@pytest.mark.parametrize("N", [256, 512, 2048, 8192, 65536])
@pytest.mark.parametrize("B", [1, 3])
def test_fft_forward_matches_ref(N, B):
    rng = np.random.default_rng(N + B)
    x = jnp.asarray(rng.integers(-(1 << 7), 1 << 7, (B, N)), dtype=jnp.float32)
    got = np.asarray(ops.negacyclic_fft(x))
    want = np.asarray(ref.fft_forward_ref(x))
    scale = np.max(np.abs(want)) + 1.0
    np.testing.assert_allclose(got / scale, want / scale, atol=2e-5)


@pytest.mark.parametrize("N", [256, 2048, 65536])
def test_fft_roundtrip(N):
    rng = np.random.default_rng(N)
    x = jnp.asarray(rng.integers(-(1 << 10), 1 << 10, (2, N)), dtype=jnp.float32)
    back = np.asarray(ops.negacyclic_ifft(ops.negacyclic_fft(x)))
    np.testing.assert_allclose(back, np.asarray(x), atol=0.25 * np.sqrt(N) / 8)


def test_fft_factorization_matches_paper():
    # the paper's FFT cluster: 2^15 points = 256-pt (FFT-A) x 128-pt (FFT-B)
    assert factor_m(1 << 15) == (256, 128)


@pytest.mark.parametrize("N", [512, 2048])
def test_fft_negacyclic_convolution_property(N):
    """Pointwise product in kernel transform domain == negacyclic conv."""
    rng = np.random.default_rng(N + 7)
    a = rng.integers(-64, 64, N)
    b = rng.integers(-64, 64, N)
    sa = ops.negacyclic_fft(jnp.asarray(a[None], dtype=jnp.float32))
    sb = ops.negacyclic_fft(jnp.asarray(b[None], dtype=jnp.float32))
    # complex pointwise product on stacked planes
    pr = sa[:, 0] * sb[:, 0] - sa[:, 1] * sb[:, 1]
    pi = sa[:, 0] * sb[:, 1] + sa[:, 1] * sb[:, 0]
    got = np.asarray(ops.negacyclic_ifft(jnp.stack([pr, pi], axis=1)))[0]
    # exact integer oracle
    want = np.zeros(N, dtype=np.int64)
    for i in range(N):
        k = (i + np.arange(N)) % (2 * N)
        np.add.at(want, k % N, np.where(k < N, a[i] * b, -(a[i] * b)))
    np.testing.assert_allclose(got, want, atol=np.maximum(1.0, np.abs(want).max() * 3e-5))


# --- BRU external-product MAC -------------------------------------------------

@pytest.mark.parametrize("B,J,K,F", [(1, 2, 2, 256), (12, 4, 2, 1024),
                                     (12, 6, 3, 2048), (48, 4, 2, 16384)])
def test_bru_mac_matches_ref(B, J, K, F):
    rng = np.random.default_rng(B * F)
    dig = jnp.asarray(rng.standard_normal((B, 2, J, F)) * 100, dtype=jnp.float32)
    bsk = jnp.asarray(rng.standard_normal((2, J, K, F)), dtype=jnp.float32)
    got = np.asarray(ops.bru_mac(dig, bsk))
    want = np.asarray(ref.external_product_mac_ref(dig, bsk))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("block_f", [128, 512, 2048])
def test_bru_mac_block_sweep(block_f):
    rng = np.random.default_rng(block_f)
    dig = jnp.asarray(rng.standard_normal((4, 2, 4, 2048)), dtype=jnp.float32)
    bsk = jnp.asarray(rng.standard_normal((2, 4, 2, 2048)), dtype=jnp.float32)
    got = np.asarray(ops.bru_mac(dig, bsk, block_f=block_f))
    want = np.asarray(ref.external_product_mac_ref(dig, bsk))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# --- LPU key-switch MAC (uint32-limb 64-bit arithmetic) -----------------------

@pytest.mark.parametrize("B,S,T", [(1, 128, 65), (4, 1024, 513), (2, 4096, 257)])
def test_keyswitch_mac_exact(B, S, T):
    rng = np.random.default_rng(S + T)
    digits = jnp.asarray(
        rng.integers(-(1 << 15), 1 << 15, (B, S)), dtype=jnp.int32)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (S, T), dtype=np.uint64))
    got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk))
    want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
    np.testing.assert_array_equal(got, want)  # EXACT mod 2^64


def test_keyswitch_mac_extreme_digits():
    """Full int32 digit range (negative, maximal) stays exact."""
    digits = jnp.asarray(
        [[-(1 << 31), (1 << 31) - 1, -1, 1, 0, 7, -7, 12345]], dtype=jnp.int32)
    rng = np.random.default_rng(0)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (8, 33), dtype=np.uint64))
    got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk, block_s=8))
    want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
    np.testing.assert_array_equal(got, want)


def test_keyswitch_mac_grid_accumulation():
    """Multi-block S accumulation (sequential grid) is exact."""
    rng = np.random.default_rng(3)
    digits = jnp.asarray(rng.integers(-(1 << 12), 1 << 12, (3, 2048)), dtype=jnp.int32)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (2048, 129), dtype=np.uint64))
    for bs in (256, 512, 2048):
        got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk, block_s=bs))
        want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
        np.testing.assert_array_equal(got, want)
