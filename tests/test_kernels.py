"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps per the deliverable contract; tolerances account for
the f32 kernel vs f64 oracle gap (documented in DESIGN.md §3).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fourstep_fft import factor_m

jax.config.update("jax_enable_x64", True)


# --- four-step FFT -----------------------------------------------------------

@pytest.mark.parametrize("N", [256, 512, 2048, 8192, 65536])
@pytest.mark.parametrize("B", [1, 3])
def test_fft_forward_matches_ref(N, B):
    rng = np.random.default_rng(N + B)
    x = jnp.asarray(rng.integers(-(1 << 7), 1 << 7, (B, N)), dtype=jnp.float32)
    got = np.asarray(ops.negacyclic_fft(x))
    want = np.asarray(ref.fft_forward_ref(x))
    scale = np.max(np.abs(want)) + 1.0
    np.testing.assert_allclose(got / scale, want / scale, atol=2e-5)


@pytest.mark.parametrize("N", [256, 2048, 65536])
def test_fft_roundtrip(N):
    rng = np.random.default_rng(N)
    x = jnp.asarray(rng.integers(-(1 << 10), 1 << 10, (2, N)), dtype=jnp.float32)
    back = np.asarray(ops.negacyclic_ifft(ops.negacyclic_fft(x)))
    np.testing.assert_allclose(back, np.asarray(x), atol=0.25 * np.sqrt(N) / 8)


def test_fft_factorization_matches_paper():
    # the paper's FFT cluster: 2^15 points = 256-pt (FFT-A) x 128-pt (FFT-B)
    assert factor_m(1 << 15) == (256, 128)


@pytest.mark.parametrize("N", [512, 2048])
def test_fft_negacyclic_convolution_property(N):
    """Pointwise product in kernel transform domain == negacyclic conv."""
    rng = np.random.default_rng(N + 7)
    a = rng.integers(-64, 64, N)
    b = rng.integers(-64, 64, N)
    sa = ops.negacyclic_fft(jnp.asarray(a[None], dtype=jnp.float32))
    sb = ops.negacyclic_fft(jnp.asarray(b[None], dtype=jnp.float32))
    # complex pointwise product on stacked planes
    pr = sa[:, 0] * sb[:, 0] - sa[:, 1] * sb[:, 1]
    pi = sa[:, 0] * sb[:, 1] + sa[:, 1] * sb[:, 0]
    got = np.asarray(ops.negacyclic_ifft(jnp.stack([pr, pi], axis=1)))[0]
    # exact integer oracle
    want = np.zeros(N, dtype=np.int64)
    for i in range(N):
        k = (i + np.arange(N)) % (2 * N)
        np.add.at(want, k % N, np.where(k < N, a[i] * b, -(a[i] * b)))
    np.testing.assert_allclose(got, want, atol=np.maximum(1.0, np.abs(want).max() * 3e-5))


# --- BRU external-product MAC -------------------------------------------------

@pytest.mark.parametrize("B,J,K,F", [(1, 2, 2, 256), (12, 4, 2, 1024),
                                     (12, 6, 3, 2048), (48, 4, 2, 16384)])
def test_bru_mac_matches_ref(B, J, K, F):
    rng = np.random.default_rng(B * F)
    dig = jnp.asarray(rng.standard_normal((B, 2, J, F)) * 100, dtype=jnp.float32)
    bsk = jnp.asarray(rng.standard_normal((2, J, K, F)), dtype=jnp.float32)
    got = np.asarray(ops.bru_mac(dig, bsk))
    want = np.asarray(ref.external_product_mac_ref(dig, bsk))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("block_f", [128, 512, 2048])
def test_bru_mac_block_sweep(block_f):
    rng = np.random.default_rng(block_f)
    dig = jnp.asarray(rng.standard_normal((4, 2, 4, 2048)), dtype=jnp.float32)
    bsk = jnp.asarray(rng.standard_normal((2, 4, 2, 2048)), dtype=jnp.float32)
    got = np.asarray(ops.bru_mac(dig, bsk, block_f=block_f))
    want = np.asarray(ref.external_product_mac_ref(dig, bsk))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# --- LPU key-switch MAC (uint32-limb 64-bit arithmetic) -----------------------

@pytest.mark.parametrize("B,S,T", [(1, 128, 65), (4, 1024, 513), (2, 4096, 257)])
def test_keyswitch_mac_exact(B, S, T):
    rng = np.random.default_rng(S + T)
    digits = jnp.asarray(
        rng.integers(-(1 << 15), 1 << 15, (B, S)), dtype=jnp.int32)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (S, T), dtype=np.uint64))
    got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk))
    want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
    np.testing.assert_array_equal(got, want)  # EXACT mod 2^64


def test_keyswitch_mac_extreme_digits():
    """Full int32 digit range (negative, maximal) stays exact."""
    digits = jnp.asarray(
        [[-(1 << 31), (1 << 31) - 1, -1, 1, 0, 7, -7, 12345]], dtype=jnp.int32)
    rng = np.random.default_rng(0)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (8, 33), dtype=np.uint64))
    got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk, block_s=8))
    want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
    np.testing.assert_array_equal(got, want)


def test_keyswitch_mac_grid_accumulation():
    """Multi-block S accumulation (sequential grid) is exact."""
    rng = np.random.default_rng(3)
    digits = jnp.asarray(rng.integers(-(1 << 12), 1 << 12, (3, 2048)), dtype=jnp.int32)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (2048, 129), dtype=np.uint64))
    for bs in (256, 512, 2048):
        got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk, block_s=bs))
        want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("S,block_s", [(100, 64), (2560, 1024), (33, 32)])
def test_keyswitch_mac_unaligned_block_padding(S, block_s):
    """S not a multiple of the block size zero-pads exactly (the fused
    engine hits this whenever big_n*ks_level is not block-aligned)."""
    rng = np.random.default_rng(S)
    digits = jnp.asarray(rng.integers(-(1 << 12), 1 << 12, (2, S)), dtype=jnp.int32)
    ksk = jnp.asarray(rng.integers(0, 1 << 64, (S, 65), dtype=np.uint64))
    got = np.asarray(ops.lpu_keyswitch_mac(digits, ksk, block_s=block_s))
    want = np.asarray(ref.keyswitch_mac_ref(digits, ksk))
    np.testing.assert_array_equal(got, want)


# --- fused engine room (repro.kernels.fused_pbs) -----------------------------
#
# The differential contract of the tentpole: every fused entry point
# graded against the reference engine path on real key material, the
# keyswitch stage bit-for-bit.

def _encrypt_batch(ctx, B):
    key = jax.random.PRNGKey(97)
    msgs = np.arange(B) % ctx.params.plaintext_modulus
    cts = jnp.stack([ctx.encrypt(jax.random.fold_in(key, i), int(m))
                     for i, m in enumerate(msgs)])
    return cts, msgs


def test_keyswitch_fused_bit_identical(ctx_2bit, pallas_engine_2bit):
    """Fused uint32-limb keyswitch == lwe.keyswitch, bit-for-bit."""
    from repro.core import lwe
    p = ctx_2bit.params
    cts, _ = _encrypt_batch(ctx_2bit, 5)
    want = lwe.keyswitch(cts, ctx_2bit.ksk, p.ks_base_log, p.ks_level)
    got = pallas_engine_2bit.fused_pack.keyswitch(cts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B", [1, 4])
def test_blind_rotate_fused_matches_reference(ctx_2bit, pallas_engine_2bit, B):
    """Fused blind rotation (FFT + BRU MAC kernels, f64 planes) extracts
    to the same decrypted digits as the complex128 reference.

    NB: raw GLWE coefficients are NOT compared — after the first CMux
    round, ~2^29 transform-rounding differences can flip a gadget-
    decompose digit at a rounding boundary, swinging individual mask
    coefficients by a whole GGSW row while the PHASE (what decrypts)
    moves only ~2^40 << delta.  The decrypt-level contract is the
    meaningful one for chained CMux."""
    from repro.core import batch as batch_mod, glwe, lwe
    p = ctx_2bit.params
    cts, msgs = _encrypt_batch(ctx_2bit, B)
    small = lwe.keyswitch(cts, ctx_2bit.ksk, p.ks_base_log, p.ks_level)
    ms = lwe.mod_switch(small, p.log2_N + 1)
    table = jnp.arange(p.plaintext_modulus, dtype=jnp.uint64)
    poly = glwe.make_lut_poly(table, p)
    luts = glwe.trivial(jnp.broadcast_to(poly, (B, p.N)), p.k)
    want = glwe.sample_extract(
        batch_mod.blind_rotate_batch(luts, ms, ctx_2bit.bsk_f, p))
    got = glwe.sample_extract(
        pallas_engine_2bit.fused_pack.blind_rotate(luts, ms))
    dec_ref = [int(ctx_2bit.decrypt(v)) for v in want]
    dec_pal = [int(ctx_2bit.decrypt(v)) for v in got]
    assert dec_pal == dec_ref == [int(m) for m in msgs]


@pytest.mark.parametrize("B", [1, 5, 12])
def test_pbs_batch_fused_decrypt_identical(ctx_2bit, engine_2bit,
                                           pallas_engine_2bit, B):
    """End-to-end fused lut_batch decrypts identically to reference."""
    from repro.core import glwe
    p = ctx_2bit.params
    cts, msgs = _encrypt_batch(ctx_2bit, B)
    table = jnp.asarray([(3 * v + 1) % p.plaintext_modulus
                         for v in range(p.plaintext_modulus)], dtype=jnp.uint64)
    polys = jnp.broadcast_to(glwe.make_lut_poly(table, p), (B, p.N))
    out_ref = engine_2bit.lut_batch(cts, polys)
    out_pal = pallas_engine_2bit.lut_batch(cts, polys)
    dec_ref = [int(ctx_2bit.decrypt(v)) for v in out_ref]
    dec_pal = [int(ctx_2bit.decrypt(v)) for v in out_pal]
    assert dec_pal == dec_ref == [(3 * int(m) + 1) % p.plaintext_modulus
                                  for m in msgs]


@pytest.mark.slow
def test_pbs_batch_fused_decrypt_identical_4bit(ctx_4bit, engine_4bit,
                                                pallas_engine_4bit):
    """Same differential at 4-bit params (N=2048): the noise margin is
    tighter, so this catches precision regressions the 2-bit set hides."""
    from repro.core import glwe
    p = ctx_4bit.params
    cts, msgs = _encrypt_batch(ctx_4bit, 6)
    table = jnp.asarray([(v * v) % p.plaintext_modulus
                         for v in range(p.plaintext_modulus)], dtype=jnp.uint64)
    polys = jnp.broadcast_to(glwe.make_lut_poly(table, p), (6, p.N))
    dec_ref = [int(ctx_4bit.decrypt(v))
               for v in engine_4bit.lut_batch(cts, polys)]
    dec_pal = [int(ctx_4bit.decrypt(v))
               for v in pallas_engine_4bit.lut_batch(cts, polys)]
    assert dec_pal == dec_ref == [(int(m) ** 2) % p.plaintext_modulus
                                  for m in msgs]


def test_fused_pack_resident_across_rounds(ctx_2bit, pallas_engine_2bit):
    """The key-reuse contract: ONE pack (same device arrays) services
    multiple chained PBS rounds, and round i+1 consumes round i's output
    correctly (the BSK-resident multi-round path)."""
    from repro.core import glwe
    eng = pallas_engine_2bit
    p = ctx_2bit.params
    pack0 = eng.fused_pack
    cts, msgs = _encrypt_batch(ctx_2bit, 4)
    table = jnp.asarray([(v + 1) % p.plaintext_modulus
                         for v in range(p.plaintext_modulus)], dtype=jnp.uint64)
    polys = jnp.broadcast_to(glwe.make_lut_poly(table, p), (4, p.N))
    out = cts
    for round_i in range(3):
        out = eng.lut_batch(out, polys)
        assert eng.fused_pack is pack0          # no rebuild between rounds
        assert eng.fused_pack.bsk_planes is pack0.bsk_planes
    dec = [int(ctx_2bit.decrypt(v)) for v in out]
    assert dec == [(int(m) + 3) % p.plaintext_modulus for m in msgs]


def test_fused_pack_bytes_within_roofline_bound(pallas_engine_2bit):
    """Bandwidth gate: the pack's streamed bytes per fused round must sit
    within the analytic `launch.roofline.pbs_round_model` bound, and key
    bytes must equal the reference engine's ledger quantity exactly."""
    from repro.launch.roofline import pbs_round_model
    eng = pallas_engine_2bit
    pack = eng.fused_pack
    for B in (1, 12, 48):
        model = pbs_round_model(eng.params, B)
        assert pack.bytes_streamed_per_round(B) <= model.fused_bytes
        # key reuse only pays off past B=1 (at B=1 the two are equal)
        assert model.fused_bytes <= model.unfused_bytes
        if B > 1:
            assert model.fused_bytes < model.unfused_bytes
    bsk_b, ksk_b = pack.resident_key_bytes
    assert (bsk_b, ksk_b) == eng.key_bytes


def test_engine_kernel_backend_validation(ctx_2bit):
    """Bad backend strings and mesh+pallas are rejected at build time."""
    from repro.core.engine import TaurusEngine
    with pytest.raises(ValueError, match="kernel_backend"):
        TaurusEngine.from_context(ctx_2bit, kernel_backend="cuda")
