"""The roofline analyzer itself: loop trip counts, collectives, DUS
aliasing — validated on small compiled programs."""
import subprocess
import sys
import os
import json

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze

mesh = jax.make_mesh((2, 4), ("data", "model"))
A = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P("data", "model")))
B = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "model")))

def f1(a, b):
    return a @ b

def f10(a, b):
    def step(x, _):
        return x @ b, ()
    x, _ = jax.lax.scan(step, a, None, length=10)
    return x

c1 = analyze(jax.jit(f1).lower(A, B).compile().as_text())
c10 = analyze(jax.jit(f10).lower(A, B).compile().as_text())
out = {
    "flops1": c1.flops, "flops10": c10.flops,
    "coll10": c10.coll_bytes, "bytes10": c10.hbm_bytes,
    "major10": c10.hbm_bytes_major,
}
print(json.dumps(out))
"""


def test_analyzer_loop_and_collective_accounting():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    # single sharded matmul: 2*512*1024*512 per device
    assert abs(out["flops1"] - 2 * 512 * 1024 * 256) < 1e6
    # scan body counted x10 (cost_analysis would report x1)
    assert abs(out["flops10"] - 10 * out["flops1"]) < 1e6
    # the all-gather inside the loop counted x10 (512x1024 f32 gathered)
    assert out["coll10"] >= 10 * 512 * 1024 * 4
    # major-bytes <= total bytes and nonzero
    assert 0 < out["major10"] <= out["bytes10"]
