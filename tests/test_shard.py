"""Sharded serving (ISSUE 10): the router/EngineShard split, elastic
admission, device assignment, KS-level dedup, and the pallas+mesh
route-around.

Decrypt-parity tests pin the tentpole's core contract: shards=1 is
indistinguishable (after decryption) from the pre-shard runtime, and
shards=2 from shards=1.  Queue-level tests use linear-only programs so
they spend no PBS time (same convention as tests/test_serve.py).
"""
import time

import numpy as np
import pytest

import jax

from repro.compiler.ir import trace
from repro.core import glwe
from repro.core.engine import TaurusEngine
from repro.core.integer import IntegerContext
from repro.launch.mesh import shard_devices, shard_mesh
from repro.runtime.elastic import ElasticAdmission, ElasticPolicy
from repro.serve import (ConfigError, ServeRuntime, build_shards,
                         decrypt_radix_output, encrypt_request_inputs,
                         radix_binop_program, radix_unop_program)
from repro.sim.arrivals import MMPP, arrival_plan

BITS = 8


@pytest.fixture()
def ic4(ctx_4bit, engine_4bit):
    return IntegerContext.create(ctx_4bit, engine_4bit)


def _linear_graph(const):
    """PBS-free program: (x + const) on a 1-element tensor."""
    return trace(lambda x: x + np.array([const]), (1,))


# --- ElasticAdmission: pure controller unit tests ---------------------------

def test_elastic_policy_validation():
    with pytest.raises(ValueError, match="floor"):
        ElasticPolicy(ceiling=2, floor=3)
    with pytest.raises(ValueError, match="floor"):
        ElasticPolicy(floor=0)
    with pytest.raises(ValueError, match="step"):
        ElasticPolicy(step_up=0)


def test_elastic_admission_grow_shrink_unit():
    el = ElasticAdmission(ElasticPolicy(ceiling=4, floor=1))
    assert el.limit == 1
    # backlog + saturated slots: grow one step at a time, never past
    # the ceiling
    for want in (2, 3, 4):
        assert el.observe(queue_depth=5, inflight=el.limit) is True
        assert el.limit == want
    assert el.observe(queue_depth=5, inflight=4) is False   # at ceiling
    assert el.high_water == 4 and el.grows == 3
    # backlog but idle slots: not a grow opportunity
    el2 = ElasticAdmission(ElasticPolicy(ceiling=4, floor=1))
    assert el2.observe(queue_depth=5, inflight=0) is False
    # low occupancy vetoes growth; a healthy signal permits it
    assert el.observe(queue_depth=5, inflight=4, occupancy=0.2) is False
    el3 = ElasticAdmission(ElasticPolicy(ceiling=4, floor=1))
    assert el3.observe(queue_depth=1, inflight=1, occupancy=0.9) is True
    # empty queue + idle slots: decay toward max(floor, inflight)
    assert el.observe(queue_depth=0, inflight=2) is True
    assert el.limit == 3                     # never cuts below running work
    assert el.observe(queue_depth=0, inflight=0) is True
    assert el.observe(queue_depth=0, inflight=0) is True
    assert el.limit == 1 and el.shrinks == 3
    assert el.observe(queue_depth=0, inflight=0) is False   # at floor


# --- device -> shard assignment ---------------------------------------------

def test_shard_devices_and_mesh():
    devs = jax.devices()
    with pytest.raises(ValueError, match=">= 1"):
        shard_devices(0)
    # oversubscription: fewer devices than shards round-robins
    sets = shard_devices(3)
    assert len(sets) == 3 and all(len(s) == 1 for s in sets)
    assert [s[0] for s in sets] == [devs[i % len(devs)] for i in range(3)]
    # exact fit: one device per shard
    one = shard_devices(len(devs))
    assert [s[0] for s in one] == list(devs)
    m = shard_mesh(one[0])
    assert m.devices.shape == (1,) and m.axis_names == ("data",)


# --- engine level: ConfigError + the keyswitch/lut_batch_small split --------

def test_engine_mesh_pallas_config_error(ctx_2bit):
    mesh = shard_mesh((jax.devices()[0],))
    with pytest.raises(ConfigError, match="pallas"):
        TaurusEngine.from_context(ctx_2bit, mesh=mesh,
                                  kernel_backend="pallas")
    # typed AND backward compatible: ConfigError is a ValueError
    assert issubclass(ConfigError, ValueError)


def test_engine_ks_split_matches_lut_batch(ctx_4bit, engine_4bit):
    """keyswitch + lut_batch_small composes to exactly lut_batch —
    the arithmetic identity KS-level dedup rests on."""
    params = ctx_4bit.params
    mod = params.plaintext_modulus
    xs = np.array([0, 3, 7, 11], dtype=np.uint64) % mod
    cts = ctx_4bit.encrypt(jax.random.key(70), xs)
    tables = np.stack([(np.arange(mod, dtype=np.uint64) + i) % mod
                       for i in range(len(xs))])
    full = engine_4bit.lut_batch_tables(cts, tables)
    small = engine_4bit.keyswitch(cts)
    split = engine_4bit.lut_batch_small(
        small, glwe.make_lut_polys_cached(tables, params))
    np.testing.assert_array_equal(np.asarray(full)[:len(xs)],
                                  np.asarray(split)[:len(xs)])
    got = [int(ctx_4bit.decrypt(r)) for r in np.asarray(split)[:len(xs)]]
    assert got == [int((x + i) % mod) for i, x in enumerate(xs)]


# --- scheduler level: KS dedup on/off decrypt parity ------------------------

def _serve_wave(ctx, engine, jobs, **kw):
    rt = ServeRuntime(ctx, engine, fused=True, max_inflight=len(jobs),
                      start_paused=True, **kw)
    handles = [rt.submit(g, enc, client_id=c) for c, g, enc in jobs]
    rt.resume()
    rt.drain()
    return rt, [h.outputs()[0] for h in handles]


def test_ks_dedup_on_off_decrypts_identical(ctx_4bit, engine_4bit, ic4):
    """A radix-add wave batches [digits, digits] against [msg, carry]
    tables every ripple round — guaranteed same-ciphertext rows, so
    KS-level dedup must fire, and turning it off must not change a
    single decrypted value."""
    m = ic4.spec(BITS).msg_bits
    g = radix_binop_program("radix_add", BITS, m)
    rng = np.random.default_rng(13)
    jobs, wants = [], []
    for i in range(3):
        a, b = int(rng.integers(0, 256)), int(rng.integers(0, 256))
        enc = encrypt_request_inputs(ic4, jax.random.key(90 + i), [a, b],
                                     BITS)
        jobs.append((f"client-{i}", g, enc))
        wants.append((a + b) % 256)

    rt_on, outs_on = _serve_wave(ctx_4bit, engine_4bit, jobs, ks_dedup=True)
    rt_off, outs_off = _serve_wave(ctx_4bit, engine_4bit, jobs,
                                   ks_dedup=False)
    for o_on, o_off, want in zip(outs_on, outs_off, wants):
        assert decrypt_radix_output(ic4, o_on, BITS)[0] == want
        assert decrypt_radix_output(ic4, o_off, BITS)[0] == want
    assert rt_on.scheduler.stats["ks_dedup_hits"] > 0
    assert rt_off.scheduler.stats["ks_dedup_hits"] == 0
    # KS dedup shares keyswitches, not whole-row dispatches: the fused
    # round structure is unchanged
    assert (rt_on.scheduler.stats["fused_rounds"]
            == rt_off.scheduler.stats["fused_rounds"])
    assert (rt_on.scheduler.stats["dispatched_luts"]
            == rt_off.scheduler.stats["dispatched_luts"])


# --- router: shards=1 vs shards=2 decrypt parity + per-shard metrics --------

def test_sharded_decrypt_parity_and_metrics(ctx_4bit, engine_4bit, ic4):
    m = ic4.spec(BITS).msg_bits
    rng = np.random.default_rng(17)
    jobs, wants = [], []
    for i, op in enumerate(("radix_add", "radix_mul", "radix_add",
                            "radix_sub")):
        g = radix_binop_program(op, BITS, m)
        a, b = int(rng.integers(0, 256)), int(rng.integers(0, 256))
        enc = encrypt_request_inputs(ic4, jax.random.key(110 + i), [a, b],
                                     BITS)
        jobs.append((f"client-{i}", g, enc))
        oracle = {"radix_add": a + b, "radix_mul": a * b,
                  "radix_sub": a - b}[op]
        wants.append(oracle % 256)
    g_relu = radix_unop_program("radix_relu", BITS, m)
    enc = encrypt_request_inputs(ic4, jax.random.key(115), [-7], BITS)
    jobs.append(("client-4", g_relu, enc))
    wants.append(0)

    rt1, outs1 = _serve_wave(ctx_4bit, engine_4bit, jobs, shards=1)
    rt2, outs2 = _serve_wave(ctx_4bit, engine_4bit, jobs, shards=2)
    for o1, o2, want in zip(outs1, outs2, wants):
        assert decrypt_radix_output(ic4, o1, BITS)[0] == want
        assert decrypt_radix_output(ic4, o2, BITS)[0] == want

    # both shards did real work, and the per-shard namespace is complete
    c2 = rt2.metrics()["counters"]
    for i in (0, 1):
        assert c2[f"serve.shard.{i}.admitted"] > 0
        assert c2[f"serve.shard.{i}.completed"] > 0
        assert c2[f"serve.shard.{i}.fused_rounds"] > 0
        assert f"serve.shard.{i}.ks_dedup_hits" in c2
        assert c2[f"serve.shard.{i}.bsk_bytes_streamed"] > 0
    assert (c2["serve.shard.0.admitted"] + c2["serve.shard.1.admitted"]
            == len(jobs))
    # shards=1 mirrors the same namespace for shard 0 only
    c1 = rt1.metrics()["counters"]
    assert c1["serve.shard.0.admitted"] == len(jobs)
    assert "serve.shard.1.admitted" not in c1


def test_router_balances_and_no_client_starves(ctx_2bit, engine_2bit):
    """Least-loaded placement spreads a linear-program wave across both
    shards, and the router's round-robin client fairness survives the
    shard split: a flooding client cannot starve the others."""
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, shards=2,
                      max_inflight=1, start_paused=True)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(120), np.array([1]))
    handles = {}
    for i in range(4):                       # client A floods first
        handles[("A", i)] = rt.submit(g, [x], client_id="A")
    handles[("B", 0)] = rt.submit(g, [x], client_id="B")
    handles[("C", 0)] = rt.submit(g, [x], client_id="C")
    rt.resume()
    rt.drain()
    order = rt.stats["admitted"]
    assert len(order) == 6
    pos = {cid: [i for i, (c, _) in enumerate(order) if c == cid]
           for cid in "ABC"}
    n_clients = 3
    assert pos["B"][0] < n_clients
    assert pos["C"][0] < n_clients
    counters = rt.metrics()["counters"]
    assert counters["serve.shard.0.admitted"] > 0
    assert counters["serve.shard.1.admitted"] > 0
    for h in handles.values():
        assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 2


def test_build_shards_validation(ctx_2bit, engine_2bit):
    with pytest.raises(ConfigError, match=">= 1"):
        build_shards(ctx_2bit, engine_2bit, n_shards=0)
    with pytest.raises(ConfigError, match="device_sets"):
        build_shards(ctx_2bit, engine_2bit, n_shards=2,
                     device_sets=[(jax.devices()[0],)])
    with pytest.raises(TypeError, match="elastic"):
        build_shards(ctx_2bit, engine_2bit, n_shards=1, elastic="yes")


def test_pallas_shards_route_around_mesh(ctx_2bit, pallas_engine_2bit):
    """A multi-device shard asking for pallas is the documented
    ConfigError combination — build_shards routes around it at
    construction by pinning the shard to a single-device pallas engine,
    and the resulting runtime still serves correctly."""
    dev = jax.devices()[0]
    shards = build_shards(ctx_2bit, pallas_engine_2bit, n_shards=2,
                          device_sets=[(dev,), (dev, dev)])
    assert shards[1].engine.kernel_backend == "pallas"
    assert shards[1].engine.mesh is None       # routed around, not crashed
    assert shards[1].engine is not shards[0].engine

    rt = ServeRuntime(ctx_2bit, pallas_engine_2bit, fused=False, shards=2,
                      max_inflight=1, start_paused=True)
    assert all(s.engine.kernel_backend == "pallas" for s in rt.shards)
    g = _linear_graph(2)
    x = ctx_2bit.encrypt(jax.random.key(130), np.array([1]))
    handles = [rt.submit(g, [x], client_id=f"c{i}") for i in range(3)]
    rt.resume()
    rt.drain()
    for h in handles:
        assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 3


@pytest.mark.slow
def test_sharded_gpt2_block_parity(ctx_4bit, engine_4bit):
    """The ISSUE 10 acceptance's heavy workload: a quantized GPT-2-style
    block (ct*ct attention, ReLU MLP) served with shards=2 decrypts to
    exactly the eager backend's values — encrypted-transformer traffic
    survives the router/shard split bit-for-bit."""
    from repro.api import Session
    from repro.fhe_ml import lower
    from repro.fhe_ml.quantize import calibrate_radix, quantize_to_radix

    g, meta = lower.lower_gpt2_block_radix(2, bits=16, msg_bits=2, seed=1)
    rng = np.random.default_rng(3)
    xf = rng.uniform(-1, 1, size=(2,))
    rq = calibrate_radix(xf, 16, 2, qmax=meta["input_qmax"])
    q = quantize_to_radix(xf, rq)
    want = meta["int_fn"](q) % (1 << 16)
    outs = {}
    for label, kw in (("eager", {"backend": "eager"}),
                      ("serve2", {"backend": "serve", "shards": 2})):
        with Session(ctx_4bit, engine_4bit, **kw) as sess:
            prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
            outs[label] = np.asarray(sess(prog, jax.random.key(7), q)[0])
    np.testing.assert_array_equal(outs["eager"] % (1 << 16), want)
    np.testing.assert_array_equal(outs["eager"], outs["serve2"])


# --- elastic admission under live traffic -----------------------------------

def test_elastic_mmpp_burst_ramps_and_decays(ctx_2bit, engine_2bit):
    """An MMPP calm->burst arrival stream against one elastic shard:
    the limit ramps above the floor during the burst, never exceeds the
    ceiling, and decays back to the floor once the burst drains."""
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, elastic=True,
                      max_inflight=4)
    el = rt.shards[0].elastic
    assert el is not None and el.limit == el.policy.floor == 1
    assert el.policy.ceiling == 4

    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(140), np.array([2]))
    # calm 1.0 virtual-s at 4 rps, then a 0.5 virtual-s burst at 80 rps
    plan = arrival_plan(MMPP(((4.0, 1.0), (80.0, 0.5))), population=3,
                        duration_s=1.5, seed=7)
    assert len(plan) > 10                      # the burst actually burst
    scale = 0.02
    t0 = time.perf_counter()
    handles = []
    for t_v, client in plan:
        delay = t_v * scale - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        handles.append(rt.submit(g, [x], client_id=f"c{client}"))
    rt.drain()

    assert el.high_water > el.policy.floor      # ramped up under backlog
    assert el.high_water <= el.policy.ceiling   # never exceeded the ceiling
    assert el.grows >= 1 and el.shrinks >= 1
    assert el.limit == el.policy.floor          # decayed after the burst
    assert rt.stats["completed"] == len(handles)
    for h in handles[:3] + handles[-3:]:
        assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 3


def test_elastic_cross_shard_fairness(ctx_2bit, engine_2bit):
    """Two elastic shards under a burst: each shard runs its OWN
    controller (limits move independently, both bounded by the shared
    ceiling), both shards take work, and no client starves."""
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, shards=2,
                      elastic=True, max_inflight=2, start_paused=True)
    g = _linear_graph(2)
    x = ctx_2bit.encrypt(jax.random.key(150), np.array([1]))
    handles = {}
    for i in range(6):                       # client A floods first
        handles[("A", i)] = rt.submit(g, [x], client_id="A")
    handles[("B", 0)] = rt.submit(g, [x], client_id="B")
    handles[("C", 0)] = rt.submit(g, [x], client_id="C")
    rt.resume()
    rt.drain()

    controllers = [s.elastic for s in rt.shards]
    assert controllers[0] is not controllers[1]
    for el in controllers:
        assert el.high_water <= el.policy.ceiling == 2
        assert el.limit == el.policy.floor
    order = rt.stats["admitted"]
    pos = {cid: [i for i, (c, _) in enumerate(order) if c == cid]
           for cid in "ABC"}
    assert pos["B"][0] < 3 and pos["C"][0] < 3
    counters = rt.metrics()["counters"]
    assert counters["serve.shard.0.admitted"] > 0
    assert counters["serve.shard.1.admitted"] > 0
    for h in handles.values():
        assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 3
