"""repro.sim: seeded arrival processes, the client state machine, the
deterministic virtual-time runner (same scenario + seed ⇒ identical
report, field for field), and a real-ciphertext smoke scenario through
`run_scenario`.

The virtual-runner tests use synthetic workloads (no crypto — the
simulator never builds or encrypts anything), so forcing every
life-cycle outcome (DONE / TIMEOUT / ABANDONED, drain and fail-fast
cutoffs) is cheap.  The real smoke scenario runs PBS-free const-op
programs on the session key material, keeping it in the smoke lane.
"""
import json
import random

import pytest

from repro.sim import (ABANDONED, DONE, FAILED, SUBMIT, TIMEOUT, WAITING,
                       ClientRequest, ClosedLoop, MMPP, Phase, Poisson,
                       Scenario, SLOTargets, Workload, WorkloadMix,
                       arrival_plan, outcome_counts, run_scenario,
                       simulate_scenario)


def _synthetic(name: str, service_s: float) -> Workload:
    """A workload the virtual runner can use without any crypto."""
    return Workload(name, builder=lambda: (None, (), ()),
                    sample=lambda rng: [], mean_service_s=service_s)


def _scenario(service_s: float, *, rate: float = 2.0, duration: float = 10.0,
              deadline: float = 5.0, drain: bool = True, seed: int = 0,
              phases: tuple = (), arrival=None) -> Scenario:
    mix = WorkloadMix([(_synthetic("syn", service_s), 1.0)])
    return Scenario("t", arrival or Poisson(rate), mix, duration,
                    deadline_s=deadline, drain=drain, seed=seed,
                    phases=phases, slo=SLOTargets(abandon_rate=0.99))


# --- arrivals ---------------------------------------------------------------

def test_poisson_schedule_seeded_and_rate_sane():
    a = Poisson(3.0).schedule(50.0, seed=4)
    b = Poisson(3.0).schedule(50.0, seed=4)
    c = Poisson(3.0).schedule(50.0, seed=5)
    assert a == b and a != c
    assert all(0 <= t < 50.0 for t in a) and a == sorted(a)
    assert 50 < len(a) < 300                 # ~150 expected

def test_mmpp_burst_segment_denser_than_calm():
    proc = MMPP(((0.5, 20.0), (8.0, 20.0)))
    times = proc.schedule(40.0, seed=9)
    assert times == proc.schedule(40.0, seed=9)
    calm = sum(1 for t in times if t < 20.0)
    burst = sum(1 for t in times if t >= 20.0)
    assert burst > 4 * max(calm, 1)

def test_arrival_plan_round_robins_population():
    plan = arrival_plan(Poisson(5.0), population=3, duration_s=10.0, seed=1)
    assert [c for _, c in plan[:6]] == [0, 1, 2, 0, 1, 2]
    with pytest.raises(AssertionError):
        arrival_plan(ClosedLoop(1.0), 2, 10.0, 0)


# --- client state machine ---------------------------------------------------

def test_state_machine_valid_paths_and_rejections():
    r = ClientRequest("c", "w", 0.0, 5.0)
    r.transition(SUBMIT)
    r.transition(WAITING)
    r.transition(DONE, at_s=1.25)
    assert r.finish_s == 1.25 and r.latency_s == 1.25
    # terminal states accept nothing further
    with pytest.raises(ValueError):
        r.transition(SUBMIT)
    # no skipping straight to WAITING, no WAITING -> SUBMIT
    with pytest.raises(ValueError):
        ClientRequest("c", "w", 0.0, 5.0).transition(WAITING)
    r2 = ClientRequest("c", "w", 0.0, 5.0)
    r2.transition(SUBMIT)
    with pytest.raises(ValueError):
        r2.transition(SUBMIT)
    # every documented edge out of SUBMIT and WAITING
    for tail in (FAILED, ABANDONED, WAITING):
        rr = ClientRequest("c", "w", 0.0, 5.0)
        rr.transition(SUBMIT)
        rr.transition(tail, at_s=2.0)
    for tail in (DONE, TIMEOUT, ABANDONED, FAILED):
        rr = ClientRequest("c", "w", 0.0, 5.0)
        rr.transition(SUBMIT)
        rr.transition(WAITING)
        rr.transition(tail, at_s=2.0)

def test_outcome_counts_tallies_terminals_only():
    recs = []
    for tail in (DONE, DONE, TIMEOUT, ABANDONED, FAILED):
        r = ClientRequest("c", "w", 0.0, 1.0)
        r.transition(SUBMIT)
        r.transition(WAITING)
        r.transition(tail, at_s=0.5)
        recs.append(r)
    open_req = ClientRequest("c", "w", 0.0, 1.0)
    open_req.transition(SUBMIT)
    counts = outcome_counts(recs + [open_req])
    assert counts == {DONE: 2, TIMEOUT: 1, ABANDONED: 1, FAILED: 1,
                      "attempts": 5}


# --- workload mix -----------------------------------------------------------

def test_workload_mix_weighted_and_seeded():
    a, b = _synthetic("a", 1.0), _synthetic("b", 1.0)
    mix = WorkloadMix([(a, 3.0), (b, 1.0)])
    draws = [mix.sample(random.Random(7)).name for _ in range(5)]
    assert len(set(draws)) == 1              # same seed, same draw
    rng = random.Random(7)
    names = [mix.sample(rng).name for _ in range(400)]
    assert 0.6 < names.count("a") / 400 < 0.9
    with pytest.raises(ValueError):
        WorkloadMix([])


# --- deterministic virtual runner -------------------------------------------

def test_simulate_identical_reports_field_for_field():
    third = 4.0
    sc = _scenario(0.8, rate=3.0, duration=12.0, deadline=4.0, seed=21,
                   arrival=MMPP(((1.0, third), (6.0, third), (1.0, third))),
                   phases=(Phase("calm", third), Phase("burst", third),
                           Phase("recover", third)))
    r1 = simulate_scenario(sc, max_inflight=2)
    r2 = simulate_scenario(sc, max_inflight=2)
    assert r1.report == r2.report
    # field-for-field through JSON too (what BENCH_sim.json consumers see)
    assert json.dumps(r1.report, sort_keys=True) == \
        json.dumps(r2.report, sort_keys=True)
    # a different seed is different traffic
    sc2 = _scenario(0.8, rate=3.0, duration=12.0, deadline=4.0, seed=22,
                    arrival=sc.arrival, phases=sc.phases)
    assert simulate_scenario(sc2, max_inflight=2).report != r1.report
    # per-phase attribution covers every terminal record
    phases = r1.report["phases"]
    assert [p["phase"] for p in phases] == ["calm", "burst", "recover"]
    assert sum(p["requests"] for p in phases) == \
        r1.report["overall"]["requests"]

def test_simulate_outcomes_done_timeout_abandoned():
    # ample capacity + generous deadline: everything DONE
    run = simulate_scenario(_scenario(0.2, deadline=5.0), max_inflight=8)
    states = {r.record.state for r in run.records}
    assert states == {DONE}
    assert run.report["overall"]["abandon_rate"] == 0.0
    # service longer than the deadline but a free slot: started, finishes
    # late -> TIMEOUT (abandon() would have refused)
    run = simulate_scenario(_scenario(3.0, rate=0.2, deadline=1.0),
                            max_inflight=8)
    assert {r.record.state for r in run.records} == {TIMEOUT}
    # one slot + slow service: the queue outlives the deadline -> ABANDONED
    run = simulate_scenario(_scenario(4.0, rate=3.0, deadline=2.0),
                            max_inflight=1)
    states = {r.record.state for r in run.records}
    assert ABANDONED in states and DONE in states or TIMEOUT in states
    assert run.report["overall"]["abandoned"] > 0

def test_simulate_fail_fast_cutoff_abandons_queue():
    # drain=False: whatever is still queued at the cutoff is dropped
    # (the runtime's close(drain=False) path), started work completes
    sc = _scenario(2.0, rate=4.0, duration=6.0, deadline=50.0, drain=False)
    run = simulate_scenario(sc, max_inflight=1)
    counts = outcome_counts([r.record for r in run.records])
    assert counts[ABANDONED] > 0 and counts[DONE] > 0
    assert all(r.record.state in (DONE, TIMEOUT, ABANDONED)
               for r in run.records)
    # abandons at the cutoff are stamped at the scenario end
    cut = [r.record for r in run.records if r.record.state == ABANDONED]
    assert all(abs(r.finish_s - 6.0) < 1e-9 or r.finish_s <= 6.0
               for r in cut)

def test_simulate_closed_loop_bounded_by_population():
    sc = _scenario(1.0, duration=20.0, deadline=10.0,
                   arrival=ClosedLoop(think_s=0.5))
    sc = Scenario(sc.name, sc.arrival, sc.mix, sc.duration_s,
                  population=2, deadline_s=sc.deadline_s, slo=sc.slo,
                  seed=3)
    run = simulate_scenario(sc, max_inflight=8)
    assert run.report == simulate_scenario(sc, max_inflight=8).report
    assert {r.record.state for r in run.records} == {DONE}
    # 2 clients, ~1.5s per cycle, 20s: roughly 2*20/1.5 requests; an
    # open loop at the same nominal rate would be unbounded by service
    assert 10 <= len(run.records) <= 40
    # never more in flight than the population: queue wait stays ~0
    assert run.report["overall"]["queue_wait_p99_s"] < 1e-9

def test_slo_checks_and_verdicts():
    sc = _scenario(0.2, deadline=5.0)
    sc = Scenario(sc.name, sc.arrival, sc.mix, sc.duration_s,
                  deadline_s=sc.deadline_s, seed=1,
                  slo=SLOTargets(p99_s=2.0, abandon_rate=0.05,
                                 goodput_rps=0.5))
    rep = simulate_scenario(sc, max_inflight=8).report
    assert rep["ok"] and rep["as_expected"]
    assert {c["metric"] for c in rep["overall"]["checks"]} == \
        {"p99_s", "abandon_rate", "goodput_rps"}
    # an impossible goodput floor flips the verdict
    sc_bad = Scenario(sc.name, sc.arrival, sc.mix, sc.duration_s,
                      deadline_s=sc.deadline_s, seed=1,
                      slo=SLOTargets(goodput_rps=1e9))
    rep_bad = simulate_scenario(sc_bad, max_inflight=8).report
    assert not rep_bad["ok"] and not rep_bad["as_expected"]

def test_scenario_phase_duration_mismatch_rejected():
    mix = WorkloadMix([(_synthetic("syn", 1.0), 1.0)])
    with pytest.raises(ValueError):
        Scenario("bad", Poisson(1.0), mix, duration_s=10.0,
                 phases=(Phase("a", 3.0), Phase("b", 3.0)))


# --- the real runner on real ciphertexts (smoke lane) -----------------------

def test_run_scenario_real_ciphertexts_smoke(ctx_2bit, engine_2bit):
    """A 1.5-second PBS-free scenario through a real ServeRuntime:
    every payload decrypts to the oracle value and the report carries
    measured latency quantiles."""
    from repro.api.session import trace_program
    from repro.api.tracing import IntSpec

    bits, msg = 4, 1
    mod = 1 << bits

    def builder():
        prog = trace_program(lambda x: x * 2 + 1, (IntSpec(bits, msg),))
        return prog.graph, prog.in_specs, prog.out_specs

    w = Workload("const4", builder,
                 sample=lambda rng: [rng.randrange(mod)],
                 oracle=lambda v: [(2 * v[0] + 1) % mod],
                 mean_service_s=0.01)
    sc = Scenario("real_smoke", Poisson(4.0), WorkloadMix([(w, 1.0)]),
                  duration_s=1.5, deadline_s=6.0, population=2,
                  slo=SLOTargets(abandon_rate=0.0, goodput_rps=0.5),
                  seed=5)
    run = run_scenario(sc, ctx_2bit, engine_2bit, max_inflight=2,
                       validate=True)
    assert run.report["runner"] == "real"
    o = run.report["overall"]
    assert o["requests"] >= 2 and o["done"] == o["requests"]
    assert o["p50_s"] is not None and o["p99_s"] is not None
    assert all(r.record.ok_payload for r in run.records)
    assert run.report["ok"] and run.report["as_expected"]
