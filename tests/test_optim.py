"""Optimizer substrate tests."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import AdamW, cosine_schedule
from repro.optim.adamw import global_norm


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for step in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, st, metrics = opt.update(params, st, g,
                                         jnp.asarray(step))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    st = opt.init(params)
    g = {"w": jnp.full(4, 1e6)}
    new, st, metrics = opt.update(params, st, g, jnp.asarray(0))
    assert float(metrics["grad_norm"]) > 1e5
    # post-clip first Adam step is bounded by lr regardless of grad scale
    assert float(jnp.max(jnp.abs(new["w"]))) <= 1.0 + 1e-6


def test_weight_decay_skips_vectors():
    opt = AdamW(lr=0.1, weight_decay=1.0)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    st = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = opt.update(params, st, zeros, jnp.asarray(0))
    assert float(jnp.max(jnp.abs(new["mat"]))) < 1.0   # decayed
    np.testing.assert_allclose(np.asarray(new["vec"]), 1.0)  # not decayed


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == 1.0
    assert 0.0 < float(lr(60)) < 1.0
    np.testing.assert_allclose(float(lr(110)), 0.1, rtol=1e-5)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    np.testing.assert_allclose(float(global_norm(t)), 5.0)
