"""Pallas kernel sweeps: shapes x dtypes against the ref.py oracles
(interpret mode on CPU; deliverable c)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels import fourstep_fft, external_product, keyswitch


@pytest.mark.parametrize("N", [256, 1024, 4096, 16384])
@pytest.mark.parametrize("B", [1, 3])
def test_fourstep_fft_roundtrip_sweep(N, B):
    rng = np.random.default_rng(N + B)
    x = rng.integers(-2 ** 20, 2 ** 20, (B, N)).astype(np.float32)
    spec = fourstep_fft.fft_forward(jnp.asarray(x))
    ref_spec = ref.fft_forward_ref(jnp.asarray(x, jnp.float64))
    scale = np.abs(np.asarray(ref_spec)).max()
    np.testing.assert_allclose(np.asarray(spec), np.asarray(ref_spec),
                               atol=scale * 2e-5, rtol=0)
    back = fourstep_fft.fft_inverse(spec)
    np.testing.assert_allclose(np.asarray(back), x, atol=scale * 2e-5)


@pytest.mark.parametrize("J,K,F", [(2, 2, 256), (4, 2, 512), (8, 4, 1024)])
@pytest.mark.parametrize("B", [1, 12])
def test_external_product_mac_sweep(J, K, F, B):
    rng = np.random.default_rng(J * K + F + B)
    dig = rng.normal(size=(B, 2, J, F)).astype(np.float32) * 100
    bsk = rng.normal(size=(2, J, K, F)).astype(np.float32)
    got = external_product.external_product_mac(jnp.asarray(dig),
                                                jnp.asarray(bsk),
                                                block_f=min(256, F))
    want = ref.external_product_mac_ref(jnp.asarray(dig, jnp.float64),
                                        jnp.asarray(bsk, jnp.float64))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-2)


@pytest.mark.parametrize("S,T", [(64, 65), (256, 129), (1024, 97)])
@pytest.mark.parametrize("B", [1, 5])
def test_keyswitch_mac_exact_sweep(S, T, B):
    """The limb kernel is EXACT mod 2^64 — bit-equal to the u64 oracle."""
    rng = np.random.default_rng(S + T + B)
    digits = rng.integers(-2 ** 15, 2 ** 15, (B, S)).astype(np.int32)
    ksk = rng.integers(0, 2 ** 64, (S, T), dtype=np.uint64)
    got = ops.lpu_keyswitch_mac(jnp.asarray(digits), jnp.asarray(ksk),
                                block_s=min(64, S))
    want = ref.keyswitch_mac_ref(jnp.asarray(digits), jnp.asarray(ksk))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5),
                                        (jnp.float64, 1e-12)])
@pytest.mark.parametrize("N", [256, 2048, 8192])
def test_fourstep_fft_dtype_sweep(N, dtype, rtol):
    """The kernels are dtype-polymorphic: f32 (TPU-native) to ~2e-5 of
    the spectrum scale, f64 (fused engine path) to ~1e-12."""
    rng = np.random.default_rng(N)
    x = rng.integers(-2 ** 20, 2 ** 20, (2, N)).astype(np.float64)
    spec = fourstep_fft.fft_forward(jnp.asarray(x, dtype), dtype=dtype)
    assert spec.dtype == jnp.dtype(dtype)
    ref_spec = ref.fft_forward_ref(jnp.asarray(x, jnp.float64))
    scale = np.abs(np.asarray(ref_spec)).max()
    np.testing.assert_allclose(np.asarray(spec), np.asarray(ref_spec),
                               atol=scale * rtol, rtol=0)
    back = fourstep_fft.fft_inverse(spec, dtype=dtype)
    np.testing.assert_allclose(np.asarray(back), x, atol=scale * rtol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-2),
                                       (jnp.float64, 1e-9)])
@pytest.mark.parametrize("B", [1, 12])
def test_external_product_mac_dtype_sweep(B, dtype, tol):
    rng = np.random.default_rng(B)
    dig = rng.normal(size=(B, 2, 4, 512)).astype(np.float64) * 100
    bsk = rng.normal(size=(2, 4, 2, 512)).astype(np.float64)
    got = external_product.external_product_mac(
        jnp.asarray(dig, dtype), jnp.asarray(bsk, dtype),
        block_f=256, dtype=dtype)
    assert got.dtype == jnp.dtype(dtype)
    want = ref.external_product_mac_ref(jnp.asarray(dig), jnp.asarray(bsk))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=tol)


@pytest.mark.parametrize("width_fixture", ["2bit"])
@pytest.mark.parametrize("B", [3, 12])
def test_fused_pbs_sweep_batch_sizes(request, width_fixture, B):
    """Fused-path differential across batch sizes on real key material
    (the sweep-level view of the tests in test_kernels.py)."""
    ctx = request.getfixturevalue(f"ctx_{width_fixture}")
    eng_ref = request.getfixturevalue(f"engine_{width_fixture}")
    eng_pal = request.getfixturevalue(f"pallas_engine_{width_fixture}")
    from repro.core import glwe
    p = ctx.params
    key = jax.random.PRNGKey(B)
    msgs = np.arange(B) % p.plaintext_modulus
    cts = jnp.stack([ctx.encrypt(jax.random.fold_in(key, i), int(m))
                     for i, m in enumerate(msgs)])
    table = jnp.asarray([(2 * v) % p.plaintext_modulus
                         for v in range(p.plaintext_modulus)],
                        dtype=jnp.uint64)
    polys = jnp.broadcast_to(glwe.make_lut_poly(table, p), (B, p.N))
    dec_ref = [int(ctx.decrypt(v)) for v in eng_ref.lut_batch(cts, polys)]
    dec_pal = [int(ctx.decrypt(v)) for v in eng_pal.lut_batch(cts, polys)]
    assert dec_pal == dec_ref


def test_fft_f32_precision_supports_48bit_claim():
    """Observation 4: the paper's 48-bit fixed point <-> our split path.
    A single f32 four-step FFT roundtrip keeps relative error ~1e-6 of
    the spectrum scale; the scheme's noise budget at width<=10 needs
    ~2^-40 of the torus, met by the f64 oracle used in the engine and by
    the split-f32 TPU path (documented in DESIGN.md)."""
    rng = np.random.default_rng(0)
    N = 4096
    x = rng.integers(-2 ** 30, 2 ** 30, (2, N)).astype(np.float64)
    spec = fourstep_fft.fft_forward(jnp.asarray(x, jnp.float32))
    back = fourstep_fft.fft_inverse(spec)
    rel = np.abs(np.asarray(back) - x).max() / np.abs(x).max()
    assert rel < 5e-5
