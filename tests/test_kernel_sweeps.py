"""Pallas kernel sweeps: shapes x dtypes against the ref.py oracles
(interpret mode on CPU; deliverable c)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels import fourstep_fft, external_product, keyswitch


@pytest.mark.parametrize("N", [256, 1024, 4096, 16384])
@pytest.mark.parametrize("B", [1, 3])
def test_fourstep_fft_roundtrip_sweep(N, B):
    rng = np.random.default_rng(N + B)
    x = rng.integers(-2 ** 20, 2 ** 20, (B, N)).astype(np.float32)
    spec = fourstep_fft.fft_forward(jnp.asarray(x))
    ref_spec = ref.fft_forward_ref(jnp.asarray(x, jnp.float64))
    scale = np.abs(np.asarray(ref_spec)).max()
    np.testing.assert_allclose(np.asarray(spec), np.asarray(ref_spec),
                               atol=scale * 2e-5, rtol=0)
    back = fourstep_fft.fft_inverse(spec)
    np.testing.assert_allclose(np.asarray(back), x, atol=scale * 2e-5)


@pytest.mark.parametrize("J,K,F", [(2, 2, 256), (4, 2, 512), (8, 4, 1024)])
@pytest.mark.parametrize("B", [1, 12])
def test_external_product_mac_sweep(J, K, F, B):
    rng = np.random.default_rng(J * K + F + B)
    dig = rng.normal(size=(B, 2, J, F)).astype(np.float32) * 100
    bsk = rng.normal(size=(2, J, K, F)).astype(np.float32)
    got = external_product.external_product_mac(jnp.asarray(dig),
                                                jnp.asarray(bsk),
                                                block_f=min(256, F))
    want = ref.external_product_mac_ref(jnp.asarray(dig, jnp.float64),
                                        jnp.asarray(bsk, jnp.float64))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-2)


@pytest.mark.parametrize("S,T", [(64, 65), (256, 129), (1024, 97)])
@pytest.mark.parametrize("B", [1, 5])
def test_keyswitch_mac_exact_sweep(S, T, B):
    """The limb kernel is EXACT mod 2^64 — bit-equal to the u64 oracle."""
    rng = np.random.default_rng(S + T + B)
    digits = rng.integers(-2 ** 15, 2 ** 15, (B, S)).astype(np.int32)
    ksk = rng.integers(0, 2 ** 64, (S, T), dtype=np.uint64)
    got = ops.lpu_keyswitch_mac(jnp.asarray(digits), jnp.asarray(ksk),
                                block_s=min(64, S))
    want = ref.keyswitch_mac_ref(jnp.asarray(digits), jnp.asarray(ksk))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fft_f32_precision_supports_48bit_claim():
    """Observation 4: the paper's 48-bit fixed point <-> our split path.
    A single f32 four-step FFT roundtrip keeps relative error ~1e-6 of
    the spectrum scale; the scheme's noise budget at width<=10 needs
    ~2^-40 of the torus, met by the f64 oracle used in the engine and by
    the split-f32 TPU path (documented in DESIGN.md)."""
    rng = np.random.default_rng(0)
    N = 4096
    x = rng.integers(-2 ** 30, 2 ** 30, (2, N)).astype(np.float64)
    spec = fourstep_fft.fft_forward(jnp.asarray(x, jnp.float32))
    back = fourstep_fft.fft_inverse(spec)
    rel = np.abs(np.asarray(back) - x).max() / np.abs(x).max()
    assert rel < 5e-5
