"""Process-wide LUT-polynomial row cache (`glwe.make_lut_polys_cached`):
bounded FIFO eviction and cross-context reuse, asserted through the
hit/miss/eviction counters (ISSUE 3 satellite).

No key material needed — the cache keys on (params, table-row bytes)
and encodes plaintext test polynomials.
"""
import numpy as np
import pytest

from repro.core import glwe
from repro.core.integer import msg_table, carry_table
from repro.core.params import TEST_PARAMS, TEST_PARAMS_4BIT


@pytest.fixture(autouse=True)
def fresh_cache():
    """Each test starts (and leaves) the process-wide cache empty so the
    counters are deterministic and other tests see no stale rows."""
    glwe.clear_row_poly_cache()
    yield
    glwe.clear_row_poly_cache()


def _rows(params, n):
    """n distinct LUT rows (cyclic shifts of the identity; n <= mod)."""
    mod = params.plaintext_modulus
    assert n <= mod
    return np.stack([(np.arange(mod) + i) % mod
                     for i in range(n)]).astype(np.uint64)


def test_miss_then_hit_counters():
    tables = _rows(TEST_PARAMS, 3)
    glwe.make_lut_polys_cached(tables, TEST_PARAMS)
    assert glwe.row_poly_cache_stats() == {
        "hits": 0, "misses": 3, "evictions": 0}
    # a fresh, differently-tiled stack of the same rows: all hits
    glwe.make_lut_polys_cached(np.tile(tables, (2, 1)), TEST_PARAMS)
    assert glwe.row_poly_cache_stats() == {
        "hits": 3, "misses": 3, "evictions": 0}


def test_duplicate_rows_count_once_per_lookup():
    """A stack tiling ONE row encodes (and counts) one miss."""
    row = _rows(TEST_PARAMS, 1)
    glwe.make_lut_polys_cached(np.tile(row, (8, 1)), TEST_PARAMS)
    s = glwe.row_poly_cache_stats()
    assert (s["misses"], s["hits"]) == (1, 0)


def test_bounded_eviction_fifo(monkeypatch):
    monkeypatch.setattr(glwe, "_ROW_POLY_CACHE_MAX", 4)
    p = TEST_PARAMS_4BIT
    tables = _rows(p, 6)
    for i in range(6):
        glwe.make_lut_polys_cached(tables[i:i + 1], p)
    s = glwe.row_poly_cache_stats()
    assert len(glwe._ROW_POLY_CACHE) <= 4
    assert s["evictions"] == 2 and s["misses"] == 6
    # the first row was evicted (FIFO): looking it up again is a miss
    # that re-encodes to the SAME polynomial
    fresh = glwe.make_lut_polys_cached(tables[:1], p)
    assert glwe.row_poly_cache_stats()["misses"] == 7
    np.testing.assert_array_equal(
        np.asarray(fresh), np.asarray(glwe.make_lut_polys(tables[:1], p)))
    # the most recent row is still cached: pure hit
    glwe.make_lut_polys_cached(tables[5:6], p)
    assert glwe.row_poly_cache_stats()["hits"] == 1


def test_cross_context_reuse_counts_hits():
    """Two independent IntegerContexts over the same parameter set share
    row encodes: the second context's identical msg/carry stack is all
    cache hits (the serving win — concurrent clients stop re-encoding)."""
    p = TEST_PARAMS_4BIT
    w, m = p.width, 2
    stack = np.concatenate([np.tile(msg_table(w, m), (4, 1)),
                            np.tile(carry_table(w, m), (4, 1))])
    ctx_a_polys = glwe.make_lut_polys_cached(stack, p)
    s = glwe.row_poly_cache_stats()
    assert (s["misses"], s["hits"]) == (2, 0)      # msg + carry rows
    ctx_b_polys = glwe.make_lut_polys_cached(stack.copy(), p)
    s = glwe.row_poly_cache_stats()
    assert (s["misses"], s["hits"]) == (2, 2)      # second context: free
    np.testing.assert_array_equal(np.asarray(ctx_a_polys),
                                  np.asarray(ctx_b_polys))


def test_params_partition_the_cache():
    """Identical table bytes under DIFFERENT params are different
    entries — a hit under one parameter set must not leak a wrongly
    scaled polynomial to another."""
    t2 = np.arange(TEST_PARAMS.plaintext_modulus, dtype=np.uint64)[None]
    glwe.make_lut_polys_cached(t2, TEST_PARAMS)
    t4 = np.arange(TEST_PARAMS_4BIT.plaintext_modulus, dtype=np.uint64)[None]
    glwe.make_lut_polys_cached(t4, TEST_PARAMS_4BIT)
    s = glwe.row_poly_cache_stats()
    assert (s["misses"], s["hits"]) == (2, 0)
