import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft, torus

U64 = jnp.uint64


def naive_negacyclic(a, b):
    N = a.shape[0]
    c = np.zeros(N, dtype=object)
    for i in range(N):
        for j in range(N):
            k = i + j
            if k < N:
                c[k] += int(a[i]) * int(b[j])
            else:
                c[k - N] -= int(a[i]) * int(b[j])
    return np.array([x % (1 << 64) for x in c], dtype=np.uint64)


@pytest.mark.parametrize("N", [64, 256, 1024])
def test_negacyclic_mul_small_ints(N):
    rng = np.random.default_rng(N)
    a = rng.integers(-128, 128, N)
    b = rng.integers(-128, 128, N)
    ref = naive_negacyclic(a, b)
    got = fft.negacyclic_mul(
        jnp.asarray(a, dtype=jnp.int64), jnp.asarray(b, dtype=jnp.int64)
    )
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("N", [256, 1024])
def test_negacyclic_mul_digit_by_torus(N):
    """digits (small) x torus (uint64) — the external-product regime.

    f64 roundoff must stay far below the scheme noise slot (the 48-bit
    fixed-point argument, Obs. 4).  Expected floor: terms ~ B*2^63, summed
    over N with log(N) FFT stages -> ~ N * B * 2^64 * 2^-53 absolute.
    For width<=6 the message slot is >= 2^57, so a 2^28 bound leaves
    >= 29 bits of headroom.
    """
    rng = np.random.default_rng(N + 1)
    a = rng.integers(-(1 << 7), 1 << 7, N)                 # decomposed digits
    b = rng.integers(0, 1 << 64, N, dtype=np.uint64)       # torus values
    ref = naive_negacyclic(a, b)
    got = np.asarray(fft.negacyclic_mul(
        jnp.asarray(a, dtype=jnp.int64), jnp.asarray(b, dtype=U64)
    ))
    err = (got - ref).astype(np.int64)  # wraparound-aware difference
    assert np.max(np.abs(err)) < (1 << 28)


def test_forward_inverse_roundtrip():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-1000, 1000, 512), dtype=jnp.float64)
    back = fft.inverse(fft.forward(a))
    np.testing.assert_allclose(np.asarray(back), np.asarray(a), atol=1e-6)


@pytest.mark.parametrize("N", [64, 256, 1024])
def test_fourstep_parity_with_core_fft(N):
    """The MXU four-step factorization must agree with `repro.core.fft`
    (the engine's reference transform) in BOTH directions: same spectrum
    layout forward, and forward∘inverse returning the input."""
    from repro.kernels import fourstep_fft

    rng = np.random.default_rng(N)
    x = rng.integers(-(1 << 10), 1 << 10, (3, N)).astype(np.float32)
    spec = fourstep_fft.fft_forward(jnp.asarray(x))         # (B, 2, N/2) f32
    ref = fft.forward(jnp.asarray(x, jnp.float64))          # (B, N/2) complex
    scale = float(np.abs(np.asarray(ref)).max()) + 1.0
    np.testing.assert_allclose(
        np.asarray(spec[:, 0]) / scale, np.real(np.asarray(ref)) / scale,
        atol=3e-5)
    np.testing.assert_allclose(
        np.asarray(spec[:, 1]) / scale, np.imag(np.asarray(ref)) / scale,
        atol=3e-5)
    back = fourstep_fft.fft_inverse(spec)
    np.testing.assert_allclose(np.asarray(back), x, atol=scale * 3e-5)


def test_float_to_torus_wraps():
    # inputs chosen to be exactly representable in f64
    x = jnp.asarray(
        [0.0, 1.0, -1.0, 2.0**64, 2.0**33 + 7, -(2.0**33) - 3, 2.0**64 + 2.0**20],
        dtype=jnp.float64,
    )
    got = np.asarray(torus.float_to_torus(x))
    expect = np.array(
        [0, 1, (1 << 64) - 1, 0, (1 << 33) + 7, (1 << 64) - (1 << 33) - 3, 1 << 20],
        dtype=np.uint64,
    )
    np.testing.assert_array_equal(got, expect)
