"""End-to-end correctness of the TFHE scheme: the paper's substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decompose as dec, fft, ggsw, glwe, lwe, torus
from repro.core.params import TEST_PARAMS, TEST_PARAMS_4BIT, TEST_PARAMS_K2
from repro.core.pbs import TFHEContext, pbs

U64 = jnp.uint64


def test_decompose_recompose_close():
    key = jax.random.key(0)
    v = jax.random.bits(key, (1024,), dtype=U64)
    for bl, lv in [(4, 5), (8, 3), (12, 2), (23, 1)]:
        d = dec.decompose(v, bl, lv)
        assert int(jnp.max(jnp.abs(d))) <= (1 << bl) // 2
        r = dec.recompose(d, bl, lv)
        err = torus.to_signed(r - v)
        bound = 1 << (64 - bl * lv)  # rounding cut
        assert int(jnp.max(jnp.abs(err))) <= bound


def test_lwe_encrypt_decrypt():
    p = TEST_PARAMS
    key = jax.random.key(1)
    sk = lwe.keygen(key, p.n)
    msgs = jnp.arange(p.plaintext_modulus, dtype=U64)
    ct = lwe.encrypt(jax.random.key(2), sk, torus.encode(msgs, p.delta), p.lwe_std)
    ph = lwe.decrypt_phase(sk, ct)
    out = torus.decode(ph, p.delta, p.plaintext_modulus)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msgs))


def test_lwe_linear_ops():
    p = TEST_PARAMS_4BIT
    sk = lwe.keygen(jax.random.key(3), p.n)
    enc = lambda k, m: lwe.encrypt(
        jax.random.key(k), sk, torus.encode(jnp.asarray(m, dtype=U64), p.delta), p.lwe_std
    )
    c3, c5 = enc(10, 3), enc(11, 5)
    dec_ = lambda ct: int(torus.decode(
        lwe.decrypt_phase(sk, ct), p.delta, p.plaintext_modulus))
    assert dec_(lwe.add(c3, c5)) == 8
    assert dec_(lwe.sub(c5, c3)) == 2
    assert dec_(lwe.scalar_mul(c3, 2)) == 6
    assert dec_(lwe.add_plain(c3, torus.encode(jnp.asarray(4, dtype=U64), p.delta))) == 7


def test_glwe_encrypt_decrypt():
    p = TEST_PARAMS
    sk = glwe.keygen(jax.random.key(4), p.k, p.N)
    msg = torus.encode(
        jax.random.randint(jax.random.key(5), (p.N,), 0, p.plaintext_modulus, dtype=jnp.int64).astype(U64),
        p.delta,
    )
    ct = glwe.encrypt(jax.random.key(6), sk, msg, p.glwe_std)
    ph = glwe.decrypt_phase(sk, ct)
    out = torus.decode(ph, p.delta, p.plaintext_modulus)
    want = torus.decode(msg, p.delta, p.plaintext_modulus)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_glwe_rotate_matches_monomial_mul():
    N = 64
    rng = np.random.default_rng(7)
    poly_np = rng.integers(0, 1 << 64, N, dtype=np.uint64)
    poly = jnp.asarray(poly_np)
    for r in [0, 1, 5, N - 1, N, N + 3, 2 * N - 1]:
        # exact integer oracle: X^r * poly mod (X^N+1, 2^64)
        want = np.zeros(N, dtype=np.uint64)
        with np.errstate(over="ignore"):  # intended mod-2^64 wraparound
            for i in range(N):
                e = (i + r) % (2 * N)
                if e < N:
                    want[e] += poly_np[i]
                else:
                    want[e - N] -= poly_np[i]
        got = glwe.rotate(poly, jnp.asarray(r), N)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_sample_extract():
    p = TEST_PARAMS
    gsk = glwe.keygen(jax.random.key(8), p.k, p.N)
    msg = torus.encode(
        jax.random.randint(jax.random.key(9), (p.N,), 0, p.plaintext_modulus, dtype=jnp.int64).astype(U64),
        p.delta,
    )
    ct = glwe.encrypt(jax.random.key(10), gsk, msg, p.glwe_std)
    ext = glwe.sample_extract(ct)
    big = glwe.flatten_key(gsk)
    ph = lwe.decrypt_phase(big, ext)
    got = int(torus.decode(ph, p.delta, p.plaintext_modulus))
    want = int(torus.decode(msg[0], p.delta, p.plaintext_modulus))
    assert got == want


def test_external_product_selects():
    """ext_prod(GGSW(s), GLWE(M)) decrypts to s*M for s in {0,1}."""
    p = TEST_PARAMS
    gsk = glwe.keygen(jax.random.key(11), p.k, p.N)
    msg = torus.encode(
        jax.random.randint(jax.random.key(12), (p.N,), 0, p.plaintext_modulus, dtype=jnp.int64).astype(U64),
        p.delta,
    )
    ct = glwe.encrypt(jax.random.key(13), gsk, msg, p.glwe_std)
    for bit in (0, 1):
        gg = ggsw.encrypt_bit(
            jax.random.key(14 + bit), gsk, jnp.asarray(bit, dtype=U64),
            p.pbs_base_log, p.pbs_level, p.glwe_std,
        )
        out = ggsw.external_product_fourier(
            fft.forward(gg), ct, p.pbs_base_log, p.pbs_level
        )
        ph = glwe.decrypt_phase(gsk, out)
        got = torus.decode(ph, p.delta, p.plaintext_modulus)
        want = (bit * np.asarray(torus.decode(msg, p.delta, p.plaintext_modulus))) % p.plaintext_modulus
        np.testing.assert_array_equal(np.asarray(got), want)


def test_keyswitch():
    p = TEST_PARAMS
    k1, k2, k3, k4 = jax.random.split(jax.random.key(20), 4)
    sk_big = lwe.keygen(k1, p.big_n)
    sk_small = lwe.keygen(k2, p.n)
    ksk = lwe.ksk_gen(k3, sk_big, sk_small, p.ks_base_log, p.ks_level, p.lwe_std)
    msgs = jnp.arange(p.plaintext_modulus, dtype=U64)
    ct = lwe.encrypt(k4, sk_big, torus.encode(msgs, p.delta), p.lwe_std)
    out = lwe.keyswitch(ct, ksk, p.ks_base_log, p.ks_level)
    got = torus.decode(lwe.decrypt_phase(sk_small, out), p.delta, p.plaintext_modulus)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msgs))


def _pbs_identity(ctx):
    mod = ctx.params.plaintext_modulus
    table = list(range(mod))
    for m in range(mod):
        ct = ctx.encrypt(jax.random.key(100 + m), m)
        out = ctx.lut(ct, table)
        assert int(ctx.decrypt(out)) == m, f"PBS identity failed at m={m}"


def test_pbs_identity_all_messages(ctx_2bit):
    _pbs_identity(ctx_2bit)


def test_pbs_identity_all_messages_k2():
    # k=2 stays locally created: tiny params, no session fixture for it
    _pbs_identity(TFHEContext.create(jax.random.key(30), TEST_PARAMS_K2))


def test_pbs_nontrivial_lut_and_noise_refresh(ctx_4bit):
    ctx = ctx_4bit
    params = ctx.params
    mod = params.plaintext_modulus
    relu_shift = [max(0, m - 8) for m in range(mod)]  # ReLU(m-8) as in Fig. 2
    for m in [0, 3, 7, 8, 9, 15]:
        ct = ctx.encrypt(jax.random.key(200 + m), m)
        out = ctx.lut(ct, relu_shift)
        assert int(ctx.decrypt(out)) == max(0, m - 8)
        # bootstrapping refreshes noise: output noise well under half a slot
        n = abs(float(ctx.decrypt_noise(out, max(0, m - 8))))
        assert n < 1.0 / (2 ** (params.width + 2))


def test_pbs_chain_depth(ctx_2bit):
    """Repeated PBS keeps working: noise does not accumulate across ops."""
    ctx = ctx_2bit
    params = ctx.params
    inc = [(m + 1) % params.plaintext_modulus for m in range(params.plaintext_modulus)]
    ct = ctx.encrypt(jax.random.key(33), 0)
    for i in range(4):
        ct = ctx.lut(ct, inc)
        assert int(ctx.decrypt(ct)) == (i + 1) % params.plaintext_modulus


def test_decompose_recompose_exact_identity():
    """When the gadget covers the full 64-bit word (base_log*level == 64),
    recompose o decompose is the IDENTITY, not just an approximation."""
    v = jax.random.bits(jax.random.key(77), (512,), dtype=U64)
    for bl, lv in [(4, 16), (8, 8), (16, 4), (32, 2)]:
        d = dec.decompose(v, bl, lv)
        r = dec.recompose(d, bl, lv)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(v))


def test_rotate_identity_and_extremes():
    """glwe.rotate edge cases: r=0 is the identity; r=2N-1 multiplies by
    X^{-1} (coefficients shift down, the wrapped one negated); r=N is
    global negation.  Checked on a full (k+1, N) GLWE layout."""
    N = 32
    rng = np.random.default_rng(3)
    ct = jnp.asarray(rng.integers(0, 1 << 64, (2, N), dtype=np.uint64))
    np.testing.assert_array_equal(
        np.asarray(glwe.rotate(ct, jnp.asarray(0), N)), np.asarray(ct))
    got = np.asarray(glwe.rotate(ct, jnp.asarray(2 * N - 1), N))
    want = np.empty_like(np.asarray(ct))
    want[:, : N - 1] = np.asarray(ct)[:, 1:]            # c_{j+1} -> slot j
    want[:, N - 1] = (-np.asarray(ct)[:, :1].astype(np.int64)
                      ).astype(np.uint64).ravel()       # -c_0 wraps to top
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        np.asarray(glwe.rotate(ct, jnp.asarray(N), N)),
        (-np.asarray(ct).astype(np.int64)).astype(np.uint64))
