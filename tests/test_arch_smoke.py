"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU; asserts output shapes and no NaNs (deliverable f)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.models import build

ARCH_MODULES = [
    "pixtral_12b", "gemma_7b", "starcoder2_15b", "deepseek_coder_33b",
    "qwen3_0_6b", "recurrentgemma_2b", "qwen2_moe_a2_7b",
    "moonshot_v1_16b_a3b", "mamba2_130m", "musicgen_large",
]


def _reduced(mod_name):
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.frontend != "none":
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_forward_and_loss(mod_name):
    cfg = _reduced(mod_name)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h, aux = model.forward(params, batch["tokens"], batch.get("frontend"))
    assert h.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))
    loss = model.loss(params, batch, loss_chunk=16)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.slow
@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_train_step_reduces_loss(mod_name):
    cfg = _reduced(mod_name)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss_fn = lambda p: model.loss(p, batch, loss_chunk=16)
    l0, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    # plain SGD step must reduce loss on the same batch
    lr = 0.5 / max(float(gnorm), 1.0)
    new_params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    l1 = loss_fn(new_params)
    assert float(l1) < float(l0)


@pytest.mark.slow
@pytest.mark.parametrize("mod_name", ARCH_MODULES)
def test_decode_matches_prefill(mod_name):
    """Greedy decode-step logits must match the teacher-forced forward."""
    cfg = _reduced(mod_name)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # teacher-forced full forward (no frontend for decode parity test)
    h, _ = model.forward(params, toks)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ref_logits = h[:, -1].astype(jnp.float32) @ head.astype(jnp.float32)

    cache = model.init_cache(B, max_len=S)
    logits = None
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1], pos)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits), rtol=2e-3, atol=2e-3)


def test_full_configs_registered():
    import repro.configs as C
    assert len(C.ARCH_IDS) == 10
    for name in C.ARCH_IDS:
        cfg = C.get(name)
        cfg_shapes = C.applicable_shapes(cfg)
        assert "train_4k" in cfg_shapes
        if name in ("mamba2-130m", "recurrentgemma-2b"):
            assert "long_500k" in cfg_shapes
        else:
            assert "long_500k" not in cfg_shapes


def test_param_counts_plausible():
    import repro.configs as C
    expect = {  # sizes implied by the ASSIGNMENT configs (±40%); moonshot's
        # 48L x 64e config is ~29B total (A3B refers to ACTIVE params —
        # checked separately below)
        "gemma-7b": 8.5e9, "starcoder2-15b": 16e9, "deepseek-coder-33b": 33e9,
        "qwen3-0.6b": 0.6e9, "mamba2-130m": 0.13e9, "pixtral-12b": 12e9,
        "qwen2-moe-a2.7b": 14.3e9, "moonshot-v1-16b-a3b": 29e9,
        "musicgen-large": 2.4e9, "recurrentgemma-2b": 2.7e9,
    }
    for name, target in expect.items():
        n = C.get(name).param_count()
        assert 0.5 * target < n < 1.6 * target, (name, n, target)
    # MoE active-param sanity (the AxB naming)
    assert C.get("qwen2-moe-a2.7b").active_param_count() < 3.5e9
    assert C.get("moonshot-v1-16b-a3b").active_param_count() < 6e9
