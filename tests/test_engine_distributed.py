"""Distributed engine: clusters == mesh devices (subprocess: 4 fake devices).

Run in a subprocess so the forced device count never leaks into the rest
of the test session (dry-run contract: only dryrun.py sees >1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import glwe
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS
    from repro.core.pbs import TFHEContext

    assert jax.device_count() == 4
    mesh = jax.make_mesh((4,), ("data",))
    ctx = TFHEContext.create(jax.random.key(50), TEST_PARAMS)
    eng = TaurusEngine.from_context(ctx, mesh=mesh)
    assert eng.n_clusters == 4 and eng.batch_size == 48  # paper: 4x12

    mod = ctx.params.plaintext_modulus
    msgs = jnp.arange(8, dtype=jnp.uint64) % mod
    cts = jax.vmap(lambda k, m: ctx.encrypt(k, m))(
        jax.random.split(jax.random.key(51), 8), msgs
    )
    table = [(3 * m + 2) % mod for m in range(mod)]
    poly = glwe.make_lut_poly(jnp.asarray(table, dtype=jnp.uint64), ctx.params)
    out = eng.lut_batch(cts, jnp.broadcast_to(poly, (8,) + poly.shape))
    got = np.asarray(jax.vmap(ctx.decrypt)(out))
    want = np.array([table[int(m)] for m in np.asarray(msgs)], dtype=np.uint64)
    np.testing.assert_array_equal(got, want)
    print("DISTRIBUTED_OK")
    """
)


@pytest.mark.slow
def test_engine_on_4_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DISTRIBUTED_OK" in r.stdout
