"""End-to-end encrypted ML: the FHE executor must match the plaintext
integer oracle bit-exactly, with both compiler optimizations live."""
import numpy as np
import pytest

import jax

from repro.api import Session
from repro.compiler.ir import trace
from repro.core.integer import RadixCiphertext
from repro.fhe_ml import lower, executor
from repro.fhe_ml.quantize import (QuantSpec, RadixQuantSpec, calibrate,
                                   calibrate_radix, check_radix_range,
                                   dequantize, dequantize_radix,
                                   quantize_affine, quantize_to_radix)


@pytest.fixture()
def ctx(ctx_6bit):
    # session-scoped keygen (tests/conftest.py); params stay TEST_PARAMS_6BIT
    return ctx_6bit


def _run_both(ctx, g, inputs, **kw):
    ref = executor.interpret(g, inputs, ctx.params.width)
    ex = executor.FheExecutor(ctx, **kw)
    enc = ex.encrypt_inputs(jax.random.PRNGKey(7), inputs)
    out = ex.run(g, enc)
    return ref, out, ex


def test_fanout_ks_dedup(ctx):
    """Two LUTs on one tensor: 1 key-switch, 2 blind rotations; results
    bit-exact vs the oracle (Observation 6 in the real engine)."""
    w = ctx.params.width
    t1 = np.arange(1 << w, dtype=np.uint64)[::-1].copy()
    t2 = (np.arange(1 << w, dtype=np.uint64) * 3) % (1 << w)

    def f(x):
        return x.lut(t1, name="a"), x.lut(t2, name="b")
    g = trace(f, (5,))
    inputs = [np.array([1, 9, 22, 40, 63])]
    ref, out, ex = _run_both(ctx, g, inputs)
    for oid in g.outputs:
        np.testing.assert_array_equal(ex.decrypt(out[oid]), ref[oid])
    assert ex.stats["pbs"] == 10
    assert ex.stats["keyswitch"] == 5          # deduped (would be 10)

    _, out2, ex2 = _run_both(ctx, g, inputs, ks_dedup=False)
    assert ex2.stats["keyswitch"] == 10
    for oid in g.outputs:
        np.testing.assert_array_equal(ex2.decrypt(out2[oid]), ref[oid])


def test_acc_dedup_shares_lut_polys(ctx):
    w = ctx.params.width
    t = (np.arange(1 << w, dtype=np.uint64) + 5) % (1 << w)

    def f(x, y):
        return x.lut(t), y.lut(t)
    g = trace(f, (3,), (3,))
    inputs = [np.array([0, 1, 2]), np.array([3, 4, 5])]
    ref, out, ex = _run_both(ctx, g, inputs)
    assert ex.stats["lut_polys"] == 1          # one accumulator image
    for oid in g.outputs:
        np.testing.assert_array_equal(ex.decrypt(out[oid]), ref[oid])


def test_quantize_roundtrip():
    x = np.linspace(-1.5, 2.5, 64)
    spec = calibrate(x, 6)
    q = quantize_affine(x, spec)
    err = np.abs(dequantize(q, spec) - x)
    assert float(err.max()) <= spec.scale * 0.51


@pytest.mark.slow
def test_encrypted_mlp_matches_oracle(ctx):
    rng = np.random.default_rng(0)
    d_in, d_h = 4, 6
    w1 = rng.normal(size=(d_in, d_h)) * 0.5
    w2 = rng.normal(size=(d_h, d_in)) * 0.5
    xf = rng.uniform(0, 1, size=(d_in,))
    in_spec = calibrate(xf, 3)                 # narrow input: headroom
    q = quantize_affine(xf, in_spec)

    g, meta = lower.lower_mlp(w1, w2, in_spec, ctx.params.width)
    ref, out, ex = _run_both(ctx, g, [q])
    got = ex.decrypt(out[g.outputs[0]])
    np.testing.assert_array_equal(got, ref[g.outputs[0]])

    # quantized pipeline approximates the float MLP direction
    f_ref = lower._gelu((xf - in_spec.zero * 0 + 0) @ 0 + 0) if False else None
    assert ex.stats["pbs"] == d_h + d_in


# --- quantize-to-radix bridge (ISSUE 4) --------------------------------------

BITS = 8
MOD = 1 << BITS


def _mlp_radix_setup():
    """Small MLP on 8-bit radix activations (smoke-lane sized)."""
    rng = np.random.default_rng(0)
    w1 = rng.normal(size=(2, 3)) * 0.5
    w2 = rng.normal(size=(3, 2)) * 0.5
    g, meta = lower.lower_mlp_radix(w1, w2, bits=BITS, msg_bits=2)
    xf = rng.uniform(-1, 1, size=(2,))
    rq = calibrate_radix(xf, BITS, 2, qmax=meta["input_qmax"])
    return g, meta, xf, rq


def test_radix_quantize_roundtrip():
    x = np.linspace(-2.0, 1.5, 33)
    rq = calibrate_radix(x, 16, 2)
    q = quantize_to_radix(x, rq)
    assert int(np.abs(q).max()) <= rq.qmax
    err = np.abs(dequantize_radix(q, rq) - x)
    assert float(err.max()) <= rq.scale * 0.51
    # two's-complement decode: signed ints and their mod-2^bits residues
    # (what decryption returns) dequantize identically
    np.testing.assert_allclose(dequantize_radix(q % rq.modulus, rq),
                               dequantize_radix(q, rq))


def test_radix_quantize_saturates_at_calibrated_cap():
    """Out-of-calibration inputs clip to the certified magnitude, not
    the full two's-complement range — otherwise a large serving-time
    activation would silently void the lowering's overflow certificate."""
    rq = calibrate_radix(np.array([0.5, 1.0]), 8, 2, qmax=20)
    assert rq.qmax_cal == 20 and rq.clip_max == 20
    q = quantize_to_radix(np.array([4.0, -4.0]), rq)   # 4x calibration max
    np.testing.assert_array_equal(q, [20, -20])


def test_radix_range_check():
    check_radix_range(8, 127.0)
    with pytest.raises(OverflowError):
        check_radix_range(8, 128.0)
    # a hopeless lowering: 64-wide dense layers cannot fit 8-bit ints
    with pytest.raises(OverflowError):
        lower.lower_mlp_radix(np.ones((64, 64)), np.ones((64, 64)),
                              bits=8, msg_bits=2)


def test_radix_linear_oracle_matches_numpy():
    """`radix_linear` integer semantics in the keyless oracle: matmul
    mod 2^bits, including negative weights (base complement)."""
    rng = np.random.default_rng(5)
    W = rng.integers(-2, 3, (3, 4))
    g = trace(lambda x: x.radix_linear(W, 2), (3, 4))

    def digits(v):
        return [(int(v) % MOD) >> (2 * i) & 3 for i in range(4)]

    xs = np.array([17, -30, 5])
    inp = np.concatenate([digits(v) for v in xs])
    out = executor.interpret(g, [inp], 4)[g.outputs[0]].reshape(-1, 4)
    got = [sum(int(dd) << (2 * i) for i, dd in enumerate(vec))
           for vec in out]
    np.testing.assert_array_equal(got, (xs @ W) % MOD)


def test_radix_linear_heavy_weights_encrypted(ctx_4bit, engine_4bit):
    """Regression: weight magnitudes >= 4 under the 4-bit window force
    the carry-save compression into solo extractions of the largest
    term (no pair fits); previously this spun until the convergence
    guard fired.  Encrypted result must still match numpy mod 2^bits."""
    from repro.api import IntSpec
    W = np.array([[4, -4], [3, 5], [-2, 1]])
    g = trace(lambda x: x.radix_linear(W, 2), (3, 4))
    xs = np.array([17, -30, 5])
    with Session(ctx_4bit, engine_4bit, backend="eager") as sess:
        prog = sess.compile(g, [IntSpec(BITS, 2, (3,))],
                            [IntSpec(BITS, 2, (2,))])
        got = np.asarray(sess(prog, jax.random.key(7), xs)[0])
    np.testing.assert_array_equal(got % MOD, (xs @ W) % MOD)


@pytest.mark.parametrize("backend", ["eager", "serve"])
def test_quantize_to_radix_mlp_roundtrip(ctx_4bit, engine_4bit, backend):
    """The quantize-to-radix acceptance: quantize -> encrypt -> radix
    linear/activation -> decrypt -> dequantize matches the float oracle
    within the quantization tolerance, on the eager backend AND through
    the multi-tenant ServeRuntime, with a noise-budget assertion on the
    output digits.  Smoke-lane sized (8-bit, 2x3x2 MLP)."""
    g, meta, xf, rq = _mlp_radix_setup()
    q = quantize_to_radix(xf, rq)
    want_ints = meta["int_fn"](q) % MOD
    with Session(ctx_4bit, engine_4bit, backend=backend) as sess:
        prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
        enc = sess.encrypt_inputs(jax.random.key(7), [q], prog)
        out_cts = sess.run(prog, enc)
        got = np.asarray(sess.decrypt_outputs(prog, out_cts)[0])
        # noise budget: every output digit's residual sits well below
        # half a plaintext slot (the propagation PBS refreshed it)
        spec = sess.int_ctx.spec(BITS, 2)
        vecs = out_cts[0].reshape(-1, spec.n_digits, out_cts[0].shape[-1])
        budget = 1.0 / 2 ** (ctx_4bit.params.width + 2)
        for vec, w in zip(vecs, want_ints):
            noise = sess.int_ctx.digit_noise(
                RadixCiphertext(spec, vec), int(w))
            assert float(np.max(np.abs(noise))) < budget
    # bit-exact integer pipeline...
    np.testing.assert_array_equal(got % MOD, want_ints)
    # ...and the dequantized floats approximate the float model within
    # the input-quantization error bound
    out_rq = RadixQuantSpec(BITS, 2, rq.scale * meta["out_scale_mul"])
    yhat = dequantize_radix(got, out_rq)
    assert np.all(np.abs(yhat - meta["float_fn"](xf)) <= meta["tol_fn"](rq))


@pytest.mark.slow
def test_encrypted_gpt2_radix_block_serve_matches_eager(ctx_4bit,
                                                        engine_4bit):
    """ISSUE 4 acceptance: a quantized-to-radix GPT-2-style block (ct*ct
    attention, ReLU MLP, 16-bit activations) submitted through
    Session(backend='serve') decrypts to the same values as the eager
    backend, and both match the exact integer oracle."""
    g, meta = lower.lower_gpt2_block_radix(2, bits=16, msg_bits=2, seed=1)
    rng = np.random.default_rng(3)
    xf = rng.uniform(-1, 1, size=(2,))
    rq = calibrate_radix(xf, 16, 2, qmax=meta["input_qmax"])
    q = quantize_to_radix(xf, rq)
    want = meta["int_fn"](q) % (1 << 16)
    outs = {}
    for backend in ("eager", "serve"):
        with Session(ctx_4bit, engine_4bit, backend=backend) as sess:
            prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
            outs[backend] = np.asarray(
                sess(prog, jax.random.key(7), q)[0])
    np.testing.assert_array_equal(outs["eager"] % (1 << 16), want)
    np.testing.assert_array_equal(outs["eager"], outs["serve"])


@pytest.mark.slow
def test_encrypted_gpt2_block_matches_oracle(ctx):
    """The paper's flagship demo at laptop scale: a quantized single-head
    GPT-2-style block (ct*ct attention, GELU MLP) runs under real TFHE
    and matches the integer oracle exactly."""
    d = 4
    rng = np.random.default_rng(3)
    in_spec = QuantSpec(3, 0.25, 4)
    q = rng.integers(0, 8, (d,))
    g, meta = lower.lower_gpt2_block(d, in_spec, ctx.params.width, seed=1)
    ref, out, ex = _run_both(ctx, g, [q])
    got = ex.decrypt(out[g.outputs[0]])
    np.testing.assert_array_equal(got, ref[g.outputs[0]])
    assert ex.stats["pbs"] > 20                # it really bootstrapped
