"""End-to-end encrypted ML: the FHE executor must match the plaintext
integer oracle bit-exactly, with both compiler optimizations live."""
import numpy as np
import pytest

import jax

from repro.compiler.ir import trace
from repro.fhe_ml import lower, executor
from repro.fhe_ml.quantize import QuantSpec, calibrate, quantize_affine, dequantize


@pytest.fixture()
def ctx(ctx_6bit):
    # session-scoped keygen (tests/conftest.py); params stay TEST_PARAMS_6BIT
    return ctx_6bit


def _run_both(ctx, g, inputs, **kw):
    ref = executor.interpret(g, inputs, ctx.params.width)
    ex = executor.FheExecutor(ctx, **kw)
    enc = ex.encrypt_inputs(jax.random.PRNGKey(7), inputs)
    out = ex.run(g, enc)
    return ref, out, ex


def test_fanout_ks_dedup(ctx):
    """Two LUTs on one tensor: 1 key-switch, 2 blind rotations; results
    bit-exact vs the oracle (Observation 6 in the real engine)."""
    w = ctx.params.width
    t1 = np.arange(1 << w, dtype=np.uint64)[::-1].copy()
    t2 = (np.arange(1 << w, dtype=np.uint64) * 3) % (1 << w)

    def f(x):
        return x.lut(t1, name="a"), x.lut(t2, name="b")
    g = trace(f, (5,))
    inputs = [np.array([1, 9, 22, 40, 63])]
    ref, out, ex = _run_both(ctx, g, inputs)
    for oid in g.outputs:
        np.testing.assert_array_equal(ex.decrypt(out[oid]), ref[oid])
    assert ex.stats["pbs"] == 10
    assert ex.stats["keyswitch"] == 5          # deduped (would be 10)

    _, out2, ex2 = _run_both(ctx, g, inputs, ks_dedup=False)
    assert ex2.stats["keyswitch"] == 10
    for oid in g.outputs:
        np.testing.assert_array_equal(ex2.decrypt(out2[oid]), ref[oid])


def test_acc_dedup_shares_lut_polys(ctx):
    w = ctx.params.width
    t = (np.arange(1 << w, dtype=np.uint64) + 5) % (1 << w)

    def f(x, y):
        return x.lut(t), y.lut(t)
    g = trace(f, (3,), (3,))
    inputs = [np.array([0, 1, 2]), np.array([3, 4, 5])]
    ref, out, ex = _run_both(ctx, g, inputs)
    assert ex.stats["lut_polys"] == 1          # one accumulator image
    for oid in g.outputs:
        np.testing.assert_array_equal(ex.decrypt(out[oid]), ref[oid])


def test_quantize_roundtrip():
    x = np.linspace(-1.5, 2.5, 64)
    spec = calibrate(x, 6)
    q = quantize_affine(x, spec)
    err = np.abs(dequantize(q, spec) - x)
    assert float(err.max()) <= spec.scale * 0.51


@pytest.mark.slow
def test_encrypted_mlp_matches_oracle(ctx):
    rng = np.random.default_rng(0)
    d_in, d_h = 4, 6
    w1 = rng.normal(size=(d_in, d_h)) * 0.5
    w2 = rng.normal(size=(d_h, d_in)) * 0.5
    xf = rng.uniform(0, 1, size=(d_in,))
    in_spec = calibrate(xf, 3)                 # narrow input: headroom
    q = quantize_affine(xf, in_spec)

    g, meta = lower.lower_mlp(w1, w2, in_spec, ctx.params.width)
    ref, out, ex = _run_both(ctx, g, [q])
    got = ex.decrypt(out[g.outputs[0]])
    np.testing.assert_array_equal(got, ref[g.outputs[0]])

    # quantized pipeline approximates the float MLP direction
    f_ref = lower._gelu((xf - in_spec.zero * 0 + 0) @ 0 + 0) if False else None
    assert ex.stats["pbs"] == d_h + d_in


@pytest.mark.slow
def test_encrypted_gpt2_block_matches_oracle(ctx):
    """The paper's flagship demo at laptop scale: a quantized single-head
    GPT-2-style block (ct*ct attention, GELU MLP) runs under real TFHE
    and matches the integer oracle exactly."""
    d = 4
    rng = np.random.default_rng(3)
    in_spec = QuantSpec(3, 0.25, 4)
    q = rng.integers(0, 8, (d,))
    g, meta = lower.lower_gpt2_block(d, in_spec, ctx.params.width, seed=1)
    ref, out, ex = _run_both(ctx, g, [q])
    got = ex.decrypt(out[g.outputs[0]])
    np.testing.assert_array_equal(got, ref[g.outputs[0]])
    assert ex.stats["pbs"] > 20                # it really bootstrapped
