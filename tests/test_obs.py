"""repro.obs: metrics primitives, span tracing + Chrome export,
bandwidth ledger, serve-stack integration (concurrent-burst metric
consistency, per-output futures, stats-view compatibility), the
benchmark harness's exit-code contract, and the telemetry-off
overhead guard.

Key material comes from the session-scoped fixtures in conftest.py;
queue-level tests use linear-only (PBS-free) programs, and the one
PBS-heavy integration test shares a single small fused wave.
"""
import json
import sys
import threading
import time
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

import jax

from repro.compiler.ir import trace
from repro.core.integer import IntegerContext
from repro.obs import (BandwidthLedger, Histogram, MetricsRegistry,
                       StatsView, Telemetry, engine_key_bytes,
                       validate_chrome_trace)
from repro.runtime.fault import FaultConfig
from repro.serve import (ServeRuntime, decrypt_radix_output,
                         encrypt_request_inputs, radix_binop_program)

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

BITS = 8


@pytest.fixture()
def ic4(ctx_4bit, engine_4bit):
    return IntegerContext.create(ctx_4bit, engine_4bit)


def _linear_graph(const):
    return trace(lambda x: x + np.array([const]), (1,))


# --- metrics primitives ------------------------------------------------------

def test_registry_counters_gauges_histograms_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("requests")
    assert reg.counter("requests") is c            # get-or-create
    c.inc()
    c.inc(4)
    reg.gauge("depth").set(7)
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"] == {"requests": 5}
    assert snap["gauges"] == {"depth": 7.0}
    s = snap["histograms"]["lat"]
    assert s["count"] == 4 and s["sum"] == 10.0 and s["mean"] == 2.5
    assert s["min"] == 1.0 and s["max"] == 4.0 and s["p50"] == 3.0


def test_counter_concurrent_increments_exact():
    reg = MetricsRegistry()
    c = reg.counter("n")

    def worker():
        for _ in range(5_000):
            c.inc()

    ts = [threading.Thread(target=worker) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == 40_000


def test_histogram_reservoir_past_cap_stays_calibrated():
    """count/sum/min/max are exact past the reservoir cap, and the
    sketch's quantiles track a known distribution (seeded RNG: exact
    reproducibility, no flake tolerance needed)."""
    h = Histogram("lat", max_samples=512)
    n = 10_000
    for i in range(n):
        h.observe(i / n)                    # uniform [0, 1)
    assert h.count == n
    assert h.total == pytest.approx(sum(i / n for i in range(n)))
    assert h.min == 0.0 and h.max == (n - 1) / n
    assert len(h._samples) == 512           # bounded memory
    assert h.quantile(0.50) == pytest.approx(0.5, abs=0.08)
    assert h.quantile(0.99) == pytest.approx(0.99, abs=0.08)


def test_stats_view_is_readonly_live_mapping():
    reg = MetricsRegistry()
    c = reg.counter("done")
    log = [("a", 0)]
    view = StatsView({"done": c, "rate": lambda: 0.5, "admitted": log})
    assert view["done"] == 0
    c.inc(3)
    assert view["done"] == 3                # live, not a copy
    assert view["rate"] == 0.5              # callables evaluated
    assert view["admitted"] is log          # logs pass through
    assert dict(view.as_dict()) == {"done": 3, "rate": 0.5, "admitted": log}
    with pytest.raises(TypeError):
        view["done"] = 9                    # Mapping, not MutableMapping


def test_telemetry_defaults_and_disabled():
    tel = Telemetry()                       # serve default: metrics only
    assert not tel.tracing
    tel.counter("c").inc()
    with tel.span("s", cat="t"):
        pass
    assert tel.snapshot()["counters"] == {"c": 1}
    assert tel.chrome_trace()["traceEvents"] == []   # tracing off

    off = Telemetry.disabled()
    off.counter("c").inc(100)
    off.histogram("h").observe(1.0)
    off.bandwidth.account_round(participants=2, rows_logical=1,
                                rows_dispatched=1, rows_padded=0,
                                bsk_bytes=10, ksk_bytes=10)
    snap = off.snapshot()
    assert snap["counters"] == {} and snap["bandwidth"] == {}


# --- span tracing + Chrome export -------------------------------------------

def test_trace_recorder_spans_instants_backfill_roundtrip(tmp_path):
    tel = Telemetry(trace=True)
    t0 = time.perf_counter()
    with tel.span("request", cat="serve", request=0) as sp:
        tel.instant("submit", cat="serve", request=0)
        with tel.span("pbs_round", cat="sched"):
            time.sleep(0.002)
        sp.set(outcome="completed")         # args discovered mid-span
    tel.record("queue_wait", "serve", t0 - 0.01, 0.005, request=0)

    spans = tel.recorder.spans()
    names = [s.name for s in spans]
    assert sorted(names) == ["pbs_round", "queue_wait", "request"]
    req = next(s for s in spans if s.name == "request")
    rnd = next(s for s in spans if s.name == "pbs_round")
    assert req.args == {"request": 0, "outcome": "completed"}
    assert req.ts <= rnd.ts and rnd.ts + rnd.dur <= req.ts + req.dur

    # exports validate: as an object, as a JSON string, and as a file
    obj = tel.chrome_trace()
    n = validate_chrome_trace(obj)
    assert n == validate_chrome_trace(json.dumps(obj))
    path = tel.write_chrome_trace(str(tmp_path / "t.json"))
    assert validate_chrome_trace(path) == n
    phs = [e["ph"] for e in obj["traceEvents"]]
    assert phs.count("X") == 3 and phs.count("i") == 1 and "M" in phs


def test_validate_chrome_trace_rejects_partial_overlap():
    def ev(name, ts, dur):
        return {"name": name, "ph": "X", "pid": 1, "tid": 0,
                "ts": ts, "dur": dur}

    ok = {"traceEvents": [ev("a", 0, 10), ev("b", 2, 5)]}       # nested
    assert validate_chrome_trace(ok) == 2
    bad = {"traceEvents": [ev("a", 0, 10), ev("b", 5, 10)]}     # partial
    with pytest.raises(ValueError, match="partially"):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace({"traceEvents": [{"name": "x", "ph": "i"}]})


# --- bandwidth ledger --------------------------------------------------------

def test_bandwidth_ledger_counterfactual_math():
    led = BandwidthLedger()
    led.account_round(participants=4, rows_logical=16, rows_dispatched=12,
                      rows_padded=4, bsk_bytes=1000, ksk_bytes=100)
    led.account_round(participants=1, rows_logical=4, rows_dispatched=4,
                      rows_padded=0, bsk_bytes=1000, ksk_bytes=100)
    snap = led.snapshot()
    # each round streams the keys once; unfused would stream them
    # participants-many times — saved = sum (participants-1) * bytes
    assert snap["bsk_bytes_streamed"] == 2_000
    assert snap["bsk_bytes_unfused"] == 5_000
    assert snap["bsk_bytes_saved"] == 3_000 == led.bsk_bytes_saved
    assert snap["ksk_bytes_saved"] == 300
    assert snap["rows_deduped"] == 4        # dedup is rows, not key bytes
    assert snap["rows_padded"] == 4 and snap["fused_rounds"] == 2


# --- serve-stack integration -------------------------------------------------

def test_concurrent_burst_metrics_consistent(ctx_2bit, engine_2bit):
    """Multi-client burst with queueing and a poisoned client: every
    accounting surface must agree — spans vs counters vs histograms vs
    the stats view — and the trace must round-trip valid."""
    def chaos(request, attempt):
        if request.client_id == "poison":
            raise RuntimeError("poisoned request")

    tel = Telemetry(trace=True)
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, max_inflight=4,
                      fault=FaultConfig(max_retries=1), fault_hook=chaos,
                      start_paused=True, telemetry=tel)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(8), np.array([1]))
    handles = []
    for i in range(12):                     # 4 clients x 3 requests
        handles.append(rt.submit(g, [x], client_id=f"c{i % 4}"))
    bad = [rt.submit(g, [x], client_id="poison") for _ in range(2)]
    rt.resume()
    rt.close()
    n_total = len(handles) + len(bad)

    snap = rt.metrics()
    c = snap["counters"]
    assert c["serve.admitted"] == n_total
    assert c["serve.completed"] + c["serve.failed"] == n_total
    assert c["serve.completed"] == len(handles)
    assert c["serve.failed"] == len(bad)
    assert c["serve.retries"] == len(bad)   # max_retries=1 -> 1 re-run each
    assert snap["histograms"]["serve.request_latency_s"]["count"] == n_total
    assert snap["histograms"]["serve.queue_wait_s"]["count"] == n_total
    assert snap["histograms"]["serve.queue_depth"]["max"] >= 4

    # the backward-compatible stats view reads the same registry
    assert rt.stats["completed"] == c["serve.completed"]
    assert rt.stats["failed"] == c["serve.failed"]
    assert len(rt.stats["admitted"]) == n_total

    # spans: one "request" span per admission, outcomes match counters
    events = tel.recorder.events()
    req_spans = [e for e in events if e.name == "request"]
    assert len(req_spans) == n_total
    outcomes = [e.args["outcome"] for e in req_spans]
    assert outcomes.count("completed") == c["serve.completed"]
    assert outcomes.count("failed") == c["serve.failed"]
    assert len([e for e in events if e.name == "submit"]) == n_total
    assert len([e for e in events if e.name == "queue_wait"]) == n_total
    retry_marks = [e for e in events if e.name == "retry"]
    assert len(retry_marks) == c["serve.retries"]

    # the trace round-trips through the Chrome exporter as valid JSON
    # with correctly nested spans on every lane
    assert validate_chrome_trace(json.dumps(tel.chrome_trace())) > 0

    for h in handles:
        assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 2


def test_output_futures_resolve_and_fail(ctx_2bit, engine_2bit):
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(9), np.array([2]))
    h = rt.submit(g, [x], client_id="A")
    (fut,) = h.output_futures
    out = fut.wait(timeout=30)              # per-output completion handle
    assert fut.done() and fut.error is None
    assert int(ctx_2bit.decrypt(out[0])) == 3
    h.wait(timeout=30)
    # the future resolved during execution, not after the request closed
    assert fut.completed_at <= h.completed_at
    assert h.submitted_at <= h.admitted_at <= fut.completed_at
    # same ciphertext the handle-level API returns
    assert out is h.outputs()[0]

    def boom(request, attempt):
        raise RuntimeError("poisoned request")

    rt2 = ServeRuntime(ctx_2bit, engine_2bit, fused=False,
                       fault=FaultConfig(max_retries=1), fault_hook=boom)
    h2 = rt2.submit(g, [x], client_id="B")
    (fut2,) = h2.output_futures
    with pytest.raises(RuntimeError, match="poisoned"):
        fut2.wait(timeout=30)               # unresolved futures fail
    assert fut2.done() and fut2.completed_at is None
    rt.close()
    rt2.close()


def test_fused_wave_publishes_scheduler_and_bandwidth(ctx_4bit, engine_4bit,
                                                      ic4):
    """One small fused radix wave: scheduler counters agree between the
    stats view and the snapshot, pbs_round spans carry fused batch ids,
    and the bandwidth ledger's totals reconcile with the engine's actual
    key-material sizes."""
    m = ic4.spec(BITS).msg_bits
    g = radix_binop_program("radix_add", BITS, m)
    jobs = []
    for i, (a, b) in enumerate([(17, 201), (90, 90)]):
        enc = encrypt_request_inputs(ic4, jax.random.key(60 + i),
                                     [a, b], BITS)
        jobs.append((f"c{i}", enc, (a + b) % 256))
    jobs.append(("c2", jobs[0][1], jobs[0][2]))   # replayed ciphertexts
    tel = Telemetry(trace=True)
    rt = ServeRuntime(ctx_4bit, engine_4bit, max_inflight=len(jobs),
                      start_paused=True, telemetry=tel)
    handles = [rt.submit(g, enc, client_id=c) for c, enc, _ in jobs]
    rt.resume()
    rt.close()
    for h, (_, _, want) in zip(handles, jobs):
        assert decrypt_radix_output(ic4, h.outputs()[0], BITS)[0] == want

    snap = rt.metrics()
    c = snap["counters"]
    sv = rt.scheduler.stats
    for key in ("fused_rounds", "logical_luts", "dispatched_luts",
                "padded_luts", "dedup_hits"):
        assert sv[key] == c[f"sched.{key}"], key
    assert sv["dedup_hits"] > 0             # jobs[2] replays jobs[0]
    assert c["sched.fused_rounds"] > 0
    assert snap["histograms"]["sched.occupancy"]["count"] \
        == c["sched.fused_rounds"]
    # integer-layer accounting rode the same registry
    assert c["integer.pbs"] == c["sched.logical_luts"]

    # bandwidth: streamed == rounds * key bytes, unfused == participants *
    bsk_b, ksk_b = engine_key_bytes(engine_4bit)
    bw = snap["bandwidth"]
    assert bw["bsk_bytes_streamed"] == bw["fused_rounds"] * bsk_b
    assert bw["ksk_bytes_streamed"] == bw["fused_rounds"] * ksk_b
    assert bw["bsk_bytes_unfused"] == bw["participants"] * bsk_b
    assert bw["bsk_bytes_saved"] == bw["bsk_bytes_unfused"] \
        - bw["bsk_bytes_streamed"]
    assert bw["bsk_bytes_saved"] > 0        # every round fused 3 requests
    assert bw["rows_deduped"] == c["sched.dedup_hits"]

    # every pbs_round span landed a fused batch id; the leader's
    # fused_round spans nest inside its own pbs_round barrier wait
    events = tel.recorder.events()
    rounds = [e for e in events if e.name == "pbs_round"]
    assert len(rounds) == 3 * c["sched.fused_rounds"]   # one per request
    assert all(e.args.get("round") is not None for e in rounds)
    fused = [e for e in events if e.name == "fused_round"]
    assert len(fused) == c["sched.fused_rounds"]
    assert all(e.args["participants"] == len(jobs) for e in fused)
    assert validate_chrome_trace(json.dumps(tel.chrome_trace())) > 0


def test_noop_telemetry_overhead_under_5_percent(ctx_2bit, engine_2bit):
    """ISSUE acceptance: disabled telemetry must add <5% wall-clock to a
    fused serve pass.  Measured structurally, not as a timing diff (two
    serve waves on shared CPU differ by more than 5% from noise alone):
    count the telemetry touchpoints an actual wave makes, microbenchmark
    the per-touchpoint cost of the disabled primitives, and bound the
    product against the measured wave time."""
    tel = Telemetry()                       # metrics on, trace off
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, max_inflight=4,
                      start_paused=True, telemetry=tel)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(12), np.array([1]))
    handles = [rt.submit(g, [x], client_id=f"c{i % 4}") for i in range(12)]
    t0 = time.perf_counter()
    rt.resume()
    rt.close()
    wave_s = time.perf_counter() - t0
    for h in handles:
        h.wait(timeout=30)

    snap = rt.metrics()
    # every counter inc, histogram observe, gauge set (2 per submit is an
    # overestimate), span/instant the wave performed
    n_requests = snap["counters"]["serve.admitted"]
    n_ops = (sum(snap["counters"].values())
             + sum(h["count"] for h in snap["histograms"].values())
             + 8 * n_requests)              # spans+instants+gauge, generous

    off = Telemetry.disabled()
    reps = 20_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with off.span("s", cat="t", a=1):
            pass
        off.counter("c").inc()
        off.histogram("h").observe(1.0)
        off.instant("i", cat="t")
    per_op = (time.perf_counter() - t0) / (4 * reps)

    overhead_s = n_ops * per_op
    assert overhead_s < 0.05 * wave_s, (
        f"no-op telemetry cost {overhead_s * 1e3:.2f}ms over {n_ops} "
        f"touchpoints vs wave {wave_s * 1e3:.0f}ms")


# --- benchmark harness exit-code contract ------------------------------------

def _bench_main(argv, mods):
    from benchmarks.run import main
    return main(argv, mods=mods)


def test_bench_run_exits_nonzero_on_failure(tmp_path, capsys):
    ok = SimpleNamespace(run=lambda: [{"bench": "x", "v": 1}])

    def explode():
        raise RuntimeError("bench blew up")

    bad = SimpleNamespace(run=explode)
    rc = _bench_main(["--only", "ok,bad", "--out-dir", str(tmp_path)],
                     {"ok": ok, "bad": bad})
    assert rc == 1                          # a partial run is a red run
    rows = json.loads((tmp_path / "results.json").read_text())
    assert rows == [{"bench": "x", "v": 1}]    # surviving rows kept
    assert "bad" in capsys.readouterr().out
    rc = _bench_main(["--only", "ok", "--out-dir", str(tmp_path)],
                     {"ok": ok, "bad": bad})
    assert rc == 0
    assert _bench_main(["--only", "nope"], {"ok": ok}) == 2


def test_bench_dry_run_checks_obs_columns():
    scaling = ("shards", "clients", "requests_per_s",
               "per_shard_occupancy", "occupancy_ratio")
    good = SimpleNamespace(
        run=lambda: [],
        BENCH_COLUMNS=("p50_s", "p99_s", "bsk_bytes_saved", "extra"),
        SCALING_COLUMNS=scaling)
    assert _bench_main(["--only", "serve", "--dry-run"],
                       {"serve": good}) == 0
    # a serve benchmark that stops declaring the observability columns
    # must fail the dry run (BENCH_serve.json consumers key on them)
    stale = SimpleNamespace(run=lambda: [], BENCH_COLUMNS=("p50_s",),
                            SCALING_COLUMNS=scaling)
    assert _bench_main(["--only", "serve", "--dry-run"],
                       {"serve": stale}) == 1
    # likewise for the shard-sweep scaling row's columns (PR 10)
    noscale = SimpleNamespace(run=lambda: [],
                              BENCH_COLUMNS=good.BENCH_COLUMNS,
                              SCALING_COLUMNS=("shards",))
    assert _bench_main(["--only", "serve", "--dry-run"],
                       {"serve": noscale}) == 1
    norun = SimpleNamespace(BENCH_COLUMNS=good.BENCH_COLUMNS,
                            SCALING_COLUMNS=scaling)
    assert _bench_main(["--only", "serve", "--dry-run"],
                       {"serve": norun}) == 1


def test_bench_dry_run_real_modules_pass():
    """The real harness dry-run (entry points + obs columns + trace
    exporter) stays green — this is what the CI smoke lane executes."""
    from benchmarks.run import main
    assert main(["--dry-run", "--only", "serve,fhe_ml"]) == 0


# --- Snapshot.diff (PR 8 satellite: phase-windowed metric deltas) -----------

def test_snapshot_diff_counters_gauges_and_exact_interval_quantiles():
    reg = MetricsRegistry()
    c = reg.counter("serve.completed")
    g = reg.gauge("serve.queue_depth")
    h = reg.histogram("serve.request_latency_s")
    c.inc(3)
    g.set(5)
    for v in (10.0, 20.0):
        h.observe(v)
    earlier = reg.snapshot()
    c.inc(4)
    g.set(2)
    for v in (30.0, 40.0, 50.0, 60.0):
        h.observe(v)
    later = reg.snapshot()
    delta = later.diff(earlier)
    # counters subtract, gauges report the later value
    assert delta["counters"]["serve.completed"] == 4
    assert delta["gauges"]["serve.queue_depth"] == 2
    # the histogram window covers ONLY the interval's samples, exactly
    hd = delta["histograms"]["serve.request_latency_s"]
    assert hd["count"] == 4 and hd["sum"] == 180.0 and hd["mean"] == 45.0
    assert hd["min"] == 30.0 and hd["max"] == 60.0
    assert hd["p50"] == 50.0 and hd["p99"] == 60.0
    # instruments created after `earlier` diff against zero
    reg.counter("serve.abandoned").inc(2)
    delta2 = reg.snapshot().diff(earlier)
    assert delta2["counters"]["serve.abandoned"] == 2
    # an empty interval has count 0 and None quantiles
    empty = reg.snapshot().diff(reg.snapshot())
    hd0 = empty["histograms"]["serve.request_latency_s"]
    assert hd0["count"] == 0 and hd0["p50"] is None
    # diffs are JSON-clean (what BENCH_sim.json consumers see)
    json.dumps(delta)


def test_snapshot_diff_reservoir_fallback_keeps_exact_counts():
    """Past the sample cap the interval quantiles are no longer exact —
    diff() must degrade to exact count/sum/mean with None quantiles
    rather than report wrong tails."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", 64)
    for v in range(10):
        h.observe(float(v))
    earlier = reg.snapshot()
    for v in range(100):                      # blows past the cap of 64
        h.observe(float(v))
    delta = reg.snapshot().diff(earlier)
    hd = delta["histograms"]["lat"]
    assert hd["count"] == 100
    assert hd["sum"] == float(sum(range(100)))
    assert hd["p50"] is None and hd["p99"] is None


def test_snapshot_diff_bandwidth_and_telemetry_roundtrip():
    tel = Telemetry()
    tel.counter("serve.admitted").inc(2)
    tel.bandwidth.account_round(participants=2, rows_logical=4,
                                rows_dispatched=3, rows_padded=1,
                                bsk_bytes=1000, ksk_bytes=100)
    earlier = tel.snapshot()
    tel.counter("serve.admitted").inc(5)
    tel.bandwidth.account_round(participants=3, rows_logical=6,
                                rows_dispatched=5, rows_padded=0,
                                bsk_bytes=1000, ksk_bytes=100)
    delta = tel.snapshot().diff(earlier)
    assert delta["counters"]["serve.admitted"] == 5
    # bandwidth ledger totals subtract like counters: only the second
    # round's traffic shows in the window
    assert delta["bandwidth"]["fused_rounds"] == 1
    assert delta["bandwidth"]["participants"] == 3
    assert delta["bandwidth"]["rows_dispatched"] == 5
    assert delta["bandwidth"]["bsk_bytes_streamed"] == 1000
    assert delta["bandwidth"]["bsk_bytes_unfused"] == 3000
    json.dumps(delta)
