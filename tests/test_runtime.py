"""Runtime substrate: checkpoint/restart, fault handling, elastic
re-mesh, gradient compression, data pipeline determinism."""
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMData
from repro.runtime import ElasticMesh, FaultConfig, Int8Compressor, StepRunner


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(8.0), "b": [jnp.ones((3, 3)),
                                            jnp.zeros((2,), jnp.int32)]}
        for step in (10, 20, 30):
            mgr.save(step, jax.tree.map(lambda x: x + step, tree))
        assert mgr.latest_step() == 30
        restored, step = mgr.restore(tree)
        assert step == 30
        np.testing.assert_allclose(restored["a"], np.arange(8.0) + 30)
        # GC kept only 2
        dirs = [n for n in os.listdir(d) if n.startswith("step_")]
        assert len(dirs) == 2


def test_checkpoint_atomicity_partial_write_ignored():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"x": jnp.ones(4)}
        mgr.save(5, tree)
        # simulate a crashed write: directory without .done marker
        os.makedirs(os.path.join(d, "step_00000099"))
        assert mgr.latest_step() == 5


def test_step_runner_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return ("ok", {"loss": jnp.asarray(1.0)})

    r = StepRunner(flaky, FaultConfig(max_retries=3))
    out = r.run()
    assert out[0] == "ok"
    assert r.stats["retries"] == 2 and r.stats["failures"] == 2


def test_step_runner_skips_nonfinite():
    r = StepRunner(lambda: ("x", {"loss": jnp.asarray(float("nan"))}))
    assert r.run() is None
    assert r.stats["skipped_nonfinite"] == 1


def test_elastic_reshard_preserves_values():
    from jax.sharding import PartitionSpec as P
    em = ElasticMesh(model_parallel=1)
    full = em.build()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    specs = {"w": P()}
    t_small, small, t_back, _ = em.shrink_then_grow(tree, specs, lost=0)
    np.testing.assert_allclose(t_back["w"], tree["w"])


def test_int8_compression_error_feedback_converges():
    """With EF, the accumulated compressed signal tracks the true sum."""
    comp = Int8Compressor()
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 1e-3
    ef = {"g": jnp.zeros((64,), jnp.float32)}
    acc = jnp.zeros((64,))
    for _ in range(50):
        out, ef_leaf = comp.roundtrip({"g": g_true}, ef)
        ef = ef_leaf
        acc = acc + out["g"]
    np.testing.assert_allclose(acc / 50, g_true, atol=2e-5)


def test_int8_compression_bytes():
    comp = Int8Compressor()
    q, scale, err = comp.compress(jnp.ones((128,)), jnp.zeros((128,)))
    assert q.dtype == jnp.int8            # 4x smaller than f32 on the wire


def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    d1 = SyntheticLMData(cfg)
    d2 = SyntheticLMData(cfg)
    b1 = d1.batch(123)
    b2 = d2.batch(123)          # fresh instance, same step -> same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(124)["tokens"], b1["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


@pytest.mark.slow
def test_train_restart_after_failure():
    """Driver-level fault tolerance: injected failure -> checkpoint
    restore -> run completes."""
    from repro.launch.train import train
    with tempfile.TemporaryDirectory() as d:
        # without any checkpoint on disk an unrecoverable step fails loudly
        with pytest.raises(RuntimeError):
            train("qwen3-0.6b", steps=8, batch=2, seq=32,
                  ckpt_dir=d, reduced=True, log_every=100, fail_at_step=4)
    with tempfile.TemporaryDirectory() as d:
        l1, _ = train("qwen3-0.6b", steps=4, batch=2, seq=32,
                      ckpt_dir=d, reduced=True, log_every=100)
        # phase 2: resume + survive an injected failure (restores the
        # step-4 checkpoint, clears the fault, finishes)
        l2, _ = train("qwen3-0.6b", steps=8, batch=2, seq=32,
                      ckpt_dir=d, reduced=True, log_every=100, resume=True,
                      fail_at_step=6)
        assert len(l2) >= 4                 # resumed from step 4
