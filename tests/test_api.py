"""The `repro.api` front door: one traced program, three backends,
identical plaintexts.

The acceptance contract of the Session API: `session.trace` compiles a
Python function over `EncryptedInt` / `EncryptedTensor` operators into a
`Program`, and `EagerBackend` (direct IntegerContext), `LocalBackend`
(serving IR interpreter) and `ServeBackend` (multi-tenant runtime with
cross- and intra-request round fusion) decrypt to the same values.
"""
import warnings

import numpy as np
import pytest

import jax

from repro.api import (EagerBackend, IntSpec, Program, Session, TensorSpec,
                       trace_program)
from repro.compiler.ir import trace
from repro.fhe_ml.executor import FheExecutor, interpret
from repro.serve import radix_binop_program

BITS = 8
MOD = 1 << BITS


def _mixed_fn(a, b):
    """Covers every traced operator family: add/sub/mul, relu, cmp,
    boolean comparison (cmp verdict + LUT)."""
    s = a + b
    p = (a - b).relu()
    return s, p, a.cmp(b), (a < b)


def _expected(x, y):
    lt = 1 if x < y else 0
    cmpv = 0 if x == y else (1 if x < y else 2)
    sub = (x - y) % MOD
    relu = 0 if sub >= MOD // 2 else sub
    return [(x + y) % MOD, relu, cmpv, lt]


@pytest.mark.parametrize("backend", ["eager", "local", "serve"])
def test_traced_program_identical_on_all_backends(ctx_4bit, engine_4bit,
                                                  backend):
    """ISSUE 3 acceptance: one traced program decrypts to identical
    plaintexts on EagerBackend, LocalBackend and ServeBackend."""
    with Session(ctx_4bit, engine_4bit, backend=backend) as sess:
        prog = sess.trace(_mixed_fn, IntSpec(BITS), IntSpec(BITS))
        x, y = 173, 209
        got = sess(prog, jax.random.key(7), x, y)
    want = _expected(x, y)
    assert got[0] == want[0] and got[1] == want[1]
    assert int(got[2][0]) == want[2] and int(got[3][0]) == want[3]


def test_trace_records_expected_graph(ctx_4bit, engine_4bit):
    sess = Session(ctx_4bit, engine_4bit, backend="eager")
    prog = sess.trace(_mixed_fn, IntSpec(BITS), IntSpec(BITS))
    ops = [n.op for n in prog.graph.nodes]
    assert ops.count("input") == 2
    for op in ("radix_add", "radix_sub", "radix_relu", "radix_cmp"):
        assert op in ops
    assert ops.count("radix_cmp") == 2         # .cmp() and (a < b)
    assert ops.count("lut") == 1               # the verdict-to-bit table
    assert len(prog.out_specs) == 4


def test_traced_program_matches_plaintext_oracle(ctx_4bit, engine_4bit):
    """The interpret() oracle executes radix nodes with integer
    semantics, so traced programs are checkable without keys."""
    sess = Session(ctx_4bit, engine_4bit, backend="eager")
    prog = sess.trace(lambda a, b: ((a + b) * a).relu(),
                      IntSpec(BITS), IntSpec(BITS))
    spec = sess.int_ctx.spec(BITS)
    x, y = 201, 77
    ref = interpret(prog.graph, [spec.to_digits(x), spec.to_digits(y)],
                    ctx_4bit.params.width)
    ref_int = spec.from_digits(ref[prog.graph.outputs[0]])
    got = sess(prog, jax.random.key(3), x, y)[0]
    t = ((x + y) * x) % MOD
    assert got == ref_int == (0 if t >= MOD // 2 else t)


def test_multi_int_specs_encrypt_run_decrypt(ctx_4bit, engine_4bit):
    """IntSpec with a leading shape: a tensor-level radix node over V
    vectors, elementwise semantics, array decrypt."""
    with Session(ctx_4bit, engine_4bit, backend="local") as sess:
        prog = sess.trace(lambda a, b: a + b,
                          IntSpec(BITS, shape=(3,)), IntSpec(BITS, shape=(3,)))
        xs, ys = [7, 200, 255], [13, 99, 1]
        got = sess(prog, jax.random.key(11), xs, ys)[0]
    np.testing.assert_array_equal(got, [(x + y) % MOD for x, y in zip(xs, ys)])


def test_tensor_program_eager_and_local_agree(ctx_2bit, engine_2bit):
    """The EncryptedTensor (fhe_ml value kind) path flows through the
    same Session door and matches the plaintext oracle on both local
    executors."""
    mod = ctx_2bit.params.plaintext_modulus
    table = np.array([(3 * v + 1) % mod for v in range(mod)])

    def prog_fn(x):
        return (x + np.array([1, 0, 1, 0])).lut(table)

    xs = np.array([0, 1, 2, 1])
    outs = {}
    for backend in ("eager", "local"):
        sess = Session(ctx_2bit, engine_2bit, backend=backend)
        prog = sess.trace(prog_fn, TensorSpec((4,)))
        outs[backend] = sess(prog, jax.random.key(5), xs)[0]
        ref = interpret(prog.graph, [xs], ctx_2bit.params.width)
        np.testing.assert_array_equal(outs[backend],
                                      ref[prog.graph.outputs[0]])
    np.testing.assert_array_equal(outs["eager"], outs["local"])


def test_program_from_graph_adopts_lowered_graphs(ctx_2bit, engine_2bit):
    """Hand-built / fhe_ml-lowered graphs run through Session.compile
    with derived tensor specs."""
    mod = ctx_2bit.params.plaintext_modulus
    g = trace(lambda x: (x + np.array([1, 1])).lut(
        np.arange(mod, dtype=np.uint64)[::-1].copy()), (2,))
    sess = Session(ctx_2bit, engine_2bit, backend="eager")
    prog = sess.compile(g)
    assert isinstance(prog, Program) and prog.n_inputs == 1
    xs = np.array([0, 2])
    got = sess(prog, jax.random.key(1), xs)[0]
    want = interpret(g, [xs], ctx_2bit.params.width)[g.outputs[0]]
    np.testing.assert_array_equal(got, want)


def test_serve_programs_trace_through_api(ctx_4bit):
    """serve.radix_binop_program graphs are api traces: same structure
    the Session records for the same op."""
    g = radix_binop_program("radix_add", BITS, 2)
    prog = trace_program(lambda a, b: a + b, (IntSpec(BITS, 2),) * 2)
    assert [n.op for n in g.nodes] == [n.op for n in prog.graph.nodes]
    assert [n.shape for n in g.nodes] == [n.shape for n in prog.graph.nodes]


def test_comparisons_need_width():
    with pytest.raises(TypeError, match="width"):
        trace_program(lambda a, b: a < b, (IntSpec(BITS, 2),) * 2)


def test_mixed_operand_type_rejected():
    # ints are fine (LPU-only const ops); anything else still needs to
    # be encrypted as a program input
    with pytest.raises(TypeError, match="EncryptedInt"):
        trace_program(lambda a: a + 1.5, (IntSpec(BITS, 2),))
    with pytest.raises(TypeError, match="EncryptedInt"):
        trace_program(lambda a: a * "3", (IntSpec(BITS, 2),))


def test_fhe_executor_is_a_deprecation_shim(ctx_2bit):
    """FheExecutor.run still works (same results, same stats surface)
    but warns, and shares its engine room with EagerBackend."""
    mod = ctx_2bit.params.plaintext_modulus
    t = np.arange(mod, dtype=np.uint64)[::-1].copy()
    g = trace(lambda x: (x.lut(t, name="a"), x.lut(t, name="b")), (2,))
    ex = FheExecutor(ctx_2bit)
    assert isinstance(ex._backend, EagerBackend)
    enc = ex.encrypt_inputs(jax.random.key(2), [np.array([1, 2])])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = ex.run(g, enc)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    np.testing.assert_array_equal(ex.decrypt(out[g.outputs[0]]),
                                  t[np.array([1, 2])])
    assert ex.stats["pbs"] == 4
    assert ex.stats["keyswitch"] == 2          # KS-dedup across the fanout
    assert ex.stats["lut_polys"] == 1          # ACC-dedup


def test_oracle_radix_semantics():
    """interpret() radix extension: digit-vector semantics mod 2^bits
    for every radix op, no keys involved."""
    m, d = 2, 4
    spec = IntSpec(BITS, m)

    def digits(v):
        return np.array([(v >> (i * m)) & 3 for i in range(d)], np.int64)

    cases = {
        "add": (lambda a, b: a + b, lambda x, y: (x + y) % MOD),
        "sub": (lambda a, b: a - b, lambda x, y: (x - y) % MOD),
        "mul": (lambda a, b: a * b, lambda x, y: (x * y) % MOD),
    }
    rng = np.random.default_rng(0)
    for name, (fn, ref) in cases.items():
        prog = trace_program(fn, (spec, spec))
        for _ in range(5):
            x, y = int(rng.integers(0, MOD)), int(rng.integers(0, MOD))
            out = interpret(prog.graph, [digits(x), digits(y)], 4)
            got = sum(int(v) << (i * m)
                      for i, v in enumerate(out[prog.graph.outputs[0]]))
            assert got == ref(x, y), (name, x, y)
    prog = trace_program(lambda a, b: a.cmp(b), (spec, spec))
    out = interpret(prog.graph, [digits(9), digits(200)], 4)
    assert out[prog.graph.outputs[0]].tolist() == [1]
    prog = trace_program(lambda a: a.relu(), (spec,))
    out = interpret(prog.graph, [digits((-5) % MOD)], 4)
    assert sum(int(v) << (i * m)
               for i, v in enumerate(out[prog.graph.outputs[0]])) == 0


# --- plaintext-constant operands (LPU-only radix_addc / radix_mulc) ----------

def test_const_ops_trace_lpu_only():
    """`x*k + c` lowers to radix_mulc/radix_addc — zero PBS in the whole
    plan — with auto-norm only when the digit window demands it."""
    spec = IntSpec(BITS, 2)
    prog = trace_program(lambda x: x * 3 + 41, (spec,))
    ops = [n.op for n in prog.graph.nodes]
    assert "radix_mulc" in ops and "radix_addc" in ops
    assert "radix_add" not in ops and "radix_mul" not in ops
    assert prog.graph.lut_applications() == 0
    # identity constants fold away entirely
    prog_id = trace_program(lambda x: (x + 0) * 1, (spec,))
    assert [n.op for n in prog_id.graph.nodes] == ["input"]


def test_const_ops_auto_norm_on_window_overflow():
    """Chaining const ops past the carry window inserts radix_norm (a
    PBS round) automatically instead of overflowing digits."""
    spec = IntSpec(BITS, 2)
    prog = trace_program(lambda x: (x * 3 + 3) * 3, (spec,))
    ops = [n.op for n in prog.graph.nodes]
    assert "radix_norm" in ops
    assert prog.graph.lut_applications() > 0   # the norm round only


def test_const_mul_rejects_negative_and_overflow():
    spec = IntSpec(BITS, 2)
    with pytest.raises(TypeError, match="negative"):
        trace_program(lambda x: x * -2, (spec,))
    with pytest.raises(TypeError, match="overflows the digit window"):
        trace_program(lambda x: x * 1000, (spec,))


@pytest.mark.parametrize("backend", ["eager", "local", "serve"])
def test_const_ops_identical_on_all_backends(ctx_4bit, engine_4bit,
                                             backend):
    """radix_addc/mulc/norm execute identically on every backend and
    match integer semantics mod 2^bits, including __radd__/__rmul__ and
    const subtraction (complement add)."""
    with Session(ctx_4bit, engine_4bit, backend=backend) as sess:
        prog = sess.trace(lambda x: (3 * x + 200, 7 + x, x - 9),
                          IntSpec(BITS))
        v = 173
        got = sess(prog, jax.random.key(5), v)
    assert got[0] == (3 * v + 200) % MOD
    assert got[1] == (7 + v) % MOD
    assert got[2] == (v - 9) % MOD


def test_const_ops_oracle_semantics():
    """interpret() covers the const ops too: keyless checking of the
    same programs the backends run."""
    m, d = 2, 4
    spec = IntSpec(BITS, m)

    def digits(v):
        return np.array([(v >> (i * m)) & 3 for i in range(d)], np.int64)

    prog = trace_program(lambda x: (x * 3 + 41) - 5, (spec,))
    for v in (0, 9, 200, 255):
        out = interpret(prog.graph, [digits(v)], 4)
        got = sum(int(x) << (i * m)
                  for i, x in enumerate(out[prog.graph.outputs[0]]))
        assert got == (v * 3 + 41 - 5) % MOD, v


# --- Pallas engine-room parity (ISSUE 9) -------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("backend", ["eager", "local", "serve"])
def test_pallas_kernel_backend_parity(ctx_4bit, engine_4bit, backend):
    """Radix add/mul/relu through every backend with
    `kernel_backend="pallas"` decrypts IDENTICAL to the reference
    engine: same ctx, same encryption key, so any plaintext difference
    is a kernel precision bug.  Serve exercises the fused waves
    (FusedLutScheduler routes them through engine.lut_batch, which is
    where the backend switch lives)."""

    def fn(a, b):
        return a + b, a * b, (a - b).relu()

    x, y = 173, 209
    with Session(ctx_4bit, engine_4bit, backend=backend) as sess:
        prog = sess.trace(fn, IntSpec(BITS), IntSpec(BITS))
        want = sess(prog, jax.random.key(21), x, y)
    with Session(ctx_4bit, backend=backend,
                 kernel_backend="pallas") as sess:
        assert sess.engine.kernel_backend == "pallas"
        prog = sess.trace(fn, IntSpec(BITS), IntSpec(BITS))
        got = sess(prog, jax.random.key(21), x, y)
    assert [int(v) for v in got] == [int(v) for v in want]
    assert int(got[0]) == (x + y) % MOD
    assert int(got[1]) == (x * y) % MOD
    assert int(got[2]) == 0          # x < y, so (x - y).relu() clamps to 0


def test_session_kernel_backend_rejects_engine_conflict(ctx_4bit,
                                                        engine_4bit):
    with pytest.raises(TypeError, match="kernel_backend"):
        Session(ctx_4bit, engine_4bit, kernel_backend="pallas")
