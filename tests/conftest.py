"""Shared fixtures: session-scoped key material.

TFHE keygen (bootstrapping + key-switching keys) costs several seconds
per parameter set; every test module creating its own context put the
suite's wall clock mostly into repeated keygen.  One context per
parameter set per session is safe — contexts are immutable key bundles
and every test derives its own encryption randomness.
"""
import jax
import pytest

from repro.core.engine import TaurusEngine
from repro.core.params import TEST_PARAMS, TEST_PARAMS_4BIT, TEST_PARAMS_6BIT
from repro.core.pbs import TFHEContext


@pytest.fixture(scope="session")
def ctx_2bit():
    return TFHEContext.create(jax.random.key(40), TEST_PARAMS)


@pytest.fixture(scope="session")
def ctx_4bit():
    return TFHEContext.create(jax.random.key(41), TEST_PARAMS_4BIT)


@pytest.fixture(scope="session")
def ctx_6bit():
    return TFHEContext.create(jax.random.PRNGKey(42), TEST_PARAMS_6BIT)


@pytest.fixture(scope="session")
def engine_2bit(ctx_2bit):
    return TaurusEngine.from_context(ctx_2bit)


@pytest.fixture(scope="session")
def engine_4bit(ctx_4bit):
    return TaurusEngine.from_context(ctx_4bit)


@pytest.fixture(scope="session")
def pallas_engine_2bit(ctx_2bit):
    return TaurusEngine.from_context(ctx_2bit, kernel_backend="pallas")


@pytest.fixture(scope="session")
def pallas_engine_4bit(ctx_4bit):
    return TaurusEngine.from_context(ctx_4bit, kernel_backend="pallas")
