"""Boolean TFHE baseline (paper Fig. 2a/5) + noise-budget analysis."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import noise
from repro.core.boolean import BooleanContext
from repro.core.params import (PAPER_PARAMS, TEST_PARAMS, TEST_PARAMS_4BIT,
                               TEST_PARAMS_6BIT)


@pytest.fixture()
def bctx(ctx_2bit):
    # gate layer over the session-scoped TEST_PARAMS key material
    return BooleanContext(ctx_2bit)


def _enc_bits(bctx, key, bits):
    return jnp.stack([bctx.encrypt(jax.random.fold_in(key, i), b)
                      for i, b in enumerate(bits)])


def test_all_gates_truth_tables(bctx):
    key = jax.random.PRNGKey(0)
    for a in (0, 1):
        for b in (0, 1):
            ca = bctx.encrypt(jax.random.fold_in(key, a), a)[None]
            cb = bctx.encrypt(jax.random.fold_in(key, 2 + b), b)[None]
            assert int(bctx.decrypt(bctx.and_(ca, cb))[0]) == (a & b)
            assert int(bctx.decrypt(bctx.or_(ca, cb))[0]) == (a | b)
            assert int(bctx.decrypt(bctx.xor(ca, cb))[0]) == (a ^ b)
            assert int(bctx.decrypt(bctx.nand(ca, cb))[0]) == 1 - (a & b)
            assert int(bctx.decrypt(bctx.not_(ca))[0]) == 1 - a


def test_ripple_carry_adder_6bit(bctx):
    """The paper's Fig. 5-top workload on the REAL engine."""
    rng = np.random.default_rng(1)
    key = jax.random.PRNGKey(9)
    a, b = int(rng.integers(0, 64)), int(rng.integers(0, 64))
    abits = [(a >> i) & 1 for i in range(6)]
    bbits = [(b >> i) & 1 for i in range(6)]
    ca = _enc_bits(bctx, jax.random.fold_in(key, 0), abits)
    cb = _enc_bits(bctx, jax.random.fold_in(key, 1), bbits)
    t0 = time.perf_counter()
    cs = bctx.add_ripple(ca, cb)
    out_bits = [int(bctx.decrypt(cs[i:i + 1])[0]) for i in range(7)]
    dt = time.perf_counter() - t0
    got = sum(bit << i for i, bit in enumerate(out_bits))
    assert got == a + b, (a, b, got)
    # 3 bootstraps/bit (vs the paper's 5-gate ripple-carry: both far more
    # than ONE multi-bit linear op — Observation 1/2)
    assert bctx.bootstraps_per_add_bit == 3


def test_maj_gate(bctx):
    key = jax.random.PRNGKey(3)
    for a in (0, 1):
        for b in (0, 1):
            for c in (0, 1):
                ca = bctx.encrypt(jax.random.fold_in(key, a), a)[None]
                cb = bctx.encrypt(jax.random.fold_in(key, 2 + b), b)[None]
                cc = bctx.encrypt(jax.random.fold_in(key, 4 + c), c)[None]
                assert int(bctx.decrypt(bctx.maj(ca, cb, cc))[0]) == \
                    int(a + b + c >= 2)


# --- noise budget ----------------------------------------------------------

def test_paper_params_noise_budget():
    """Every Table-II parameter set keeps p_err < 2^-40 at the width its
    PBS actually evaluates (full width at large N; radix chunks at small
    N, per Concrete's strategy / paper footnotes 3-4)."""
    for name, p in PAPER_PARAMS.items():
        lg = noise.log2_failure_prob(p, noise.radix_width(p))
        assert lg < -40, (name, lg)


def test_test_params_are_sound():
    for p in (TEST_PARAMS, TEST_PARAMS_4BIT, TEST_PARAMS_6BIT):
        assert noise.log2_failure_prob(p) < -30, p.name


def test_width_needs_bigger_params():
    """Fig. 6: wider width at fixed (n, N) destroys the budget; the
    paper's wider sets recover it with larger n/N."""
    # full width 6 in ONE LUT at N=2048 blows the budget...
    cnn = PAPER_PARAMS["cnn20"]
    assert noise.log2_failure_prob(cnn, width=cnn.width) > -40
    # ...radix chunks fix it at the same hardware dimensions...
    assert noise.log2_failure_prob(cnn, noise.radix_width(cnn)) < -40
    # ...and the paper's N=65536 set carries full width 9 in one LUT.
    dt = PAPER_PARAMS["decision_tree"]
    assert noise.radix_width(dt) == 9
    assert noise.log2_failure_prob(dt) < -40


def test_measured_noise_below_model(bctx):
    """Empirical PBS output noise stays within the analytic bound."""
    from repro.core import glwe
    params = bctx.params
    ctx = bctx.ctx
    key = jax.random.PRNGKey(11)
    msgs = np.arange(4) % params.plaintext_modulus
    cts = jnp.stack([ctx.encrypt(jax.random.fold_in(key, i), int(m))
                     for i, m in enumerate(msgs)])
    table = jnp.arange(params.plaintext_modulus, dtype=jnp.uint64)
    from repro.core import batch as batch_mod
    poly = glwe.make_lut_poly(table, params)
    out = batch_mod.pbs_batch(cts, jnp.broadcast_to(poly, (4, params.N)),
                              ctx.bsk_f, ctx.ksk, params)
    res = np.asarray([float(ctx.decrypt_noise(out[i], int(msgs[i])))
                      for i in range(4)])
    bound = 6.0 * np.sqrt(noise.pbs_out_var(params))
    assert np.max(np.abs(res)) < max(bound, 1e-9), (res, bound)
