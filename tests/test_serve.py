"""Serving runtime: IR interpreter vs plaintext oracle, cross-request
fused rounds with online dedup (on/off results identical), per-client
fairness, admission control, and fault retry.

Key material comes from the session-scoped fixtures in conftest.py; the
queue-level tests use linear-only programs so they spend no PBS time.
"""
import numpy as np
import pytest

import jax

from repro.compiler.ir import trace
from repro.compiler.passes import fused_round_dedup
from repro.core.integer import IntegerContext
from repro.fhe_ml.executor import interpret
from repro.runtime.fault import FaultConfig
from repro.serve import (AdmissionError, IrInterpreter, ServeRuntime,
                         decrypt_radix_output, encrypt_request_inputs,
                         radix_binop_program, radix_unop_program)

BITS = 8


@pytest.fixture()
def ic4(ctx_4bit, engine_4bit):
    return IntegerContext.create(ctx_4bit, engine_4bit)


def _linear_graph(const):
    """PBS-free program: (x + const) on a 1-element tensor."""
    return trace(lambda x: x + np.array([const]), (1,))


# --- the IR execution contract (radix_* included) ---------------------------

def test_interpreter_radix_ops_match_oracle(ctx_4bit, engine_4bit, ic4):
    m = ic4.spec(BITS).msg_bits
    interp = IrInterpreter(ctx_4bit, engine_4bit)
    cases = [("radix_add", 173, 209, (173 + 209) % 256),
             ("radix_sub", 60, 77, (60 - 77) % 256),
             ("radix_mul", 13, 11, 143)]
    for op, a, b, want in cases:
        g = radix_binop_program(op, BITS, m)
        enc = encrypt_request_inputs(ic4, jax.random.key(a), [a, b], BITS)
        out = interp.run_outputs(g, enc)[0]
        assert decrypt_radix_output(ic4, out, BITS)[0] == want, op
    # unary + collapsing ops
    g = radix_unop_program("radix_relu", BITS, m)
    enc = encrypt_request_inputs(ic4, jax.random.key(1), [-5], BITS)
    out = interp.run_outputs(g, enc)[0]
    assert decrypt_radix_output(ic4, out, BITS)[0] == 0
    g = radix_binop_program("radix_cmp", BITS, m)
    enc = encrypt_request_inputs(ic4, jax.random.key(2), [9, 200], BITS)
    out = interp.run_outputs(g, enc)[0]
    assert int(ctx_4bit.decrypt(out[0])) == 1          # a < b


def test_interpreter_lut_linear_match_plaintext_interpreter(ctx_2bit,
                                                           engine_2bit):
    """Tensor lut/linear/addc nodes agree with the fhe_ml plaintext
    oracle on the same graph."""
    mod = ctx_2bit.params.plaintext_modulus
    table = np.array([(3 * v + 1) % mod for v in range(mod)])

    def prog(x):
        return (x + np.array([1, 0, 1, 0])).lut(table)

    g = trace(prog, (4,))
    xs = np.array([0, 1, 2, 1])
    want = interpret(g, [xs], ctx_2bit.params.width)[g.outputs[0]]
    enc = ctx_2bit.encrypt(jax.random.key(3), xs)
    interp = IrInterpreter(ctx_2bit, engine_2bit)
    out = interp.run_outputs(g, [enc])[0]
    got = np.asarray(jax.vmap(ctx_2bit.decrypt)(out))
    np.testing.assert_array_equal(got, want)


# --- cross-request fused rounds + online dedup ------------------------------

def _serve_wave(ctx, engine, jobs, *, dedup):
    rt = ServeRuntime(ctx, engine, fused=True, dedup=dedup,
                      max_inflight=len(jobs), start_paused=True)
    handles = [rt.submit(g, enc, client_id=c) for c, g, enc in jobs]
    rt.resume()
    rt.drain()
    return rt, [h.outputs()[0] for h in handles]


def test_fused_dedup_on_off_decrypts_identical(ctx_4bit, engine_4bit, ic4):
    """The dedup-on fused run must be indistinguishable (after
    decryption) from dedup-off and from sequential execution — with a
    duplicated request in the wave so dedup actually fires."""
    m = ic4.spec(BITS).msg_bits
    g = radix_binop_program("radix_add", BITS, m)
    rng = np.random.default_rng(5)
    jobs, wants = [], []
    for i in range(3):
        a, b = int(rng.integers(0, 256)), int(rng.integers(0, 256))
        enc = encrypt_request_inputs(ic4, jax.random.key(40 + i), [a, b], BITS)
        jobs.append((f"client-{i}", g, enc))
        wants.append((a + b) % 256)
    jobs.append(("client-0", g, jobs[0][2]))           # the retried twin
    wants.append(wants[0])

    rt_on, outs_on = _serve_wave(ctx_4bit, engine_4bit, jobs, dedup=True)
    rt_off, outs_off = _serve_wave(ctx_4bit, engine_4bit, jobs, dedup=False)
    seq = IrInterpreter(ctx_4bit, engine_4bit)
    outs_seq = [seq.run_outputs(g, enc)[0] for _, g, enc in jobs]

    for o_on, o_off, o_seq, want in zip(outs_on, outs_off, outs_seq, wants):
        d_on = decrypt_radix_output(ic4, o_on, BITS)[0]
        assert d_on == want
        assert d_on == decrypt_radix_output(ic4, o_off, BITS)[0]
        assert d_on == decrypt_radix_output(ic4, o_seq, BITS)[0]
    assert rt_on.scheduler.stats["dedup_hits"] > 0     # the twin was free
    assert rt_off.scheduler.stats["dedup_hits"] == 0
    # every fused round saw the whole wave (all programs identical)
    assert rt_on.scheduler.mean_occupancy == pytest.approx(1.0)
    assert (rt_on.scheduler.stats["dispatched_luts"]
            < rt_off.scheduler.stats["dispatched_luts"])


def test_fused_round_dedup_scatter_reconstructs():
    """Property (exhaustive over random rounds): dedup + scatter is
    lossless and dispatches each unique (ciphertext, table) pair exactly
    once, for any mix of duplicate rows."""
    rng = np.random.default_rng(11)
    for trial in range(200):
        n = int(rng.integers(1, 40))
        pairs = [(int(rng.integers(0, 8)), int(rng.integers(0, 4)))
                 for _ in range(n)]
        unique_idx, inverse, hits = fused_round_dedup(pairs)
        assert len(unique_idx) + hits == len(pairs)
        assert len(set(pairs[i] for i in unique_idx)) == len(unique_idx)
        assert [pairs[unique_idx[j]] for j in inverse] == pairs


# --- queue: fairness, admission, retry --------------------------------------

def test_fairness_no_client_starves(ctx_2bit, engine_2bit):
    """Round-robin admission: a flood from one client cannot starve
    another — any request is admitted within
    (#clients x (its position in its own client's queue + 1))
    admissions of the wave start."""
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, max_inflight=1,
                      start_paused=True)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(4), np.array([1]))
    handles = {}
    for i in range(4):                       # client A floods first
        handles[("A", i)] = rt.submit(g, [x], client_id="A")
    handles[("B", 0)] = rt.submit(g, [x], client_id="B")
    handles[("C", 0)] = rt.submit(g, [x], client_id="C")
    rt.resume()
    rt.drain()
    order = rt.stats["admitted"]
    assert len(order) == 6
    pos = {cid: [i for i, (c, _) in enumerate(order) if c == cid]
           for cid in "ABC"}
    n_clients = 3
    # B and C each had one queued request: admitted within one RR sweep
    assert pos["B"][0] < n_clients
    assert pos["C"][0] < n_clients
    # A's k-th request admitted within n_clients * (k + 1) admissions
    for k, p in enumerate(pos["A"]):
        assert p < n_clients * (k + 1)
    # every request completed with the right value
    for h in handles.values():
        out = h.outputs()[0]
        assert int(ctx_2bit.decrypt(out[0])) == 2


def test_admission_control_rejects_over_cap(ctx_2bit, engine_2bit):
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False,
                      max_queued_per_client=2, start_paused=True)
    g = _linear_graph(0)
    x = ctx_2bit.encrypt(jax.random.key(5), np.array([0]))
    rt.submit(g, [x], client_id="A")
    rt.submit(g, [x], client_id="A")
    with pytest.raises(AdmissionError):
        rt.submit(g, [x], client_id="A")
    rt.submit(g, [x], client_id="B")       # other clients unaffected
    assert rt.stats["rejected"] == 1
    rt.resume()
    rt.drain()
    assert rt.stats["completed"] == 3


def test_fault_retry_recovers(ctx_2bit, engine_2bit):
    """A request whose execution fails (injected) retries through
    runtime.fault.StepRunner and still completes."""
    boom = {"left": 2}

    def chaos(request, attempt):
        if request.client_id == "flaky" and boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("injected failure")

    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False,
                      fault=FaultConfig(max_retries=3), fault_hook=chaos)
    g = _linear_graph(2)
    x = ctx_2bit.encrypt(jax.random.key(6), np.array([1]))
    h_ok = rt.submit(g, [x], client_id="steady")
    h_flaky = rt.submit(g, [x], client_id="flaky")
    rt.drain()
    assert int(ctx_2bit.decrypt(h_ok.outputs()[0][0])) == 3
    assert int(ctx_2bit.decrypt(h_flaky.outputs()[0][0])) == 3
    assert h_flaky.retries == 2 and h_ok.retries == 0
    assert rt.stats["retries"] == 2 and rt.stats["failed"] == 0


def test_fault_exhausted_retries_surface(ctx_2bit, engine_2bit):
    def always_fail(request, attempt):
        raise RuntimeError("poisoned request")

    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False,
                      fault=FaultConfig(max_retries=1),
                      fault_hook=always_fail)
    g = _linear_graph(0)
    x = ctx_2bit.encrypt(jax.random.key(7), np.array([0]))
    h = rt.submit(g, [x])
    rt.drain()
    with pytest.raises(RuntimeError, match="poisoned"):
        h.wait(timeout=5)
    assert rt.stats["failed"] == 1


# --- typed submit validation -------------------------------------------------

def test_submit_validation_typed_errors(ctx_2bit, engine_2bit):
    """Malformed requests fail AT SUBMIT with SubmitValidationError —
    not as worker-thread failures that burn fault retries."""
    from repro.serve import RuntimeClosedError, SubmitValidationError
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, start_paused=True)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(30), np.array([1]))
    with pytest.raises(SubmitValidationError, match="1 input nodes"):
        rt.submit(g, [], client_id="A")                 # too few inputs
    with pytest.raises(SubmitValidationError, match="1 input nodes"):
        rt.submit(g, [x, x], client_id="A")             # too many
    with pytest.raises(SubmitValidationError, match="expected a"):
        rt.submit(g, [x[:, :-1]], client_id="A")        # truncated ct
    with pytest.raises(SubmitValidationError, match="expected a"):
        rt.submit(g, [np.stack([x, x])], client_id="A")  # wrong rank
    assert rt.stats["invalid"] == 4 and rt.stats["retries"] == 0
    h = rt.submit(g, [x], client_id="A")                # valid one runs
    rt.resume()
    rt.close()
    assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 2
    with pytest.raises(RuntimeClosedError):
        rt.submit(g, [x], client_id="A")


# --- intra-request fusion (tensor-level radix nodes) ------------------------

def test_intra_request_vector_fanout_fuses(ctx_4bit, engine_4bit, ic4):
    """ONE request whose program adds a (3,)-tensor of radix integers:
    with intra_fuse the three vectors' identical carry rounds barrier
    into shared fused batches (round count collapses to one vector's
    schedule), and the decrypted values match the unfused run."""
    import jax.numpy as jnp

    m = ic4.spec(BITS).msg_bits
    d = ic4.spec(BITS).n_digits
    g = trace(lambda a, b: a.radix_add(b, msg_bits=m), (3, d), (3, d))
    rng = np.random.default_rng(9)
    xs = [int(v) for v in rng.integers(0, 256, 3)]
    ys = [int(v) for v in rng.integers(0, 256, 3)]
    enc = [jnp.concatenate(encrypt_request_inputs(
               ic4, jax.random.key(80 + j), vals, BITS))
           for j, vals in enumerate((xs, ys))]

    def wave(intra):
        rt = ServeRuntime(ctx_4bit, engine_4bit, max_inflight=1,
                          intra_fuse=intra, start_paused=True)
        h = rt.submit(g, enc, client_id="A")
        rt.resume()
        rt.drain()
        return rt, decrypt_radix_output(ic4, h.outputs()[0], BITS)

    rt_on, got_on = wave(True)
    rt_off, got_off = wave(False)
    want = [(x + y) % 256 for x, y in zip(xs, ys)]
    assert got_on == want and got_off == want
    on, off = rt_on.scheduler.stats, rt_off.scheduler.stats
    # same logical work, a third of the dispatches: rounds fused 3-wide
    assert on["logical_luts"] == off["logical_luts"]
    assert on["fused_rounds"] * 3 == off["fused_rounds"]
    assert rt_on.scheduler.mean_occupancy == pytest.approx(1.0)


# --- abandon / fail-fast shutdown (PR 8 satellites) --------------------------

def test_cancel_queued_request_abandons(ctx_2bit, engine_2bit):
    """RequestHandle.abandon() removes a still-queued request: waiters
    unblock with RequestAbandonedError, the abandoned counter moves,
    and other clients' requests are untouched."""
    from repro.serve import RequestAbandonedError
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, start_paused=True)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(50), np.array([1]))
    h_a = rt.submit(g, [x], client_id="A")
    h_b = rt.submit(g, [x], client_id="B")
    assert h_a.abandon() is True
    assert h_a.abandon() is False            # already terminal
    with pytest.raises(RequestAbandonedError):
        h_a.wait(timeout=1)
    with pytest.raises(RequestAbandonedError):
        h_a.output_futures[0].wait(timeout=1)
    assert rt.stats["abandoned"] == 1
    rt.resume()
    rt.drain()
    assert int(ctx_2bit.decrypt(h_b.outputs()[0][0])) == 2
    assert rt.stats["completed"] == 1
    # a finished handle cannot be abandoned
    assert h_b.abandon() is False
    rt.close()


def test_close_drain_false_fails_queued_fast(ctx_2bit, engine_2bit):
    """close(drain=False) is fail-fast: queued requests terminate with
    RuntimeClosedError IMMEDIATELY (no waiter hangs on work that will
    never run) instead of the old hang-forever behavior."""
    import time as _time

    from repro.serve import RuntimeClosedError
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, start_paused=True)
    g = _linear_graph(3)
    x = ctx_2bit.encrypt(jax.random.key(51), np.array([1]))
    handles = [rt.submit(g, [x], client_id=f"c{i}") for i in range(3)]
    t0 = _time.perf_counter()
    rt.close(drain=False)
    for h in handles:
        with pytest.raises(RuntimeClosedError, match="still queued"):
            h.wait(timeout=5)
        assert h.done()
    assert _time.perf_counter() - t0 < 2.0   # fail-fast, not a hang
    assert rt.stats["abandoned"] == 3 and rt.stats["completed"] == 0
    with pytest.raises(RuntimeClosedError):
        rt.submit(g, [x], client_id="late")


def test_close_drain_false_lets_inflight_finish(ctx_2bit, engine_2bit):
    """Requests already EXECUTING at close(drain=False) run to
    completion (a PBS round cannot be stopped mid-flight) and their
    handles resolve normally."""
    rt = ServeRuntime(ctx_2bit, engine_2bit, fused=False, max_inflight=1)
    g = _linear_graph(1)
    x = ctx_2bit.encrypt(jax.random.key(52), np.array([2]))
    h = rt.submit(g, [x], client_id="A")
    h.wait(timeout=30)                       # admitted + done
    rt.close(drain=False)
    assert int(ctx_2bit.decrypt(h.outputs()[0][0])) == 3
    assert rt.stats["completed"] == 1 and rt.stats["abandoned"] == 0
