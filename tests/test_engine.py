"""Batched engine correctness: batched == unbatched == decrypt oracle.

Key material comes from the session-scoped fixtures in conftest.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import glwe

U64 = jnp.uint64


def test_batched_pbs_matches_decrypt_oracle(ctx_2bit, engine_2bit):
    ctx, eng = ctx_2bit, engine_2bit
    mod = ctx.params.plaintext_modulus
    msgs = np.array([0, 1, 2, 3, 3, 2, 1], dtype=np.uint64)  # odd B: pad path
    cts = jax.vmap(lambda k, m: ctx.encrypt(k, m))(
        jax.random.split(jax.random.key(41), len(msgs)), jnp.asarray(msgs)
    )
    table = [(m * 3 + 1) % mod for m in range(mod)]
    poly = glwe.make_lut_poly(jnp.asarray(table, dtype=U64), ctx.params)
    polys = jnp.broadcast_to(poly, (len(msgs),) + poly.shape)
    out = eng.lut_batch(cts, polys)
    got = np.asarray(jax.vmap(ctx.decrypt)(out))
    want = np.array([table[int(m)] for m in msgs], dtype=np.uint64)
    np.testing.assert_array_equal(got, want)


def test_batched_equals_xpu_unbatched_semantics(ctx_2bit, engine_2bit):
    """Round-robin batching must not change results vs the XPU-style loop."""
    ctx, eng = ctx_2bit, engine_2bit
    mod = ctx.params.plaintext_modulus
    msgs = jnp.asarray([3, 0, 2, 1], dtype=U64)
    cts = jax.vmap(lambda k, m: ctx.encrypt(k, m))(
        jax.random.split(jax.random.key(42), 4), msgs
    )
    poly = glwe.make_lut_poly(jnp.arange(mod, dtype=U64), ctx.params)
    polys = jnp.broadcast_to(poly, (4,) + poly.shape)
    a = eng.lut_batch(cts, polys)
    b = eng.lut_batch_xpu(cts, polys)
    # Same math/keys, but einsum reduction order differs -> FFT roundoff
    # crosses decomposition rounding boundaries -> different (equally
    # valid) ciphertexts. The CONTRACT is equal decryptions.
    np.testing.assert_array_equal(
        np.asarray(jax.vmap(ctx.decrypt)(a)), np.asarray(jax.vmap(ctx.decrypt)(b))
    )
    np.testing.assert_array_equal(
        np.asarray(jax.vmap(ctx.decrypt)(a)), np.asarray(msgs)
    )


def test_linear_ops_roundtrip(ctx_2bit, engine_2bit):
    ctx, eng = ctx_2bit, engine_2bit
    c1 = ctx.encrypt(jax.random.key(43), 1)
    c2 = ctx.encrypt(jax.random.key(44), 2)
    assert int(ctx.decrypt(eng.add(c1, c2))) == 3
    assert int(ctx.decrypt(eng.scalar_mul(c1, 3))) == 3
    assert int(ctx.decrypt(eng.add_plain(c2, 1))) == 3
    assert int(ctx.decrypt(eng.trivial(2))) == 2


def test_lut_batch_tables_heterogeneous(ctx_2bit, engine_2bit):
    """Integer-table entry point: DIFFERENT tables per ciphertext in one
    batch (what the radix carry rounds dispatch)."""
    ctx, eng = ctx_2bit, engine_2bit
    mod = ctx.params.plaintext_modulus
    msgs = np.array([1, 3, 0, 2], dtype=np.uint64)
    cts = jax.vmap(lambda k, m: ctx.encrypt(k, m))(
        jax.random.split(jax.random.key(45), len(msgs)), jnp.asarray(msgs)
    )
    tables = np.stack([np.roll(np.arange(mod, dtype=np.uint64), i)
                       for i in range(len(msgs))])
    out = eng.lut_batch_tables(cts, tables)
    got = np.asarray(jax.vmap(ctx.decrypt)(out))
    want = np.array([tables[i][int(m)] for i, m in enumerate(msgs)])
    np.testing.assert_array_equal(got, want)


def test_lut_batch_tables_single_table_broadcasts(ctx_2bit, engine_2bit):
    """A 1-D table is applied to the whole batch (the common one-LUT
    case without callers hand-tiling it)."""
    ctx, eng = ctx_2bit, engine_2bit
    mod = ctx.params.plaintext_modulus
    msgs = np.array([2, 0, 3], dtype=np.uint64)
    cts = jax.vmap(lambda k, m: ctx.encrypt(k, m))(
        jax.random.split(jax.random.key(46), len(msgs)), jnp.asarray(msgs)
    )
    table = np.array([(m + 1) % mod for m in range(mod)], dtype=np.uint64)
    out = eng.lut_batch_tables(cts, table)
    got = np.asarray(jax.vmap(ctx.decrypt)(out))
    np.testing.assert_array_equal(got, (msgs + 1) % mod)


def test_lut_batch_tables_count_mismatch_raises(ctx_2bit, engine_2bit):
    """Regression: a table count that doesn't match the ciphertext batch
    used to slip into the jitted PBS as a silent shape mismatch."""
    ctx, eng = ctx_2bit, engine_2bit
    mod = ctx.params.plaintext_modulus
    cts = jax.vmap(lambda k, m: ctx.encrypt(k, m))(
        jax.random.split(jax.random.key(47), 3),
        jnp.asarray([0, 1, 2], dtype=U64)
    )
    two_tables = np.tile(np.arange(mod, dtype=np.uint64), (2, 1))
    with pytest.raises(ValueError, match="3 ciphertexts but 2 tables"):
        eng.lut_batch_tables(cts, two_tables)
    with pytest.raises(ValueError, match="tables must be"):
        eng.lut_batch_tables(cts, np.zeros((3, mod + 1), dtype=np.uint64))
    # the poly-level entry validates too
    polys = glwe.make_lut_polys(two_tables, ctx.params)
    with pytest.raises(ValueError, match="3 ciphertexts but 2 LUT"):
        eng.lut_batch(cts, polys)
