"""Radix wide-integer subsystem: encrypted 8/16/32-bit arithmetic must
match the plaintext oracle, with every carry round dispatched through
`TaurusEngine.lut_batch` at batch sizes >= the digit count."""
import numpy as np
import pytest

import jax

from repro.core.integer import IntegerContext, RadixSpec
from repro.core.params import TEST_PARAMS, TEST_PARAMS_4BIT


@pytest.fixture()
def ic2(ctx_2bit, engine_2bit):
    return IntegerContext.create(ctx_2bit, engine_2bit)


@pytest.fixture()
def ic4(ctx_4bit, engine_4bit):
    return IntegerContext.create(ctx_4bit, engine_4bit)


# --- digit layout -----------------------------------------------------------

def test_spec_layout():
    s = RadixSpec.create(TEST_PARAMS_4BIT, 16)       # width 4 -> 2 msg bits
    assert (s.msg_bits, s.base, s.n_digits) == (2, 4, 8)
    s2 = RadixSpec.create(TEST_PARAMS, 32)           # width 2 -> 1 msg bit
    assert (s2.msg_bits, s2.base, s2.n_digits) == (1, 2, 32)


def test_spec_digit_roundtrip():
    s = RadixSpec.create(TEST_PARAMS_4BIT, 16)
    for v in (0, 1, 0xBEEF, 0xFFFF, 12345):
        assert s.from_digits(s.to_digits(v)) == v
    # unpropagated carries still recombine to the represented integer
    assert s.from_digits([5, 3, 0, 0, 0, 0, 0, 0]) == 5 + 3 * 4


@pytest.mark.parametrize("bits", [8, 16, 32])
def test_encrypt_decrypt_roundtrip(ic4, bits):
    rng = np.random.default_rng(bits)
    for i in range(3):
        v = int(rng.integers(0, 1 << bits))
        ct = ic4.encrypt(jax.random.key(100 * bits + i), v, bits)
        assert ic4.decrypt(ct) == v
        assert ct.digits.shape[0] == bits // ct.spec.msg_bits


def test_encrypt_decrypt_roundtrip_base2(ic2):
    v = 0xDEADBEEF
    ct = ic2.encrypt(jax.random.key(0), v, 32)
    assert ic2.decrypt(ct) == v and ct.digits.shape[0] == 32


# --- the acceptance pair: 16-bit add/mul vs the plaintext oracle ------------

def _assert_batched(ic, n_digits):
    """Every PBS round went through TaurusEngine.lut_batch with at least
    one ciphertext per digit in the dispatched batch."""
    assert ic.stats["lut_batches"] > 0
    assert min(ic.stats["dispatch_sizes"]) >= n_digits


def test_add16_matches_oracle(ic4, monkeypatch):
    rng = np.random.default_rng(7)
    eng = ic4.engine
    calls = []
    real = type(eng).lut_batch

    def spy(self, cts, polys):
        calls.append(int(cts.shape[0]))
        return real(self, cts, polys)
    monkeypatch.setattr(type(eng), "lut_batch", spy)

    for i in range(2):
        a, b = int(rng.integers(0, 1 << 16)), int(rng.integers(0, 1 << 16))
        ca = ic4.encrypt(jax.random.key(2 * i), a, 16)
        cb = ic4.encrypt(jax.random.key(2 * i + 1), b, 16)
        ic4.reset_stats()
        calls.clear()
        s = ic4.add(ca, cb)
        assert ic4.decrypt(s) == (a + b) % 2 ** 16
        _assert_batched(ic4, ca.spec.n_digits)
        # the rounds really went through the engine's batched PBS entry
        assert calls == ic4.stats["dispatch_sizes"]
        assert min(calls) >= ca.spec.n_digits


def test_mul16_matches_oracle(ic4):
    rng = np.random.default_rng(11)
    a, b = int(rng.integers(0, 1 << 16)), int(rng.integers(0, 1 << 16))
    ca = ic4.encrypt(jax.random.key(50), a, 16)
    cb = ic4.encrypt(jax.random.key(51), b, 16)
    ic4.reset_stats()
    m = ic4.mul(ca, cb)
    assert ic4.decrypt(m) == (a * b) % 2 ** 16
    _assert_batched(ic4, ca.spec.n_digits)
    # the partial-product wave batches every pairwise LUT at once
    d = ca.spec.n_digits
    assert max(ic4.stats["batch_sizes"]) >= d * (d + 1)


def test_add8_ripple_base2(ic2):
    """Width-2 params take the ripple strategy (no room for the bivariate
    status combine): still one lut_batch of 2D per round."""
    a, b = 173, 209
    ca = ic2.encrypt(jax.random.key(60), a, 8)
    cb = ic2.encrypt(jax.random.key(61), b, 8)
    ic2.reset_stats()
    s = ic2.add(ca, cb)
    assert ic2.decrypt(s) == (a + b) % 256
    d = ca.spec.n_digits
    assert ic2.stats["lut_batches"] == d                 # D ripple rounds
    assert all(bs == 2 * d for bs in ic2.stats["batch_sizes"])


def test_mul8_base2_carry_save(ic2):
    """Base-2 digits (width 2): carry-save compression + ripple rounds."""
    a, b = 171, 206
    ca = ic2.encrypt(jax.random.key(72), a, 8)
    cb = ic2.encrypt(jax.random.key(73), b, 8)
    assert ic2.decrypt(ic2.mul(ca, cb)) == (a * b) % 256


def test_mul8_matches_oracle_random(ic4):
    rng = np.random.default_rng(13)
    for i in range(2):
        a, b = int(rng.integers(0, 256)), int(rng.integers(0, 256))
        ca = ic4.encrypt(jax.random.key(70 + 2 * i), a, 8)
        cb = ic4.encrypt(jax.random.key(71 + 2 * i), b, 8)
        assert ic4.decrypt(ic4.mul(ca, cb)) == (a * b) % 256


# --- carry behaviour at digit boundaries ------------------------------------

def test_carry_chain_full_wraparound(ic4):
    """0xFFFF + 1 = 0 mod 2^16: the longest possible carry chain."""
    ca = ic4.encrypt(jax.random.key(80), 0xFFFF, 16)
    cb = ic4.encrypt(jax.random.key(81), 1, 16)
    s = ic4.add(ca, cb)
    assert ic4.decrypt(s) == 0
    assert np.all(ic4.decrypt_digits(s) == 0)            # digits reduced

def test_carry_stops_mid_chain(ic4):
    """0x00FF + 1 = 0x0100: carries cross exactly the low digits."""
    ca = ic4.encrypt(jax.random.key(82), 0x00FF, 16)
    cb = ic4.encrypt(jax.random.key(83), 1, 16)
    assert ic4.decrypt(ic4.add(ca, cb)) == 0x0100


def test_add16_base2_lookahead_carry_boundary(ic2):
    """Width-2 params at 16 base-2 digits auto-select the two-level
    carry-lookahead: 2 + 2*ceil(log2 D) batched rounds instead of the
    D-round ripple, correct across every carry boundary."""
    d = 16
    want_rounds = 2 + 2 * (d - 1).bit_length()
    assert want_rounds < d                       # the point of the scan
    cases = [(0xFFFF, 1, 0x0000),                # full-length carry chain
             (0x7FFF, 1, 0x8000),                # chain stops at the MSB
             (0xAAAA, 0x5555, 0xFFFF),           # all-propagate, no carry
             (0xD9C2, 0xA30F, 0x7CD1)]
    for a, b, want in cases:
        ca = ic2.encrypt(jax.random.key(a), a, 16)
        cb = ic2.encrypt(jax.random.key(b + 7), b, 16)
        ic2.reset_stats()
        s = ic2.add(ca, cb)
        assert ic2.decrypt(s) == want, (a, b)
        assert np.all(ic2.decrypt_digits(s) < 2)       # fully propagated
        assert ic2.stats["lut_batches"] == want_rounds
        assert min(ic2.stats["batch_sizes"]) >= d      # full-width rounds


def test_sub_wraps_two_complement(ic4):
    a, b = 0x1234, 0xBEEF
    ca = ic4.encrypt(jax.random.key(84), a, 16)
    cb = ic4.encrypt(jax.random.key(85), b, 16)
    assert ic4.decrypt(ic4.sub(ca, cb)) == (a - b) % 2 ** 16
    assert ic4.decrypt(ic4.sub(cb, ca)) == (b - a) % 2 ** 16


def test_mul_digit_row(ic4):
    a = 0x0BED
    ca = ic4.encrypt(jax.random.key(86), a, 16)
    for dval in (0, 1, 3):
        dig = ic4.encrypt(jax.random.key(87 + dval), dval, 16)
        got = ic4.mul_digit(ca, dig.digits[0])
        assert ic4.decrypt(got) == (a * dval) % 2 ** 16


# --- predicates -------------------------------------------------------------

def test_compare_three_way(ic4):
    pairs = [(100, 100, 0), (99, 100, 1), (0xBEEF, 0x1234, 2),
             (0x1234, 0x1234, 0)]
    for a, b, want in pairs:
        ca = ic4.encrypt(jax.random.key(a % 97), a, 16)
        cb = ic4.encrypt(jax.random.key(b % 89 + 200), b, 16)
        assert int(ic4.ctx.decrypt(ic4.compare(ca, cb))) == want, (a, b)


def test_relu_clamp_signed(ic4):
    for v, want in ((1234, 1234), (-1234, 0), (0, 0), (-1, 0),
                    (0x7FFF, 0x7FFF)):
        ct = ic4.encrypt(jax.random.key(v % 251 + 300), v, 16)
        assert ic4.decrypt(ic4.relu_clamp(ct)) == want, v


# --- noise budget ------------------------------------------------------------

def test_per_digit_noise_budget(ic4):
    """After add+mul chains every digit's residual noise sits well below
    half a plaintext slot (PBS refreshed it)."""
    a, b = 0xBEEF, 0x1234
    ca = ic4.encrypt(jax.random.key(90), a, 16)
    cb = ic4.encrypt(jax.random.key(91), b, 16)
    s = ic4.add(ca, cb)
    noise = ic4.digit_noise(s, (a + b) % 2 ** 16)
    budget = 1.0 / 2 ** (ic4.params.width + 2)
    assert np.max(np.abs(noise)) < budget
    m = ic4.mul(ca, cb)
    noise_m = ic4.digit_noise(m, (a * b) % 2 ** 16)
    assert np.max(np.abs(noise_m)) < budget


@pytest.mark.slow
def test_pallas_noise_budget_regression(ctx_4bit, pallas_engine_4bit):
    """The Pallas engine room's PBS refresh keeps per-digit noise within
    the same budget as the reference engine — the regression gate for
    kernel transform precision (an f32-plane or limb bug would blow
    past this long before decryption flips)."""
    ic = IntegerContext.create(ctx_4bit, pallas_engine_4bit)
    a, b = 0xBE, 0x34
    ca = ic.encrypt(jax.random.key(90), a, 8)
    cb = ic.encrypt(jax.random.key(91), b, 8)
    budget = 1.0 / 2 ** (ic.params.width + 2)
    s = ic.add(ca, cb)
    assert ic.decrypt(s) == (a + b) % 2 ** 8
    assert np.max(np.abs(ic.digit_noise(s, (a + b) % 2 ** 8))) < budget
    m = ic.mul(ca, cb)
    assert ic.decrypt(m) == (a * b) % 2 ** 8
    assert np.max(np.abs(ic.digit_noise(m, (a * b) % 2 ** 8))) < budget


# --- the round-plan cost model vs reality -----------------------------------

@pytest.mark.parametrize("fixture,bits,strategy", [
    ("ic2", 16, "lookahead"),    # width 2, D=16: 2 + 2*log2(D) < D
    ("ic2", 8, "ripple"),        # width 2, D=8: lookahead doesn't pay
    ("ic4", 16, "prefix"),       # width 4: packed Hillis-Steele scan
])
def test_round_plan_matches_observed_stats(request, fixture, bits, strategy):
    """`radix_round_plan` is the compiler's single source of truth for
    the batched-PBS schedule; with msg_bits it must model the SAME
    strategy `IntegerContext.propagate` auto-selects — round count AND
    per-round batch sizes (ISSUE 3 satellite: base-2 programs were
    under-counted before the lookahead plan existed)."""
    from repro.compiler.ir import radix_round_plan
    ic = request.getfixturevalue(fixture)
    spec = ic.spec(bits)
    mask = (1 << bits) - 1
    a = ic.encrypt(jax.random.key(301), 0xBEEF & mask, bits)
    b = ic.encrypt(jax.random.key(302), 0x1234 & mask, bits)
    ic.reset_stats()
    s = ic.add(a, b)
    assert ic.decrypt(s) == (0xBEEF + 0x1234) & mask
    plan = radix_round_plan("radix_add", spec.n_digits, spec.msg_bits)
    assert ic.stats["lut_batches"] == len(plan), strategy
    assert ic.stats["batch_sizes"] == [r["luts"] for r in plan], strategy
    # msg_bits omitted keeps the historical wide-window (prefix) model
    if strategy == "prefix":
        assert plan == radix_round_plan("radix_add", spec.n_digits)
