"""Pipeline parallelism: schedule correctness on a multi-device host mesh
(subprocess with XLA host-device override) and single-device parity."""
import json
import os
import subprocess
import sys

import numpy as np

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.models.pipeline import make_pipelined_fwd

n_stages, n_micro, B, S, d = 4, 8, 16, 4, 8
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(n_stages, d, d)) / np.sqrt(d), jnp.float32)
x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)

def stage_fn(W, h):
    return jnp.tanh(h @ W)

# reference: plain sequential stages
ref = x
for i in range(n_stages):
    ref = stage_fn(Ws[i], ref)

mesh = jax.make_mesh((4,), ("pod",))
fwd = make_pipelined_fwd(stage_fn, mesh, n_micro=n_micro)
out = jax.jit(fwd)(Ws[:, None], x)   # leading stage axis, singleton slice
err = float(jnp.max(jnp.abs(out - ref)))
print(json.dumps({"err": err}))
"""


def test_pipeline_matches_sequential():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, out
