"""Property-based tests (hypothesis) on the scheme's algebraic invariants
and the compiler's dedup correctness."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev-only dependency (see requirements-dev.txt); skipping "
           "property-based tests")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import decompose as dec, fft, glwe, torus
from repro.core.params import TEST_PARAMS

U64 = jnp.uint64
_SET = settings(max_examples=25, deadline=None)


@given(st.lists(st.integers(0, 2 ** 64 - 1), min_size=1, max_size=16),
       st.integers(2, 16), st.integers(1, 4))
@_SET
def test_decompose_recompose_error_bound(vals, base_log, level):
    """|recompose(decompose(v)) - v| <= 2^(63 - base_log*level)."""
    v = jnp.asarray(np.array(vals, dtype=np.uint64))
    digits = dec.decompose(v, base_log, level)
    assert int(jnp.max(jnp.abs(digits))) <= (1 << base_log) // 2
    back = dec.recompose(digits, base_log, level)
    err = torus.to_signed(back - v)
    bound = 1 << max(64 - base_log * level - 1, 0)
    assert int(jnp.max(jnp.abs(err))) <= bound


# values that stress limb boundaries: all-ones/zero in either uint32
# limb, sign-bit edges, and the carry-chain corners of 16-bit sub-limbs
_LIMB_EDGES = [0, 1, 0xFFFF, 0x10000, 0xFFFFFFFF, 0x100000000,
               0xFFFF0000FFFF0000, 0x0000FFFF0000FFFF,
               (1 << 63) - 1, 1 << 63, (1 << 64) - 1]
_u64 = st.one_of(st.sampled_from(_LIMB_EDGES),
                 st.integers(0, 2 ** 64 - 1))
_digit = st.one_of(st.sampled_from([0, 1, -1, (1 << 31) - 1, -(1 << 31),
                                    0x7FFF, -0x8000, 0x10000]),
                   st.integers(-(1 << 31), (1 << 31) - 1))


@given(st.lists(_digit, min_size=1, max_size=8),
       st.lists(_u64, min_size=1, max_size=8))
@_SET
def test_limb_mul64_matches_python_int(digits, keys):
    """The kernel's 16-bit-sub-limb 64-bit multiply (`_mul64`) == exact
    Python int arithmetic mod 2^64, including carry/overflow edges at
    every limb boundary."""
    from repro.kernels.keyswitch import _mul64
    n = min(len(digits), len(keys))
    d = np.array(digits[:n], dtype=np.int32)
    k = np.array(keys[:n], dtype=np.uint64)
    du_lo = jnp.asarray(d.astype(np.uint32))
    du_hi = jnp.asarray((d >> 31).astype(np.uint32))
    k_hi = jnp.asarray((k >> np.uint64(32)).astype(np.uint32))
    k_lo = jnp.asarray((k & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    hi, lo = _mul64(du_hi, du_lo, k_hi, k_lo)
    got = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) \
        | np.asarray(lo).astype(np.uint64)
    want = np.array([(int(a) * int(b)) % (1 << 64)
                     for a, b in zip(d.tolist(), k.tolist())],
                    dtype=np.uint64)
    np.testing.assert_array_equal(got, want)


@given(st.lists(_digit, min_size=1, max_size=64),
       st.lists(_u64, min_size=1, max_size=4),
       st.integers(1, 64))
@_SET
def test_keyswitch_mac_exact_vs_python_int(digits, keys, block_s):
    """The whole limb MAC kernel (interpret mode), random torus keys and
    digits at limb edges, any block size == exact big-int dot mod 2^64."""
    from repro.kernels import ops
    S, T = len(digits), len(keys)
    d = np.array(digits, dtype=np.int32)[None, :]          # B=1
    ksk = np.tile(np.array(keys, dtype=np.uint64), (S, 1))
    rng = np.random.default_rng(S * T)
    ksk ^= rng.integers(0, 1 << 64, (S, T), dtype=np.uint64)
    got = np.asarray(ops.lpu_keyswitch_mac(
        jnp.asarray(d), jnp.asarray(ksk), block_s=block_s))[0]
    want = np.array(
        [sum(int(d[0, s]) * int(ksk[s, t]) for s in range(S)) % (1 << 64)
         for t in range(T)], dtype=np.uint64)
    np.testing.assert_array_equal(got, want)


@given(st.lists(_u64, min_size=1, max_size=16),
       st.integers(2, 16), st.integers(1, 4))
@_SET
def test_decompose_recompose_limb_edges(vals, base_log, level):
    """decompose/recompose round-trip at limb-boundary torus values:
    carries crossing the uint32 seam and sign-bit edges stay within the
    gadget's rounding bound (same invariant as the random-value test,
    pinned on the adversarial corners the fused keyswitch feeds)."""
    v = jnp.asarray(np.array(vals, dtype=np.uint64))
    digits = dec.decompose(v, base_log, level)
    assert int(jnp.max(jnp.abs(digits))) <= (1 << base_log) // 2
    back = dec.recompose(digits, base_log, level)
    err = torus.to_signed(back - v)
    bound = 1 << max(64 - base_log * level - 1, 0)
    assert int(jnp.max(jnp.abs(err))) <= bound


@given(st.integers(0, 2 ** 32), st.integers(0, 2 ** 32))
@_SET
def test_torus_add_homomorphic(a, b):
    """encode(a) + encode(b) == encode(a+b) on the torus."""
    d = TEST_PARAMS.delta
    ea = torus.encode(jnp.asarray(a, U64), d)
    eb = torus.encode(jnp.asarray(b, U64), d)
    expect = torus.encode(jnp.asarray((a + b), U64), d)
    assert int(ea + eb) == int(expect)


@given(st.lists(st.integers(-2 ** 20, 2 ** 20), min_size=8, max_size=8),
       st.lists(st.integers(-2 ** 20, 2 ** 20), min_size=8, max_size=8))
@_SET
def test_negacyclic_mul_matches_schoolbook(a, b):
    """FFT negacyclic product == coefficient-domain X^N+1 reduction."""
    N = 8
    av = np.array(a, np.int64)
    bv = np.array(b, np.int64)
    ref = np.zeros(N, dtype=np.object_)
    for i in range(N):
        for j in range(N):
            k = i + j
            s = int(av[i]) * int(bv[j])
            if k >= N:
                ref[(k - N)] -= s
            else:
                ref[k] += s
    ref = jnp.asarray(np.array([x % (1 << 64) for x in ref],
                               dtype=np.uint64))
    got = fft.negacyclic_mul(jnp.asarray(av).astype(U64),
                             jnp.asarray(bv).astype(U64))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@given(st.integers(0, 2 ** 63 - 1), st.integers(3, 12))
@_SET
def test_mod_switch_rounds_to_nearest(v, log2_2N):
    from repro.core import lwe
    out = int(lwe.mod_switch(jnp.asarray([v], U64), log2_2N)[0])
    exact = v / 2 ** (64 - log2_2N)
    assert abs(((out - exact + 2 ** (log2_2N - 1)) % 2 ** log2_2N)
               - 2 ** (log2_2N - 1)) <= 0.5 + 1e-9


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 15))
@_SET
def test_rotate_composes(r1, r2):
    """X^r1 * (X^r2 * p) == X^(r1+r2 mod 2N) * p."""
    N = 16
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.integers(0, 2 ** 40, (N,), dtype=np.uint64))
    a = glwe.rotate(glwe.rotate(p, r2 % (2 * N), N), r1 % (2 * N), N)
    b = glwe.rotate(p, (r1 + r2) % (2 * N), N)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.lists(st.integers(0, 63), min_size=2, max_size=12),
       st.integers(1, 4))
@_SET
def test_ks_dedup_invariant(vals, n_luts):
    """KS-dedup never changes LUT results, only the key-switch count."""
    from repro.compiler.ir import trace
    from repro.compiler import passes
    from repro.fhe_ml.executor import interpret
    tables = [np.roll(np.arange(64, dtype=np.uint64), i) for i in range(n_luts)]

    def f(x):
        return tuple(x.lut(t) for t in tables)
    g = trace(f, (len(vals),))
    ref = interpret(g, [np.array(vals)], 6)
    _, s_on = passes.lower_to_physical(g, ks_dedup=True)
    _, s_off = passes.lower_to_physical(g, ks_dedup=False)
    assert s_on.ks_after == len(vals)
    assert s_off.ks_after == len(vals) * n_luts
    # interpretation (semantics) is independent of the pass
    ref2 = interpret(g, [np.array(vals)], 6)
    for oid in g.outputs:
        np.testing.assert_array_equal(ref[oid], ref2[oid])
