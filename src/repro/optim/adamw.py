"""AdamW with decoupled weight decay, f32 master moments, global-norm clip.

Optimizer state inherits the parameter sharding (FSDP axis included), so
at 33B params the m/v moments are ~1 GB/device on the 256-chip mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        return adamw_init(params)

    def update(self, params, opt_state, grads, step):
        return adamw_update(self, params, opt_state, grads, step)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(opt: AdamW, params, opt_state, grads, step):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-12))
    lr = opt.lr(step) if callable(opt.lr) else jnp.asarray(opt.lr, F32)
    t = (step + 1).astype(F32)
    bc1 = 1.0 - opt.b1 ** t
    bc2 = 1.0 - opt.b2 ** t

    def upd(p, m, v, g):
        g = g.astype(F32) * scale
        m = opt.b1 * m + (1 - opt.b1) * g
        v = opt.b2 * v + (1 - opt.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        decay = opt.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(F32) - lr * (delta + decay * p.astype(F32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_g = treedef.flatten_up_to(grads)
    out = [upd(p, m, v, g) for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v}, metrics
