"""Optimizer substrate (no optax): AdamW + cosine schedule + global clip."""
from repro.optim.adamw import AdamW, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
