"""Calibrated Taurus / CPU / GPU cost models (paper §VI).

Taurus microarchitecture constants (paper §IV):
  * BRU: 512 BSK multiplications/cycle @ 1 GHz.  One blind-rotation
    iteration of one ciphertext costs (k+1)^2*level*N/256 cycles (the
    2x folds the 4-real-mult complex MAC into the 512/cycle figure).
  * 12 round-robin ciphertexts per BRU keep the pipeline full; the
    single-ciphertext LATENCY is therefore 12x the per-ct compute time.
    Validation: GPT-2 params (n=1003, k=1, l=1, N=32768) give
    12 * 1003*4*32768/256 cycles = 6.16 ms — exactly the paper's
    reported minimum high-width bootstrap latency; CNN-20 params give
    0.28 ms, matching §VI-C.
  * LPU: 4 lanes x 64 elements @ 1 GHz = 256 MAC/cycle/cluster.
  * 4 compute clusters; batch = 48 ciphertexts; full synchronization.
  * Two HBM2E stacks: 819 GB/s.

Memory model (Fig. 13): BSK/KSK stream ONCE per batch (global buffers +
NoC broadcast, key reuse across the whole batch); GLWE accumulators live
in the 9216 KB per-cluster buffer and spill to DRAM when
12 * 2 * (k+1) * N * 12 B exceeds it (Fig. 14).

The XPU variant (Table IV) replaces the BRU with a Morphling-style
systolic array: 4 rows x 8 coeff/cycle FFT units and NO cross-ciphertext
BSK reuse; with k=1 only (k+1)=2 of 4 PE columns are used (Obs. 3).
"""
from __future__ import annotations

import dataclasses

from repro.core.params import TFHEParams
from repro.compiler.schedule import Schedule, Batch

GHZ = 1e9
HBM_BW = 819e9
ACC_BUF_BYTES = 9216 * 1024
CLUSTERS = 4
BATCH = 48
ROUND_ROBIN = 12


@dataclasses.dataclass
class TaurusModel:
    params: TFHEParams
    mac_per_cycle: int = 512          # BRU BSK mults/cycle
    lpu_mac_per_cycle: int = 256      # per cluster
    clusters: int = CLUSTERS
    bsk_reuse: bool = True            # round-robin key reuse (paper)
    sync_groups: int = 1              # Obs. 5: grouped synchronization

    # -- per-ciphertext compute -------------------------------------------
    @property
    def t_ct_br(self) -> float:
        p = self.params
        cycles = p.n * (p.k + 1) ** 2 * p.pbs_level * p.N / (self.mac_per_cycle / 2)
        return cycles / GHZ

    @property
    def t_ct_ks(self) -> float:
        p = self.params
        cycles = p.big_n * p.ks_level * (p.n + 1) / self.lpu_mac_per_cycle
        return cycles / GHZ

    @property
    def t_ct_se(self) -> float:
        return self.params.big_n / self.lpu_mac_per_cycle / GHZ

    @property
    def pbs_latency(self) -> float:
        """Single-ciphertext bootstrap latency (12 in flight)."""
        return ROUND_ROBIN * self.t_ct_br

    # -- per-batch ----------------------------------------------------------
    def t_br_batch(self, b: Batch) -> float:
        per_cluster = -(-max(b.n_br, 0) // self.clusters)
        return per_cluster * self.t_ct_br

    def t_lpu_batch(self, b: Batch) -> float:
        ks = -(-b.n_ks // self.clusters) * self.t_ct_ks
        se = -(-b.n_se // self.clusters) * self.t_ct_se
        lin = b.lin_macs / (self.clusters * self.lpu_mac_per_cycle * GHZ)
        return ks + se + lin

    def runtime(self, sched: Schedule) -> tuple:
        return sched.runtime(self.t_br_batch, self.t_lpu_batch)

    # -- memory bandwidth (Fig. 13 / Obs. 5) ---------------------------------
    @property
    def bsk_bytes(self) -> float:
        p = self.params
        return p.n * (p.k + 1) ** 2 * p.pbs_level * (p.N // 2) * 12.0  # 48-bit cplx

    @property
    def ksk_bytes(self) -> float:
        p = self.params
        return p.big_n * p.ks_level * (p.n + 1) * 8.0

    @property
    def acc_bytes_per_ct(self) -> float:
        """Two GLWE accumulators per in-flight ciphertext, stored in the
        transform domain: (k+1) polys x N/2 complex coeffs x 12 B
        (48-bit re+im).  At the paper's GPT-2 params (N=32768, k=1) this
        gives exactly the 9216 KB default for 12 round-robin cts (Fig. 14).
        """
        p = self.params
        return 2 * (p.k + 1) * (p.N // 2) * 12.0

    @property
    def round_robin_eff(self) -> int:
        """In-flight ciphertexts per BRU, limited by the 9216 KB
        accumulator buffer at large N (the paper's Fig. 13b/14 trade)."""
        fit = int(ACC_BUF_BYTES // self.acc_bytes_per_ct)
        return max(1, min(ROUND_ROBIN, fit))

    @property
    def pbs_latency(self) -> float:  # override: depth-aware
        return self.round_robin_eff * self.t_ct_br

    def batch_bandwidth(self) -> dict:
        """Required DRAM bandwidth during one full BR batch.

        BSK chunks are shared across clusters (global buffer + NoC) and
        across the in-flight round-robin set; when fewer ciphertexts fit
        in the accumulator buffer (large N), the 12 per-core assignments
        run in ceil(12/rr_eff) waves and the BSK streams once per wave.
        """
        t = ROUND_ROBIN * self.t_ct_br        # full-batch BR time
        waves = -(-ROUND_ROBIN // self.round_robin_eff)
        streams = (waves * self.sync_groups) if self.bsk_reuse else BATCH
        bsk_bw = self.bsk_bytes * streams / t
        p = self.params
        lwe_bw = BATCH * (p.big_n + 1) * 8.0 / t
        return {"bsk": bsk_bw, "ksk": self.ksk_bytes / t,
                "lwe": lwe_bw, "waves": waves,
                "total": bsk_bw + self.ksk_bytes / t + lwe_bw}

    def bandwidth_bound_runtime(self, sched: Schedule) -> tuple:
        """Runtime including the DRAM-bandwidth ceiling (Fig. 14)."""
        t_comp, util = self.runtime(sched)
        bw = self.batch_bandwidth()["total"]
        scale = max(1.0, bw / HBM_BW)
        return t_comp * scale, util / scale


def xpu_model(params: TFHEParams) -> TaurusModel:
    """Morphling-style systolic-array variant (Table IV baseline).

    4 FFT rows x 8 coeffs/cycle; with k=1 only 2 of 4 PE columns are
    usable (Obs. 3), and there is no cross-ciphertext BSK reuse, so the
    effective MAC throughput is 8 coeffs * 2 rows * 4 SAs ~ 75/cycle
    after the bandwidth penalty of streaming BSK per ciphertext.
    """
    return TaurusModel(params, mac_per_cycle=75, bsk_reuse=False)


@dataclasses.dataclass
class CpuModel:
    """Concrete on a 48-core EPYC 7R13 (paper's baseline platform).

    Per-core PBS time = c1 * n*(k+1)^2*l*N*log2(N) * cache_penalty, where
    cache_penalty models the paper's §I observation that the scaled
    evaluation keys overflow L3 and stall on DRAM bandwidth:
    (bsk_bytes / L3)^0.5 once the BSK exceeds the 32 MB slice.

    Calibrated against Table II: CNN-20 gives ~92 ms/PBS/core at N=2048
    and GPT-2 ~6 s/PBS/core at N=32768; c1 = 8.5e-10 with the cache
    penalty reproduces both within ~1.5x.  NOTE: benchmarks compare
    Taurus primarily against the paper's MEASURED CPU/GPU seconds; this
    model is the analytic cross-check.
    """
    params: TFHEParams
    cores: int = 48
    c1: float = 8.5e-10
    l3_bytes: float = 32e6

    @property
    def t_ct_pbs(self) -> float:
        import math
        p = self.params
        units = p.n * (p.k + 1) ** 2 * p.pbs_level * p.N * math.log2(p.N)
        bsk = p.n * (p.k + 1) ** 2 * p.pbs_level * (p.N // 2) * 16.0  # f64 cplx
        penalty = max(1.0, (bsk / self.l3_bytes) ** 0.5)
        return self.c1 * units * penalty

    def runtime(self, sched: Schedule) -> float:
        t = 0.0
        for b in sched.batches:
            t += -(-b.n_br // self.cores) * self.t_ct_pbs
            t += b.lin_macs * self.params.big_n * 2e-12 / self.cores
        return t


@dataclasses.dataclass
class GpuModel:
    """Concrete-cuda on 2x RTX A5000 (paper's GPU baseline).

    GPUs batch PBS well but pay kernel-launch/transfer overheads on the
    serial chains; calibrated to the paper's observed 0.6-3x over CPU.
    """
    params: TFHEParams
    batch_throughput: int = 512       # ciphertexts bootstrapped per wave
    c_unit: float = 2.2e-11           # per n*(k+1)^2*l*N unit per wave
    overhead: float = 150e-6          # per dependent level

    @property
    def t_wave(self) -> float:
        p = self.params
        return p.n * (p.k + 1) ** 2 * p.pbs_level * p.N * self.c_unit

    def runtime(self, sched: Schedule) -> float:
        t = 0.0
        for b in sched.batches:
            t += -(-b.n_br // self.batch_throughput) * self.t_wave
            if b.dependent:
                t += self.overhead
            t += b.lin_macs * 5e-12
        return t
