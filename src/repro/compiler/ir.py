"""FHELinAlg-style tensor IR (paper Fig. 12: MLIR FHELinAlg dialect).

Values are ciphertext TENSORS (every element an LWE ciphertext); plaintext
constants ride along as numpy arrays.  Ops:

    input   (shape)
    add     (a, b)                    elementwise ct + ct     — no PBS
    sub     (a, b)                                            — no PBS
    addc    (a, const)                ct + plaintext          — no PBS
    mulc    (a, const)                ct * plaintext integer  — no PBS
    linear  (a, W[, b])               const-matrix matmul     — no PBS
    lut     (a, table)                elementwise PBS (the only op that
                                      bootstraps; bivariate LUTs are
                                      pre-combined linearly, footnote 4)
    concat/reshape                    layout only

The tracer below builds graphs from numpy-like code; `repro.fhe_ml`
lowers quantized transformer blocks into it, and `repro.compiler.passes`
lowers graphs to physical Taurus ops with both dedup passes applied.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

LINEAR_OPS = ("add", "sub", "addc", "mulc", "linear", "concat", "reshape")

# Radix wide-integer ops (repro.core.integer): a tensor whose LAST axis is
# the little-endian digit vector of a W-bit integer.  Each op expands into
# a fixed schedule of batched-PBS rounds; `radix_round_plan` is the single
# source of truth for that schedule, shared by the lowering in
# `repro.compiler.passes` and by PBS accounting here.
#
# `radix_linear` is the tensor-level op the fhe_ml quantize-to-radix
# bridge lowers linear layers to: a plaintext integer matmul ACROSS the
# vector axis of a (V, D) radix tensor (`IntegerContext.linear_compress`
# + per-output carry propagation).  Unlike the elementwise ops its round
# count depends on the weight matrix, so the node carries `term_maxes`
# (per-term digit ceilings of its worst output column) for the plan.
#
# `radix_addc` / `radix_mulc` are the LPU-ONLY plaintext-constant ops
# (no PBS round at all): they leave the result UN-PROPAGATED, with the
# per-digit plaintext ceiling tracked as the node's `max_val` attr —
# `RadixSpec.from_digits` decrypts such values exactly, so a program
# ending in const ops never bootstraps for them.  `radix_norm` is the
# explicit renormalization (`IntegerContext.propagate(max_val=...)`)
# the tracer inserts when an un-propagated value feeds a PBS op whose
# digit packing assumes values below base.
RADIX_OPS = ("radix_add", "radix_sub", "radix_mul", "radix_relu",
             "radix_cmp", "radix_linear", "radix_addc", "radix_mulc",
             "radix_norm")


def _ceil_log2(n: int) -> int:
    return max(0, (n - 1).bit_length())


def radix_round_plan(op: str, n_digits: int, msg_bits: Optional[int] = None,
                     width: Optional[int] = None,
                     term_maxes: Optional[tuple] = None,
                     max_val: Optional[int] = None) -> list:
    """Batched-PBS rounds of one radix op over a D-digit vector,
    mirroring the carry strategy `IntegerContext.propagate` auto-selects.
    Each round is a dict:
      luts     PBS applications in the round's single batch
      sources  distinct input ciphertexts feeding those LUTs (the
               key-switch count after KS-dedup: fanout shares one KS)
      tables   symbolic accumulator-table ids (ACC-dedup keys)
      macs     LPU MACs of the round's linear stitch-up

    msg_bits selects the carry strategy the runtime will take: the
    runtime decides on the parameter set's plaintext window, which the
    params-free IR does not know, so the model assumes the standard
    width = 2*msg_bits layout unless `width` is given explicitly.
    Wide windows (msg_bits >= 2 / width >= 4, or msg_bits None — the
    historical default) take the packed Hillis-Steele prefix scan;
    narrow windows take the two-level carry-lookahead scan where
    2 + 2*ceil(log2 D) beats D, else ripple.  (Base-2 programs were
    previously costed with the prefix plan, which under-counted their
    rounds.)  Single-digit vectors are one ripple extraction round for
    every strategy, exactly like the runtime.
    """
    d = n_digits

    def ripple_plan(rounds):
        return [{"luts": 2 * d, "sources": d,
                 "tables": ("radix/msg", "radix/carry"), "macs": d}
                for _ in range(rounds)]

    def add_plan():
        if d == 1:
            return ripple_plan(1)
        if width is not None:
            narrow = width < 4
        else:
            narrow = msg_bits == 1        # standard width = 2*msg_bits
        if not narrow:
            rounds = [{"luts": 2 * d, "sources": d,
                       "tables": ("radix/msg", "radix/sigma"), "macs": d}]
            for _ in range(_ceil_log2(d)):
                rounds.append({"luts": d, "sources": d,
                               "tables": ("radix/combine",), "macs": d})
            rounds.append({"luts": d, "sources": d,
                           "tables": ("radix/msg",), "macs": d})
            return rounds
        if 2 + 2 * _ceil_log2(d) < d:
            # two-level lookahead: status kept as (generate, propagate)
            # bit pairs, each scan level two batched bit-logic rounds
            rounds = [{"luts": 3 * d, "sources": d,
                       "tables": ("radix/msg", "radix/generate",
                                  "radix/propagate"), "macs": d}]
            dd = 1
            while dd < d:
                k = d - dd
                # round A: AND terms + propagate combine; lanes below the
                # scan distance refresh through the bit identity.  Every
                # row is a fresh LPU combination -> no KS sharing.
                rounds.append({"luts": 2 * k + dd, "sources": 2 * k + dd,
                               "tables": ("radix/bit_and", "radix/bit_or"),
                               "macs": 2 * k})
                # round B: fold the lookahead term into generate
                rounds.append({"luts": d, "sources": d,
                               "tables": ("radix/bit_or",), "macs": k})
                dd *= 2
            rounds.append({"luts": d, "sources": d,
                           "tables": ("radix/msg",), "macs": d})
            return rounds
        # ripple: D batched (msg, carry) extraction rounds
        return ripple_plan(d)

    if op in ("radix_addc", "radix_mulc"):
        return []                         # LPU-only: no PBS round at all
    if op == "radix_norm":
        # mirrors `IntegerContext.propagate(max_val=...)`: batched
        # (msg, carry) pre-extraction rounds fold the digit ceiling down
        # to 2*base-2, then the add-style carry scan finishes
        m = msg_bits if msg_bits is not None else 2
        w_eff = width if width is not None else 2 * m
        base = 1 << m
        mv = max_val if max_val is not None else (1 << w_eff) - 1
        rounds = []
        while mv > 2 * base - 2:
            mv = (base - 1) + (mv >> m)
            rounds.append({"luts": 2 * d, "sources": d,
                           "tables": ("radix/msg", "radix/carry"),
                           "macs": d})
        return rounds + add_plan()
    if op in ("radix_add", "radix_sub"):
        return add_plan()
    if op == "radix_linear":
        # Mirrors `IntegerContext.linear_compress`: the weighted digit
        # vectors are LPU-combined into per-output term lists; each round
        # greedily merges, per column, the terms whose summed digit
        # ceiling fits the plaintext window and extracts (msg, carry)
        # for the merged groups; the surviving terms then pre-reduce and
        # carry-propagate exactly like an add.  `term_maxes` is the
        # per-column tuple of per-term ceilings recorded on the node at
        # trace time (a flat tuple of ints is accepted as one column) —
        # compression rounds run until EVERY column is down to one term,
        # so the count is the max over columns, like the runtime.
        m = msg_bits if msg_bits is not None else 2
        w_eff = width if width is not None else 2 * m
        window = (1 << w_eff) - 1
        base = 1 << m
        extract = {"luts": 2 * d, "sources": d,
                   "tables": ("radix/msg", "radix/carry"), "macs": d}
        rounds = []
        if term_maxes and isinstance(term_maxes[0], (tuple, list)):
            cols = [sorted(c) if c else [0] for c in term_maxes]
        else:
            cols = [sorted(term_maxes) if term_maxes else [base - 1]]
        guard = 0
        max_rounds = 8 * (d + max(len(c) for c in cols)) + 8
        while any(len(c) > 1 for c in cols):
            guard += 1
            assert guard <= max_rounds, "radix_linear plan failed to converge"
            for c in cols:
                if len(c) < 2:
                    continue
                c.sort()
                taken, mx = 0, 0
                while taken < len(c) and mx + c[taken] <= window:
                    mx += c[taken]
                    taken += 1
                if taken < 2:
                    # no pair fits: solo-extract the LARGEST term
                    # (mirrors linear_compress — its ceiling shrinks)
                    mx = c.pop()
                else:
                    del c[:taken]
                c.append((base - 1) + (mx >> m))
            rounds.append(dict(extract))
        mv = max(c[0] for c in cols)
        while mv > 2 * base - 2:
            mv = (base - 1) + (mv >> m)
            rounds.append(dict(extract))
        return rounds + add_plan()
    if op == "radix_mul":
        t = d * (d + 1) // 2
        rounds = [{"luts": 2 * t, "sources": t,
                   "tables": ("radix/pp_lo", "radix/pp_hi"), "macs": 2 * t}]
        for _ in range(_ceil_log2(d) + 1):       # carry-save compression
            rounds.append({"luts": 2 * d, "sources": d,
                           "tables": ("radix/msg", "radix/carry"),
                           "macs": 2 * d})
        # no trailing propagation: with the standard msg/carry split the
        # compression already leaves every digit < base
        return rounds
    if op == "radix_relu":
        return [{"luts": 1, "sources": 1, "tables": ("radix/sign",), "macs": 0},
                {"luts": d, "sources": d, "tables": ("radix/mask",), "macs": d}]
    if op == "radix_cmp":
        rounds = [{"luts": d, "sources": d, "tables": ("radix/cmp",),
                   "macs": d}]
        n = d
        while n > 1:
            # odd lane counts: the leftover verdict passes through with no
            # PBS, so only floor(n/2) combines dispatch
            rounds.append({"luts": n // 2, "sources": n // 2,
                           "tables": ("radix/cmp_combine",), "macs": n // 2})
            n = -(-n // 2)
        return rounds
    raise ValueError(op)


def radix_vectors(node) -> int:
    """How many independent digit vectors a radix node processes.  cmp
    collapses the digit axis, so its OUTPUT already counts vectors."""
    if node.op == "radix_cmp":
        return node.n_elements
    return node.n_elements // node.attrs["n_digits"]


@dataclasses.dataclass
class Node:
    id: int
    op: str
    inputs: tuple            # node ids
    shape: tuple
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def n_elements(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclasses.dataclass
class Graph:
    nodes: list = dataclasses.field(default_factory=list)
    outputs: list = dataclasses.field(default_factory=list)

    def add(self, op: str, inputs: tuple, shape: tuple, **attrs) -> Node:
        node = Node(len(self.nodes), op, inputs, tuple(shape), attrs)
        self.nodes.append(node)
        return node

    def users(self) -> dict:
        out: dict = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for i in n.inputs:
                out[i].append(n.id)
        return out

    # -- statistics ---------------------------------------------------------
    def count(self, op: str) -> int:
        return sum(1 for n in self.nodes if n.op == op)

    def lut_applications(self) -> int:
        """Total element-level PBS operations (before any dedup)."""
        total = sum(n.n_elements for n in self.nodes if n.op == "lut")
        for n in self.nodes:
            if n.op in RADIX_OPS:
                total += radix_vectors(n) * sum(
                    r["luts"]
                    for r in radix_round_plan(
                        n.op, n.attrs["n_digits"], n.attrs.get("msg_bits"),
                        term_maxes=n.attrs.get("term_maxes"),
                        max_val=n.attrs.get("max_val")))
        return total


class FheTensor:
    """Tracing handle: numpy-like ops recorded into a Graph."""

    def __init__(self, graph: Graph, node: Node):
        self.graph = graph
        self.node = node

    @property
    def shape(self):
        return self.node.shape

    def _bin(self, other: "FheTensor", op: str) -> "FheTensor":
        assert self.shape == other.shape, (self.shape, other.shape)
        n = self.graph.add(op, (self.node.id, other.node.id), self.shape)
        return FheTensor(self.graph, n)

    def __add__(self, other):
        if isinstance(other, FheTensor):
            return self._bin(other, "add")
        n = self.graph.add("addc", (self.node.id,), self.shape,
                           const=np.asarray(other))
        return FheTensor(self.graph, n)

    def __sub__(self, other):
        if isinstance(other, FheTensor):
            return self._bin(other, "sub")
        return self + (-np.asarray(other))

    def __mul__(self, const):
        assert not isinstance(const, FheTensor), \
            "ct*ct needs a bivariate LUT — use lut2()"
        n = self.graph.add("mulc", (self.node.id,), self.shape,
                           const=np.asarray(const))
        return FheTensor(self.graph, n)

    def linear(self, W: np.ndarray, bias: Optional[np.ndarray] = None):
        """x @ W (+ bias): W integer plaintext (in_dim, out_dim)."""
        assert self.shape[-1] == W.shape[0]
        shape = self.shape[:-1] + (W.shape[1],)
        n = self.graph.add("linear", (self.node.id,), shape, W=W, bias=bias)
        return FheTensor(self.graph, n)

    def lut(self, table: np.ndarray, name: str = ""):
        """Elementwise programmable bootstrap with `table`."""
        n = self.graph.add("lut", (self.node.id,), self.shape,
                           table=np.asarray(table), name=name)
        return FheTensor(self.graph, n)

    def lut2(self, other: "FheTensor", table: np.ndarray, radix: int,
             name: str = ""):
        """Bivariate LUT (paper footnote 4): combine linearly then one PBS.
        encoded = a * radix + b; table indexed by the combined value."""
        comb = (self * radix)._bin(other, "add")
        return comb.lut(table, name=name)

    def reshape(self, *shape):
        n = self.graph.add("reshape", (self.node.id,), shape)
        return FheTensor(self.graph, n)

    # -- radix wide-integer ops (last axis = digit vector) ------------------
    def _radix_bin(self, other: "FheTensor", op: str, msg_bits: int):
        assert self.shape == other.shape and self.shape, (
            "radix ops need matching digit-vector shapes")
        n = self.graph.add(op, (self.node.id, other.node.id), self.shape,
                           msg_bits=msg_bits, n_digits=self.shape[-1])
        return FheTensor(self.graph, n)

    def radix_add(self, other, msg_bits: int):
        """Carry-propagated wide-integer add over the digit axis."""
        return self._radix_bin(other, "radix_add", msg_bits)

    def radix_sub(self, other, msg_bits: int):
        return self._radix_bin(other, "radix_sub", msg_bits)

    def radix_mul(self, other, msg_bits: int):
        """Schoolbook wide-integer product mod 2^(msg_bits * D)."""
        return self._radix_bin(other, "radix_mul", msg_bits)

    def radix_relu(self, msg_bits: int):
        """Two's-complement max(x, 0) over the digit vector."""
        n = self.graph.add("radix_relu", (self.node.id,), self.shape,
                           msg_bits=msg_bits, n_digits=self.shape[-1])
        return FheTensor(self.graph, n)

    def radix_linear(self, W: np.ndarray, msg_bits: int) -> "FheTensor":
        """Plaintext integer matmul ACROSS the vector axis of a radix
        tensor: out[j] = sum_i W[i, j] * self[i] mod 2^bits, each output
        vector carry-propagated back below base.

        Input shape (V_in, D) -> output (W.shape[1], D); W is an integer
        (V_in, V_out) matrix (negative weights lower through the base
        complement, so two's-complement semantics hold as long as the
        true accumulator magnitude stays below 2^(bits-1) — the
        `repro.fhe_ml.quantize` range check enforces that bound)."""
        W = np.asarray(W, np.int64)
        assert len(self.shape) == 2 and self.shape[0] == W.shape[0], (
            f"radix_linear needs a (V_in, D) digit tensor matching W rows: "
            f"{self.shape} vs W {W.shape}")
        d = self.shape[-1]
        base = 1 << msg_bits
        # per-column per-term digit ceilings, recorded for
        # `radix_round_plan`: |w|*(base-1) per nonzero weight, plus one
        # trivial term carrying the two's-complement +|w| constants when
        # the column has negative weights (compression rounds run until
        # every column is reduced, so the plan needs them all)
        cols = []
        for j in range(W.shape[1]):
            col = [abs(int(w)) * (base - 1) for w in W[:, j] if w]
            if bool((W[:, j] < 0).any()):
                col.append(base - 1)
            cols.append(tuple(col) if col else (0,))
        n = self.graph.add("radix_linear", (self.node.id,),
                           (W.shape[1], d), W=W, msg_bits=msg_bits,
                           n_digits=d, term_maxes=tuple(cols))
        return FheTensor(self.graph, n)

    def radix_addc(self, const: int, msg_bits: int,
                   max_val: int) -> "FheTensor":
        """Add a plaintext constant digitwise — LPU only, NO carry
        propagation: the result's per-digit ceiling is `max_val`
        (recorded on the node; `from_digits` still decrypts exactly)."""
        n = self.graph.add("radix_addc", (self.node.id,), self.shape,
                           const=int(const), msg_bits=msg_bits,
                           n_digits=self.shape[-1], max_val=int(max_val))
        return FheTensor(self.graph, n)

    def radix_mulc(self, const: int, msg_bits: int,
                   max_val: int) -> "FheTensor":
        """Multiply by a non-negative plaintext integer digitwise — LPU
        only, NO carry propagation (`max_val` = resulting digit ceiling)."""
        n = self.graph.add("radix_mulc", (self.node.id,), self.shape,
                           const=int(const), msg_bits=msg_bits,
                           n_digits=self.shape[-1], max_val=int(max_val))
        return FheTensor(self.graph, n)

    def radix_norm(self, msg_bits: int, max_val: int) -> "FheTensor":
        """Carry-propagate an un-normalized digit vector back below base
        (`max_val` = the INPUT's digit ceiling, what the runtime's
        `propagate(max_val=...)` receives)."""
        n = self.graph.add("radix_norm", (self.node.id,), self.shape,
                           msg_bits=msg_bits, n_digits=self.shape[-1],
                           max_val=int(max_val))
        return FheTensor(self.graph, n)

    def radix_cmp(self, other, msg_bits: int):
        """Three-way compare -> one ciphertext per digit vector."""
        assert self.shape == other.shape and self.shape
        n = self.graph.add("radix_cmp", (self.node.id, other.node.id),
                           self.shape[:-1] + (1,),
                           msg_bits=msg_bits, n_digits=self.shape[-1])
        return FheTensor(self.graph, n)


def trace(fn, *input_shapes):
    """Run `fn(x1, x2, ...)` on tracing tensors; returns the Graph."""
    g = Graph()
    args = [FheTensor(g, g.add("input", (), s)) for s in input_shapes]
    out = fn(*args)
    outs = out if isinstance(out, (tuple, list)) else (out,)
    g.outputs = [t.node.id for t in outs]
    return g
