"""FHELinAlg-style tensor IR (paper Fig. 12: MLIR FHELinAlg dialect).

Values are ciphertext TENSORS (every element an LWE ciphertext); plaintext
constants ride along as numpy arrays.  Ops:

    input   (shape)
    add     (a, b)                    elementwise ct + ct     — no PBS
    sub     (a, b)                                            — no PBS
    addc    (a, const)                ct + plaintext          — no PBS
    mulc    (a, const)                ct * plaintext integer  — no PBS
    linear  (a, W[, b])               const-matrix matmul     — no PBS
    lut     (a, table)                elementwise PBS (the only op that
                                      bootstraps; bivariate LUTs are
                                      pre-combined linearly, footnote 4)
    concat/reshape                    layout only

The tracer below builds graphs from numpy-like code; `repro.fhe_ml`
lowers quantized transformer blocks into it, and `repro.compiler.passes`
lowers graphs to physical Taurus ops with both dedup passes applied.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

LINEAR_OPS = ("add", "sub", "addc", "mulc", "linear", "concat", "reshape")


@dataclasses.dataclass
class Node:
    id: int
    op: str
    inputs: tuple            # node ids
    shape: tuple
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def n_elements(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclasses.dataclass
class Graph:
    nodes: list = dataclasses.field(default_factory=list)
    outputs: list = dataclasses.field(default_factory=list)

    def add(self, op: str, inputs: tuple, shape: tuple, **attrs) -> Node:
        node = Node(len(self.nodes), op, inputs, tuple(shape), attrs)
        self.nodes.append(node)
        return node

    def users(self) -> dict:
        out: dict = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for i in n.inputs:
                out[i].append(n.id)
        return out

    # -- statistics ---------------------------------------------------------
    def count(self, op: str) -> int:
        return sum(1 for n in self.nodes if n.op == op)

    def lut_applications(self) -> int:
        """Total element-level PBS operations (before any dedup)."""
        return sum(n.n_elements for n in self.nodes if n.op == "lut")


class FheTensor:
    """Tracing handle: numpy-like ops recorded into a Graph."""

    def __init__(self, graph: Graph, node: Node):
        self.graph = graph
        self.node = node

    @property
    def shape(self):
        return self.node.shape

    def _bin(self, other: "FheTensor", op: str) -> "FheTensor":
        assert self.shape == other.shape, (self.shape, other.shape)
        n = self.graph.add(op, (self.node.id, other.node.id), self.shape)
        return FheTensor(self.graph, n)

    def __add__(self, other):
        if isinstance(other, FheTensor):
            return self._bin(other, "add")
        n = self.graph.add("addc", (self.node.id,), self.shape,
                           const=np.asarray(other))
        return FheTensor(self.graph, n)

    def __sub__(self, other):
        if isinstance(other, FheTensor):
            return self._bin(other, "sub")
        return self + (-np.asarray(other))

    def __mul__(self, const):
        assert not isinstance(const, FheTensor), \
            "ct*ct needs a bivariate LUT — use lut2()"
        n = self.graph.add("mulc", (self.node.id,), self.shape,
                           const=np.asarray(const))
        return FheTensor(self.graph, n)

    def linear(self, W: np.ndarray, bias: Optional[np.ndarray] = None):
        """x @ W (+ bias): W integer plaintext (in_dim, out_dim)."""
        assert self.shape[-1] == W.shape[0]
        shape = self.shape[:-1] + (W.shape[1],)
        n = self.graph.add("linear", (self.node.id,), shape, W=W, bias=bias)
        return FheTensor(self.graph, n)

    def lut(self, table: np.ndarray, name: str = ""):
        """Elementwise programmable bootstrap with `table`."""
        n = self.graph.add("lut", (self.node.id,), self.shape,
                           table=np.asarray(table), name=name)
        return FheTensor(self.graph, n)

    def lut2(self, other: "FheTensor", table: np.ndarray, radix: int,
             name: str = ""):
        """Bivariate LUT (paper footnote 4): combine linearly then one PBS.
        encoded = a * radix + b; table indexed by the combined value."""
        comb = (self * radix)._bin(other, "add")
        return comb.lut(table, name=name)

    def reshape(self, *shape):
        n = self.graph.add("reshape", (self.node.id,), shape)
        return FheTensor(self.graph, n)


def trace(fn, *input_shapes):
    """Run `fn(x1, x2, ...)` on tracing tensors; returns the Graph."""
    g = Graph()
    args = [FheTensor(g, g.add("input", (), s)) for s in input_shapes]
    out = fn(*args)
    outs = out if isinstance(out, (tuple, list)) else (out,)
    g.outputs = [t.node.id for t in outs]
    return g
