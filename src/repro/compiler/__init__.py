"""Taurus companion compiler (paper §V).

FHELinAlg-style tensor IR + tracing, the two deduplication passes
(KS-dedup, ACC-dedup), the batch scheduler with BRU/LPU overlap, and the
calibrated Taurus cycle/bandwidth cost model that reproduces Tables II/IV
and Figures 13/15.
"""
from repro.compiler.ir import Graph, FheTensor, trace  # noqa: F401
from repro.compiler.passes import lower_to_physical, DedupStats  # noqa: F401
from repro.compiler.schedule import Schedule, build_schedule  # noqa: F401
from repro.compiler.cost import TaurusModel, CpuModel, GpuModel  # noqa: F401
