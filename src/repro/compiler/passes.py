"""Lowering + the paper's two deduplication passes (§V, Observation 6).

Physical op stream (what the scheduler/cost model consume):

    KS   one key-switch of one ciphertext  (LPU)
    BR   one blind rotation               (BRU)  — carries its LUT table id
    SE   one sample extraction            (LPU)
    LIN  bulk linear work                 (LPU)  — MAC count attached

KS-dedup: Taurus runs PBS key-switching-FIRST, so when several `lut`
nodes consume the SAME tensor (fanout), the key-switched small-LWE
ciphertexts are computed once and broadcast to every blind rotation
(paper: up to 47.12% fewer key-switches).

ACC-dedup: `lut` nodes applying the same table to many tensor elements
share one GLWE test-polynomial accumulator image in DRAM instead of one
per element (paper: −91.54% GLWE storage).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.compiler.ir import (Graph, Node, RADIX_OPS, radix_round_plan,
                               radix_vectors)


@dataclasses.dataclass
class PhysOp:
    kind: str                # KS | BR | SE | LIN
    node: int                # producing IR node
    count: int               # ciphertext elements covered
    level: int               # dependency level (scheduling)
    macs: int = 0            # for LIN: plaintext-ct MACs
    table_id: int = 0        # for BR: which accumulator image


@dataclasses.dataclass
class DedupStats:
    ks_before: int = 0
    ks_after: int = 0
    acc_before: int = 0
    acc_after: int = 0

    @property
    def ks_saved_frac(self) -> float:
        return 1.0 - self.ks_after / self.ks_before if self.ks_before else 0.0

    @property
    def acc_saved_frac(self) -> float:
        return 1.0 - self.acc_after / self.acc_before if self.acc_before else 0.0


def _levels(g: Graph) -> dict:
    """Dependency depth per node.  A radix op spans as many levels as it
    has batched-PBS rounds, so chained radix ops serialize correctly in
    the schedule."""
    lvl = {}
    for n in g.nodes:
        depth = (len(radix_round_plan(n.op, n.attrs["n_digits"],
                                      n.attrs.get("msg_bits"),
                                      term_maxes=n.attrs.get("term_maxes"),
                                      max_val=n.attrs.get("max_val")))
                 if n.op in RADIX_OPS else 1)
        lvl[n.id] = depth + max((lvl[i] for i in n.inputs), default=-1)
    return lvl


def _table_key(t: np.ndarray) -> bytes:
    return np.ascontiguousarray(t).tobytes()


def fused_round_dedup(pair_keys) -> tuple:
    """Online (serving-time) extension of the KS/ACC dedup passes.

    The static passes above dedup within ONE compiled graph.  When a
    serving scheduler fuses the ready PBS rounds of many concurrent
    requests into a single engine batch, the same observation applies
    across requests: two batch rows with an identical
    (ciphertext-digest, table-digest) pair are the SAME bootstrap —
    key-switch, blind rotation and sample extraction included — so the
    round dispatches it once and fans the refreshed ciphertext back out
    (retried/replayed requests dedup to zero marginal PBS work).

    pair_keys: one hashable (ct_key, table_key) per fused batch row.
    Returns (unique_idx, inverse, hits): the row indices to dispatch,
    the scatter map (inverse[i] indexes the dispatched results to rebuild
    row i), and how many rows were deduplicated away.
    """
    first: dict = {}
    unique_idx: list = []
    inverse: list = []
    for i, key in enumerate(pair_keys):
        if key not in first:
            first[key] = len(unique_idx)
            unique_idx.append(i)
        inverse.append(first[key])
    return unique_idx, inverse, len(inverse) - len(unique_idx)


def lower_to_physical(g: Graph, *, ks_dedup: bool = True,
                      acc_dedup: bool = True):
    """Graph -> (list[PhysOp], DedupStats).

    Key-switch placement: with the KS-first order, the key-switch belongs
    to the PBS *input* tensor.  Without dedup every `lut` node key-switches
    its own copy; with dedup all luts sharing an input share one KS.
    """
    lvl = _levels(g)
    ops: list = []
    stats = DedupStats()
    ks_done: set = set()          # input node ids already key-switched
    tables: dict = {}             # table bytes -> id

    for n in g.nodes:
        if n.op == "lut":
            src = n.inputs[0]
            stats.ks_before += n.n_elements
            if (src not in ks_done) or not ks_dedup:
                ops.append(PhysOp("KS", n.id, n.n_elements, lvl[src] + 1))
                stats.ks_after += n.n_elements
                ks_done.add(src)
            # accumulator image(s)
            stats.acc_before += n.n_elements
            key = _table_key(n.attrs["table"])
            if acc_dedup:
                if key not in tables:
                    tables[key] = len(tables)
                    stats.acc_after += 1
                tid = tables[key]
            else:
                stats.acc_after += n.n_elements
                tid = len(tables)
                tables[_table_key(n.attrs["table"]) + bytes([tid % 251])] = tid
            ops.append(PhysOp("BR", n.id, n.n_elements, lvl[n.id],
                              table_id=tid))
            ops.append(PhysOp("SE", n.id, n.n_elements, lvl[n.id]))
        elif n.op in ("radix_addc", "radix_mulc"):
            # LPU-only const ops: one MAC per digit, zero PBS rounds
            ops.append(PhysOp("LIN", n.id, n.n_elements, lvl[n.id],
                              macs=n.n_elements))
        elif n.op in RADIX_OPS:
            # one KS/BR/SE wave per batched round (see ir.radix_round_plan).
            # Within a round the (msg, carry)-style LUT fanout reads the
            # SAME digit ciphertexts, so KS-dedup collapses `luts` key-
            # switches down to `sources` — the digit-batch analogue of the
            # tensor-fanout dedup above.
            vecs = radix_vectors(n)
            plan = radix_round_plan(n.op, n.attrs["n_digits"],
                                    n.attrs.get("msg_bits"),
                                    term_maxes=n.attrs.get("term_maxes"),
                                    max_val=n.attrs.get("max_val"))
            base_lvl = lvl[n.id] - len(plan) + 1
            if n.op == "radix_linear":
                # the LPU weight combine that precedes the rounds: one
                # D-digit scalar-mul/add per nonzero weight
                macs = int(np.count_nonzero(n.attrs["W"])) \
                    * n.attrs["n_digits"]
                ops.append(PhysOp("LIN", n.id, macs, max(base_lvl - 1, 0),
                                  macs=macs))
            for r, rd in enumerate(plan):
                luts = rd["luts"] * vecs
                srcs = rd["sources"] * vecs
                stats.ks_before += luts
                ks_n = srcs if ks_dedup else luts
                stats.ks_after += ks_n
                ops.append(PhysOp("KS", n.id, ks_n, base_lvl + r))
                stats.acc_before += luts
                tid = 0
                if acc_dedup:
                    for tkey in rd["tables"]:
                        key = tkey.encode()
                        if key not in tables:
                            tables[key] = len(tables)
                            stats.acc_after += 1
                        tid = tables[key]
                else:
                    stats.acc_after += luts
                    tid = len(tables)
                    tables[rd["tables"][0].encode() + bytes([tid % 251])] = tid
                ops.append(PhysOp("BR", n.id, luts, base_lvl + r,
                                  table_id=tid))
                ops.append(PhysOp("SE", n.id, luts, base_lvl + r))
                if rd.get("macs"):
                    ops.append(PhysOp("LIN", n.id, rd["macs"] * vecs,
                                      base_lvl + r, macs=rd["macs"] * vecs))
        elif n.op == "linear":
            W = n.attrs["W"]
            macs = n.n_elements * W.shape[0]
            ops.append(PhysOp("LIN", n.id, n.n_elements, lvl[n.id], macs=macs))
        elif n.op in ("add", "sub", "addc", "mulc"):
            ops.append(PhysOp("LIN", n.id, n.n_elements, lvl[n.id],
                              macs=n.n_elements))
        # input/reshape/concat: free
    return ops, stats
