"""Table-II workload graphs (paper §VI-C), built through the tracer.

Graph structure mirrors each application's published shape (e.g. the
decision tree is the paper's 91-node/18-depth scikit-learn model); tensor
sizes are chosen so the resulting PBS counts land at Taurus runtimes in
the paper's reported range — the *ratios* (CPU/GPU/XPU speedups, dedup
percentages, utilization-vs-batch curves) are what the benchmarks check.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.compiler.ir import Graph, FheTensor, trace
from repro.core.params import PAPER_PARAMS, TFHEParams


def _table(width: int, fn) -> np.ndarray:
    n = 1 << width
    return np.asarray([fn(i) % n for i in range(n)], dtype=np.uint64)


def relu_table(width):
    half = 1 << (width - 1)
    return _table(width, lambda i: i if i < half else 0)


def gelu_table(width):
    half = 1 << (width - 1)

    def f(i):
        x = (i - half) / half * 4.0
        y = x * 0.5 * (1 + math.tanh(0.7978845 * (x + 0.044715 * x ** 3)))
        return int(round((y / 4.0) * half + half))
    return _table(width, f)


def exp_table(width):
    n = 1 << width
    return _table(width, lambda i: int(round(math.exp((i - n // 2) / (n // 4)) * 4)))


def recip_table(width):
    n = 1 << width
    return _table(width, lambda i: n // (i + 1))


def square_table(width):
    n = 1 << width
    return _table(width, lambda i: (i * i) >> width)


def cmp_table(width, thr):
    return _table(width, lambda i: 1 if i >= thr else 0)


def _rng(seed):
    return np.random.default_rng(seed)


def _int_w(rng, shape, lo=-3, hi=4):
    return rng.integers(lo, hi, shape).astype(np.int64)


# ---------------------------------------------------------------------------

def cnn(n_layers: int, hw: int, ch: int, width: int, seed=0) -> Graph:
    """PTQ CNN: n_layers x (linear conv-as-matmul + ReLU LUT)."""
    rng = _rng(seed)
    relu = relu_table(width)
    feat = hw * hw * ch

    def f(x):
        for i in range(n_layers):
            x = x.linear(_int_w(rng, (feat, feat)))
            x = x.lut(relu, name=f"relu{i}")
        return x.linear(_int_w(rng, (feat, 10)))
    return trace(f, (feat,))


def gpt2_block_graph(n_layers: int, seq: int, d: int, n_heads: int,
                     width: int, seed=0) -> Graph:
    """Quantized GPT-2: per layer QKV linear, ct*ct attention via square
    LUTs ((a+b)^2 - (a-b)^2)/4, softmax exp+recip LUTs, GELU MLP.

    Concrete-style detail: the requantization after each matmul applies a
    second (digit/carry) LUT to the SAME ciphertext the activation LUT
    reads — the fanout pattern KS-dedup exploits (Obs. 6)."""
    rng = _rng(seed)
    gelu = gelu_table(width)
    expt = exp_table(width)
    rcp = recip_table(width)
    sq = square_table(width)
    carry = _table(width, lambda i: i >> (width // 2))

    def ct_dot(a: FheTensor, b: FheTensor):
        """ct.ct inner product via the square trick: 2 LUTs per element."""
        s = (a + b).lut(sq, name="sq+")
        dif = (a - b).lut(sq, name="sq-")
        return s - dif

    def f(x):  # x: (seq, d)
        for li in range(n_layers):
            q = x.linear(_int_w(rng, (d, d)))
            k = x.linear(_int_w(rng, (d, d)))
            v = x.linear(_int_w(rng, (d, d)))
            for h in range(n_heads):
                s = ct_dot(q, k)                          # (seq, d) elementwise
                s = s.linear(_int_w(rng, (d, seq), 0, 2))  # fold hd -> scores
                e = s.lut(expt, name="exp")
                _hi = s.lut(carry, name="exp_carry")       # fanout on s
                z = e.linear(np.ones((seq, 1), np.int64))
                zi = z.lut(rcp, name="recip")
                if h == 0:
                    e0, zi0 = e, zi
            # prob * V: ct*ct again (square trick), folded to (seq, d)
            pv = ct_dot(e0.linear(_int_w(rng, (seq, d), 0, 2)), v)
            x = x + pv.linear(_int_w(rng, (d, d)))
            h1 = x.linear(_int_w(rng, (d, 4 * d)))
            a1 = h1.lut(gelu, name="gelu")
            _c1 = h1.lut(carry, name="gelu_carry")         # fanout on h1
            x = x + a1.linear(_int_w(rng, (4 * d, d)))
        return x
    return trace(f, (seq, d))


def decision_tree_graph(n_nodes: int, depth: int, width: int,
                        n_features: int = 16, seed=0) -> Graph:
    """Paper's tree: 91 nodes / 18 depth.  Every node compares ONE scalar
    feature ciphertext against its threshold — all comparisons run in one
    parallel wave (same feature ct fans out to many cmp LUTs: KS-dedup),
    then a log-depth bivariate-AND tree aggregates path indicators."""
    rng = _rng(seed)
    and_t = _table(width, lambda i: 1 if i == 3 else 0)   # a*2+b == 3

    def f(*feats):  # n_features x (1,) ciphertexts
        comps = [feats[int(rng.integers(0, n_features))].lut(
            cmp_table(width, int(rng.integers(1, 1 << width))),
            name=f"cmp{i}") for i in range(n_nodes)]
        level = comps
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level) - 1, 2):
                nxt.append(level[i].lut2(level[i + 1], and_t, radix=2,
                                         name="and"))
            if len(level) % 2:
                nxt.append(level[-1])
            level = nxt
        return level[0]
    return trace(f, *([(1,)] * n_features))


def knn_graph(n_train: int, k: int, width: int, n_features: int = 8,
              seed=0) -> Graph:
    """KNN: parallel distance computation, then a mostly-SERIAL tournament
    top-k (the latency-bound workload: only 3.2x over the XPU variant)."""
    rng = _rng(seed)
    sq = square_table(width)
    half = width // 2
    min2 = _table(width, lambda i: min(i >> half, i % (1 << half)))

    def f(*feats):
        dists = []
        for i in range(n_train):
            parts = [(feats[j] + int(rng.integers(0, 4))).lut(sq, name="sq")
                     for j in range(n_features)]
            acc = parts[0]
            for p in parts[1:]:
                acc = acc + p
            dists.append(acc * 1)
        # k rounds of tournament min-reduction (serial across rounds)
        sel = dists
        for _ in range(k):
            level = sel
            while len(level) > 1:
                nxt = []
                for i in range(0, len(level) - 1, 2):
                    nxt.append(level[i].lut2(level[i + 1], min2,
                                             radix=1 << half, name="min"))
                if len(level) % 2:
                    nxt.append(level[-1])
                level = nxt
            sel = sel[1:]  # winner removed; next round over the rest
        return level[0]
    return trace(f, *([(1,)] * n_features))


def xgboost_graph(n_trees: int, depth: int, width: int, n_features: int = 16,
                  seed=0) -> Graph:
    """50 estimators x depth 4: all trees evaluate in parallel (the
    highest-utilization workload, Fig. 15)."""
    rng = _rng(seed)
    nodes_per_tree = 2 ** depth - 1
    and_t = _table(width, lambda i: 1 if i == 3 else 0)

    def f(*feats):
        leaves = []
        for t in range(n_trees):
            comps = [feats[int(rng.integers(0, n_features))].lut(
                cmp_table(width, int(rng.integers(1, 1 << width))),
                name="cmp") for _ in range(nodes_per_tree)]
            acc = comps[0]
            for c in comps[1:depth]:
                acc = acc.lut2(c, and_t, radix=2, name="and")
            leaves.append(acc)
        out = leaves[0]
        for l in leaves[1:]:
            out = out + l
        return out
    return trace(f, *([(1,)] * n_features))


# ---------------------------------------------------------------------------
# wide-integer (radix) workloads — the "beyond Table II" direction: the
# multi-bit digit space carries 16/32-bit integers, every carry round one
# PBS batch (repro.core.integer).  No paper reference numbers; these feed
# the dedup/scheduler/cost pipeline (exercised by tests/test_compiler.py).

def wide_add_graph(bits: int = 32, msg_bits: int = 4) -> Graph:
    d = bits // msg_bits

    def f(a, b):
        return a.radix_add(b, msg_bits)
    return trace(f, (d,), (d,))


def wide_mul_graph(bits: int = 16, msg_bits: int = 4) -> Graph:
    d = bits // msg_bits

    def f(a, b):
        return a.radix_mul(b, msg_bits)
    return trace(f, (d,), (d,))


def wide_affine_relu_graph(bits: int = 16, msg_bits: int = 4) -> Graph:
    """ReLU(a * w + b): the quantized-inference inner loop on wide ints."""
    d = bits // msg_bits

    def f(a, w, b):
        return a.radix_mul(w, msg_bits).radix_add(b, msg_bits).radix_relu(
            msg_bits)
    return trace(f, (d,), (d,), (d,))


def build_wide() -> dict:
    """name -> (graph, params); xgboost's 8-bit space gives 4-bit digits."""
    p = PAPER_PARAMS["xgboost"]
    return {
        "wide_add32": (wide_add_graph(32, 4), p),
        "wide_mul16": (wide_mul_graph(16, 4), p),
        "wide_affine_relu16": (wide_affine_relu_graph(16, 4), p),
    }


@dataclasses.dataclass
class Workload:
    name: str
    graph: Graph
    params: TFHEParams
    paper_cpu_s: float
    paper_gpu_s: float | None
    paper_taurus_ms: float
    paper_xpu_ms: float


def build_all() -> dict:
    P = PAPER_PARAMS
    return {
        "cnn20": Workload("CNN-20 (PTQ)", cnn(20, 5, 4, 6), P["cnn20"],
                          3.85, 6.096, 11.60, 78.65),
        "cnn50": Workload("CNN-50 (PTQ)", cnn(50, 6, 4, 6), P["cnn50"],
                          15.31, 49.714, 74.27, 506.27),
        "decision_tree": Workload("Decision Tree",
                                  decision_tree_graph(91, 18, 9),
                                  P["decision_tree"],
                                  645.40, 522.2351, 409.19, 2794.60),
        "gpt2": Workload("GPT2", gpt2_block_graph(12, 4, 16, 1, 6),
                         P["gpt2"], 1218.13, 721.14, 860.94, 5851.00),
        "gpt2_12head": Workload("GPT2 (12-head)",
                                gpt2_block_graph(12, 4, 16, 12, 6),
                                P["gpt2_12head"],
                                23685.14, None, 10649.33, 75219.27),
        "knn": Workload("KNN", knn_graph(30, 3, 9), P["knn"],
                        284.69, 204.6, 306.66, 982.49),
        "xgboost": Workload("XGBoost Reg", xgboost_graph(50, 4, 8),
                            P["xgboost"], 1793.27, 912.11, 689.29, 4749.30),
    }
