"""Batch scheduler with BRU/LPU overlap (paper §IV-B, Fig. 9).

Taurus schedules at batch granularity: 48 ciphertexts per batch (12
round-robin x 4 clusters), full synchronization across clusters
(Observation 5).  The compiler groups blind rotations into batches by
dependency level; LPU work (key-switch, sample-extract, linear ops) of
batch i+1 overlaps the BRU time of batch i when the levels allow it —
dependent consecutive batches serialize (Fig. 9, batches 4/5).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro.compiler.passes import PhysOp


@dataclasses.dataclass
class Batch:
    level: int
    n_br: int = 0               # blind rotations in this batch
    n_ks: int = 0
    n_se: int = 0
    lin_macs: int = 0
    dependent: bool = False     # depends on the previous batch's output


@dataclasses.dataclass
class Schedule:
    batches: list
    batch_size: int

    @property
    def total_pbs(self) -> int:
        return sum(b.n_br for b in self.batches)

    def runtime(self, t_br_batch, t_lpu_batch) -> tuple:
        """Pipelined runtime given per-batch cost callables.

        t_br_batch(b) / t_lpu_batch(b): seconds for the BRU / LPU portion
        of one batch.  Independent batches overlap LPU(i+1) with BRU(i);
        dependent ones serialize (Fig. 9).  Returns (seconds, utilization).
        """
        t = 0.0
        busy_br = 0.0
        prev_br_end = 0.0
        for b in self.batches:
            lpu = t_lpu_batch(b)
            br = t_br_batch(b)
            if b.dependent:
                start = prev_br_end + lpu          # must wait, then KS
            else:
                start = max(prev_br_end, t + lpu)  # LPU overlapped
            prev_br_end = start + br
            t = start
            busy_br += br
        total = prev_br_end
        util = busy_br / total if total else 0.0
        return total, util


def build_schedule(ops: list, batch_size: int = 48) -> Schedule:
    """Group physical ops into level-synchronous batches of <= batch_size
    blind rotations (plus their KS/SE and the level's linear work)."""
    by_level: dict = defaultdict(lambda: {"br": 0, "ks": 0, "se": 0, "macs": 0})
    for op in ops:
        s = by_level[op.level]
        if op.kind == "BR":
            s["br"] += op.count
        elif op.kind == "KS":
            s["ks"] += op.count
        elif op.kind == "SE":
            s["se"] += op.count
        else:
            s["macs"] += op.macs

    batches: list = []
    for level in sorted(by_level):
        s = by_level[level]
        n = max(s["br"], 1)
        n_batches = -(-s["br"] // batch_size) if s["br"] else (1 if s["macs"] else 0)
        for i in range(max(n_batches, 1) if (s["br"] or s["macs"]) else 0):
            frac = min(batch_size, s["br"] - i * batch_size) / n if s["br"] else 0
            batches.append(Batch(
                level=level,
                n_br=min(batch_size, max(s["br"] - i * batch_size, 0)),
                n_ks=int(s["ks"] * frac),
                n_se=int(s["se"] * frac),
                lin_macs=s["macs"] // max(n_batches, 1),
                # first batch of a level depends on the previous level
                dependent=(i == 0),
            ))
    return Schedule(batches, batch_size)
