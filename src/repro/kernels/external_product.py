"""BRU transform-domain MAC kernel (paper Fig. 7 bottom).

Computes, for a whole round-robin batch of ciphertexts against ONE shared
BSK slice (the key-reuse strategy):

    out[b, k, f] = sum_j dig[b, j, f] * bsk[j, k, f]        (complex)

with j = (k_dim+1)*level decomposition rows, k = k_dim+1 output polys,
f the transform-domain coefficient.  The BSK block is loaded into VMEM
once per grid step and consumed by every ciphertext in the batch —
arithmetic intensity on the BSK stream scales with B, which is exactly
why Taurus round-robins 12 ciphertexts per core.

Layouts (stacked re/im planes, f32 or f64 via `dtype`):
    dig: (B, 2, J, F)     bsk: (2, J, K, F)     out: (B, 2, K, F)
The grid tiles F (VMEM-sized chunks, multiples of 128 lanes).  The
fused PBS engine (`repro.kernels.fused_pbs`) keeps the BSK operand
RESIDENT in this transform-domain plane layout across every round of a
fused batch — the decomposition + transform is paid once per key, not
once per round.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dig_ref, bsk_ref, o_ref):
    dr = dig_ref[:, 0]            # (B, J, Fb)
    di = dig_ref[:, 1]
    wr = bsk_ref[0]               # (J, K, Fb)
    wi = bsk_ref[1]
    # out[b,k,f] = sum_j d[b,j,f] * w[j,k,f]
    outr = jnp.einsum("bjf,jkf->bkf", dr, wr) - jnp.einsum("bjf,jkf->bkf", di, wi)
    outi = jnp.einsum("bjf,jkf->bkf", dr, wi) + jnp.einsum("bjf,jkf->bkf", di, wr)
    o_ref[:, 0] = outr
    o_ref[:, 1] = outi


@functools.partial(jax.jit, static_argnames=("block_f", "interpret", "dtype"))
def external_product_mac(dig: jax.Array, bsk: jax.Array, *,
                         block_f: int = 2048, interpret: bool = True,
                         dtype=jnp.float32) -> jax.Array:
    """dig (B,2,J,F), bsk (2,J,K,F) -> (B,2,K,F), stacked re/im planes.

    `dtype` selects the plane precision: f32 is the TPU-native mode; the
    fused engine path runs f64 planes (interpret mode) so the MAC error
    stays far below the scheme's noise budget on 64-bit torus operands.
    """
    B, _, J, F = dig.shape
    _, _, K, _ = bsk.shape
    dtype = jnp.dtype(dtype)
    bf = min(block_f, F)
    assert F % bf == 0
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((B, 2, K, F), dtype),
        grid=(F // bf,),
        in_specs=[
            pl.BlockSpec((B, 2, J, bf), lambda f: (0, 0, 0, f)),
            pl.BlockSpec((2, J, K, bf), lambda f: (0, 0, 0, f)),
        ],
        out_specs=pl.BlockSpec((B, 2, K, bf), lambda f: (0, 0, 0, f)),
        interpret=interpret,
    )(dig.astype(dtype), bsk.astype(dtype))
