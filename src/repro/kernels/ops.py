"""Jit'd public wrappers over the Pallas kernels.

`interpret=True` everywhere in this container (CPU); on a real TPU the
flag flips to False with identical call signatures.

`dtype` selects the transform-plane precision: f32 is the TPU-native
mode, f64 is what the fused engine path (`repro.kernels.fused_pbs`)
runs so the 64-bit torus noise budget holds in interpret mode.  The
keyswitch MAC is uint32-limb exact regardless.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import fourstep_fft, external_product, keyswitch, ref

INTERPRET = True  # no TPU in this container; see DESIGN.md §5


def negacyclic_fft(x: jax.Array, *, dtype=jnp.float32) -> jax.Array:
    """Forward negacyclic transform, (B, N) real -> (B, 2, N/2) planes."""
    return fourstep_fft.fft_forward(x, interpret=INTERPRET, dtype=dtype)


def negacyclic_ifft(spec: jax.Array, *, dtype=jnp.float32) -> jax.Array:
    """(B, 2, M) -> (B, 2M) plane-dtype coefficients."""
    return fourstep_fft.fft_inverse(spec, interpret=INTERPRET, dtype=dtype)


def bru_mac(dig: jax.Array, bsk: jax.Array, *, block_f: int = 2048,
            dtype=jnp.float32) -> jax.Array:
    """Blind-rotation MAC: (B,2,J,F) x (2,J,K,F) -> (B,2,K,F)."""
    return external_product.external_product_mac(
        dig, bsk, block_f=block_f, interpret=INTERPRET, dtype=dtype
    )


def lpu_keyswitch_mac(digits: jax.Array, ksk_u64: jax.Array,
                      *, block_s: int = 1024) -> jax.Array:
    """digits (B,S) int32 x ksk (S,T) uint64 -> (B,T) uint64 (mod 2^64)."""
    hi, lo = ref.split_u64(ksk_u64)
    ohi, olo = keyswitch.keyswitch_mac(
        digits, hi, lo, block_s=block_s, interpret=INTERPRET
    )
    return ref.merge_u64(ohi, olo)
