"""LPU key-switch MAC kernel: 64-bit torus arithmetic from uint32 limbs.

TPU has no uint64 — the paper's LPU is a 64-bit integer vector unit, so
the TPU adaptation synthesizes mod-2^64 arithmetic from uint32 limb pairs
(hi, lo) with explicit carries.  16-bit sub-limb partial products keep
every intermediate inside uint32.

Computes   acc[b, t] = sum_{s} d[b, s] * K[s, t]   (mod 2^64)

where s flattens (n_from, level), d are signed gadget digits (int32,
interpreted mod 2^64 as two's complement), and K is the key-switching key
as (hi, lo) uint32 planes.  The caller forms  out = (0..0, b) - acc.

Accumulation strategy (fully vectorized, no sequential carries): partial
products are accumulated per 16-bit lane into uint32 accumulators, then
lanes are recombined with carry propagation once per block.  A block of
S_BLK <= 4096 terms keeps every lane accumulator < 2^32.  Blocks combine
across grid steps mod 2^64 (sequential grid accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

U32 = np.uint32
MASK16 = np.uint32(0xFFFF)


def _mul64(du_hi, du_lo, k_hi, k_lo):
    """(du_hi,du_lo) * (k_hi,k_lo) mod 2^64, all uint32, via 16-bit parts.

    Broadcasting: du_* are (..., 1), k_* are (S, T)-shaped blocks.
    Returns (hi, lo) uint32.
    """
    a0 = du_lo & MASK16
    a1 = du_lo >> U32(16)
    b0 = k_lo & MASK16
    b1 = k_lo >> U32(16)
    # full 64-bit product of the two low words
    p00 = a0 * b0                       # < 2^32
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1
    mid = p01 + p10                     # may wrap: detect carry
    mid_c = (mid < p01).astype(U32)     # carry into bit 32 of (mid << 16)
    lo = p00 + (mid << U32(16))
    lo_c = (lo < p00).astype(U32)
    hi = p11 + (mid >> U32(16)) + (mid_c << U32(16)) + lo_c
    # cross terms only affect the high word (mod 2^64)
    hi = hi + du_lo * k_hi + du_hi * k_lo
    return hi, lo


def _kernel(d_ref, khi_ref, klo_ref, ohi_ref, olo_ref):
    sblk = d_ref.shape[1]
    d = d_ref[0]                                 # (S,) int32 digits
    du_lo = d.astype(U32)[:, None]               # two's complement low word
    du_hi = (d >> 31).astype(U32)[:, None]       # sign-extension high word
    k_hi = khi_ref[...]                          # (S, T)
    k_lo = klo_ref[...]
    p_hi, p_lo = _mul64(du_hi, du_lo, k_hi, k_lo)

    # lane-wise accumulation: sum 16-bit lanes of p_lo/p_hi in uint32.
    # Each lane sum < S_BLK * 2^16 <= 2^28 for S_BLK <= 4096.
    s_lo0 = jnp.sum(p_lo & MASK16, axis=0, dtype=U32)
    s_lo1 = jnp.sum(p_lo >> U32(16), axis=0, dtype=U32)
    s_hi0 = jnp.sum(p_hi & MASK16, axis=0, dtype=U32)
    s_hi1 = jnp.sum(p_hi >> U32(16), axis=0, dtype=U32)
    # recombine with carries
    blk_lo = s_lo0 + (s_lo1 << U32(16))
    carry = (s_lo1 + (s_lo0 >> U32(16))) >> U32(16)
    blk_hi = s_hi0 + (s_hi1 << U32(16)) + carry

    @pl.when(pl.program_id(1) == 0)
    def _init():
        ohi_ref[...] = jnp.zeros_like(ohi_ref)
        olo_ref[...] = jnp.zeros_like(olo_ref)

    acc_lo = olo_ref[0] + blk_lo
    acc_hi = ohi_ref[0] + blk_hi + (acc_lo < olo_ref[0]).astype(U32)
    olo_ref[0] = acc_lo
    ohi_ref[0] = acc_hi


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def keyswitch_mac(digits: jax.Array, ksk_hi: jax.Array, ksk_lo: jax.Array, *,
                  block_s: int = 1024, interpret: bool = True):
    """digits (B, S) int32, ksk_hi/lo (S, T) uint32 -> (hi, lo) (B, T) uint32.

    S flattens (n_from * level); T = n_to + 1.  When S is not a multiple
    of the block size, digits and key rows are zero-padded up to one —
    zero digits contribute nothing to the MAC, so the result is
    unchanged (the fused engine path hits this whenever
    big_n * ks_level is not block-aligned).
    """
    B, S = digits.shape
    _, T = ksk_hi.shape
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        zeros_d = jnp.zeros((B, pad), dtype=digits.dtype)
        zeros_k = jnp.zeros((pad, T), dtype=ksk_hi.dtype)
        digits = jnp.concatenate([digits, zeros_d], axis=1)
        ksk_hi = jnp.concatenate([ksk_hi, zeros_k], axis=0)
        ksk_lo = jnp.concatenate([ksk_lo, zeros_k], axis=0)
        S += pad
    assert S % bs == 0 and bs <= 4096
    grid = (B, S // bs)
    out_shape = [
        jax.ShapeDtypeStruct((B, T), U32),
        jax.ShapeDtypeStruct((B, T), U32),
    ]
    return pl.pallas_call(
        _kernel,
        out_shape=out_shape,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs), lambda b, s: (b, s)),
            pl.BlockSpec((bs, T), lambda b, s: (s, 0)),
            pl.BlockSpec((bs, T), lambda b, s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T), lambda b, s: (b, 0)),
            pl.BlockSpec((1, T), lambda b, s: (b, 0)),
        ],
        interpret=interpret,
    )(digits.astype(jnp.int32), ksk_hi, ksk_lo)
