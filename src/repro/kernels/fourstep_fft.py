"""Four-step negacyclic FFT as MXU matmuls (paper §IV-C, adapted to TPU).

The paper factors its 2^15-point double-real FFT into heterogeneous
256-point (FFT-A) and 128-point (FFT-B) units joined by a shutter
transpose.  On TPU the same factorization M = R*C maps onto the MXU:

    stage A:  DFT_R  @ X      (column transforms — one matmul)
    twiddle:  elementwise W^(k1*c)
    stage B:  X @ DFT_C^T     (row transforms — one matmul)

The shutter-transpose becomes the (free) matmul operand layout change.
Complex arithmetic is carried as separate re/im f32 planes (stacked
axis), i.e. 4 real matmuls per complex matmul.

Layout contract (matches `repro.core.fft` up to dtype):
    forward:  real coeffs (B, N) -> spectrum (B, 2, M), M = N/2,
              spectrum[m] = FFT_M(fold+twist(x))[m]
    inverse:  spectrum (B, 2, M) -> real coeffs (B, N)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def factor_m(M: int) -> tuple[int, int]:
    """Pick R*C = M mirroring the paper's 256x128 for M = 2^15."""
    assert M & (M - 1) == 0 and M >= 4
    lg = M.bit_length() - 1
    r = min(256, 1 << ((lg + 1) // 2))
    return r, M // r


@functools.lru_cache(maxsize=16)
def _constants(N: int, inverse: bool):
    """Precompute twist, DFT matrices, twiddles as stacked re/im f32."""
    M = N // 2
    R, C = factor_m(M)
    j = np.arange(M)
    twist = np.exp(1j * np.pi * j / N)                       # fold twist
    dft_r = np.exp(-2j * np.pi * np.outer(np.arange(R), np.arange(R)) / R)
    dft_c = np.exp(-2j * np.pi * np.outer(np.arange(C), np.arange(C)) / C)
    tw = np.exp(-2j * np.pi * np.outer(np.arange(R), np.arange(C)) / M)
    if inverse:
        dft_r, dft_c, tw, twist = (
            np.conj(dft_r) / R, np.conj(dft_c) / C, np.conj(tw), np.conj(twist))
    # NB: cache plain numpy (never jnp) — a jnp constant created inside a
    # jit trace is a Tracer and would leak through the lru_cache.
    as32 = lambda z: np.stack([z.real, z.imag]).astype(np.float32)
    return R, C, as32(twist), as32(dft_r), as32(dft_c), as32(tw)


def _cmatmul(ar, ai, br, bi):
    """(ar+i*ai) @ (br+i*bi) with f32 accumulation on the MXU."""
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def _fwd_kernel(x_ref, twist_ref, dr_ref, dc_ref, tw_ref, o_ref, *, R, C, M):
    x = x_ref[0]                                   # (N,) real coeffs
    # fold + twist: u = (x_lo + i x_hi) * twist
    ur = x[:M] * twist_ref[0] - x[M:] * twist_ref[1]
    ui = x[:M] * twist_ref[1] + x[M:] * twist_ref[0]
    ar, ai = ur.reshape(R, C), ui.reshape(R, C)
    # stage A (FFT-A analogue): column DFT via MXU
    er, ei = _cmatmul(dr_ref[0], dr_ref[1], ar, ai)
    # twiddle (between-stage rotation)
    br = er * tw_ref[0] - ei * tw_ref[1]
    bi = er * tw_ref[1] + ei * tw_ref[0]
    # stage B (FFT-B analogue): row DFT; transpose-of-output IS the
    # paper's shutter transpose, folded into the store layout.
    fr, fi = _cmatmul(br, bi, dc_ref[0].T, dc_ref[1].T)
    o_ref[0, 0] = fr.T.reshape(M)
    o_ref[0, 1] = fi.T.reshape(M)


def _inv_kernel(s_ref, twist_ref, dr_ref, dc_ref, tw_ref, o_ref, *, R, C, M):
    sr = s_ref[0, 0].reshape(C, R).T               # undo output transpose
    si = s_ref[0, 1].reshape(C, R).T
    # inverse stage B
    br, bi = _cmatmul(sr, si, dc_ref[0].T, dc_ref[1].T)
    # un-twiddle
    er = br * tw_ref[0] - bi * tw_ref[1]
    ei = br * tw_ref[1] + bi * tw_ref[0]
    # inverse stage A
    ar, ai = _cmatmul(dr_ref[0], dr_ref[1], er, ei)
    ur, ui = ar.reshape(M), ai.reshape(M)
    # untwist + unfold
    xr = ur * twist_ref[0] - ui * twist_ref[1]
    xi = ur * twist_ref[1] + ui * twist_ref[0]
    o_ref[0] = jnp.concatenate([xr, xi])


def _const_specs(R, C, M):
    full = lambda shape: pl.BlockSpec(shape, lambda b: (0,) * len(shape))
    return [
        full((2, M)),          # twist
        full((2, R, R)),       # DFT_R
        full((2, C, C)),       # DFT_C
        full((2, R, C)),       # twiddle
    ]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fft_forward(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Negacyclic forward transform: real (B, N) f32 -> (B, 2, N/2) f32."""
    B, N = x.shape
    M = N // 2
    R, C = factor_m(M)
    _, _, twist, dr, dc, tw = _constants(N, inverse=False)
    kernel = functools.partial(_fwd_kernel, R=R, C=C, M=M)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, 2, M), jnp.float32),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N), lambda b: (b, 0))] + _const_specs(R, C, M),
        out_specs=pl.BlockSpec((1, 2, M), lambda b: (b, 0, 0)),
        interpret=interpret,
    )(x.astype(jnp.float32), twist, dr, dc, tw)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fft_inverse(spec: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Inverse: (B, 2, M) f32 -> real coeffs (B, 2M) f32."""
    B, _, M = spec.shape
    N = 2 * M
    R, C = factor_m(M)
    _, _, twist, dr, dc, tw = _constants(N, inverse=True)
    kernel = functools.partial(_inv_kernel, R=R, C=C, M=M)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, 2, M), lambda b: (b, 0, 0))] + _const_specs(R, C, M),
        out_specs=pl.BlockSpec((1, N), lambda b: (b, 0)),
        interpret=interpret,
    )(spec.astype(jnp.float32), twist, dr, dc, tw)
