"""Four-step negacyclic FFT as MXU matmuls (paper §IV-C, adapted to TPU).

The paper factors its 2^15-point double-real FFT into heterogeneous
256-point (FFT-A) and 128-point (FFT-B) units joined by a shutter
transpose.  On TPU the same factorization M = R*C maps onto the MXU:

    stage A:  DFT_R  @ X      (column transforms — one matmul)
    twiddle:  elementwise W^(k1*c)
    stage B:  X @ DFT_C^T     (row transforms — one matmul)

The shutter-transpose becomes the (free) matmul operand layout change.
Complex arithmetic is carried as separate re/im planes (stacked axis),
i.e. 4 real matmuls per complex matmul.

Precision: the kernel is dtype-polymorphic.  f32 is the TPU-native
plane dtype (benchmark/standalone mode; relative error ~2e-5 of the
spectrum scale).  The fused PBS engine path (`repro.kernels.fused_pbs`)
runs the SAME kernel with f64 planes — interpret mode executes f64
natively, and the scheme's noise budget needs the f64 accuracy for
64-bit torus operands (a hardware TPU deployment would swap in the
split-plane fixed-point path of the paper's Obs. 4 instead).

Layout contract (matches `repro.core.fft` up to dtype):
    forward:  real coeffs (B, N) -> spectrum (B, 2, M), M = N/2,
              spectrum[m] = FFT_M(fold+twist(x))[m]
    inverse:  spectrum (B, 2, M) -> real coeffs (B, N)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def factor_m(M: int) -> tuple[int, int]:
    """Pick R*C = M mirroring the paper's 256x128 for M = 2^15."""
    assert M & (M - 1) == 0 and M >= 4
    lg = M.bit_length() - 1
    r = min(256, 1 << ((lg + 1) // 2))
    return r, M // r


@functools.lru_cache(maxsize=32)
def _constants(N: int, inverse: bool, dtype_name: str = "float32"):
    """Precompute twist, DFT matrices, twiddles as stacked re/im planes."""
    M = N // 2
    R, C = factor_m(M)
    j = np.arange(M)
    twist = np.exp(1j * np.pi * j / N)                       # fold twist
    dft_r = np.exp(-2j * np.pi * np.outer(np.arange(R), np.arange(R)) / R)
    dft_c = np.exp(-2j * np.pi * np.outer(np.arange(C), np.arange(C)) / C)
    tw = np.exp(-2j * np.pi * np.outer(np.arange(R), np.arange(C)) / M)
    if inverse:
        dft_r, dft_c, tw, twist = (
            np.conj(dft_r) / R, np.conj(dft_c) / C, np.conj(tw), np.conj(twist))
    # NB: cache plain numpy (never jnp) — a jnp constant created inside a
    # jit trace is a Tracer and would leak through the lru_cache.
    as_planes = lambda z: np.stack([z.real, z.imag]).astype(dtype_name)
    return R, C, as_planes(twist), as_planes(dft_r), as_planes(dft_c), as_planes(tw)


def _cmatmul(ar, ai, br, bi, acc_dtype):
    """(ar+i*ai) @ (br+i*bi) with plane-dtype accumulation on the MXU."""
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=acc_dtype)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def _fwd_kernel(x_ref, twist_ref, dr_ref, dc_ref, tw_ref, o_ref, *, R, C, M,
                acc_dtype):
    x = x_ref[0]                                   # (N,) real coeffs
    # fold + twist: u = (x_lo + i x_hi) * twist
    ur = x[:M] * twist_ref[0] - x[M:] * twist_ref[1]
    ui = x[:M] * twist_ref[1] + x[M:] * twist_ref[0]
    ar, ai = ur.reshape(R, C), ui.reshape(R, C)
    # stage A (FFT-A analogue): column DFT via MXU
    er, ei = _cmatmul(dr_ref[0], dr_ref[1], ar, ai, acc_dtype)
    # twiddle (between-stage rotation)
    br = er * tw_ref[0] - ei * tw_ref[1]
    bi = er * tw_ref[1] + ei * tw_ref[0]
    # stage B (FFT-B analogue): row DFT; transpose-of-output IS the
    # paper's shutter transpose, folded into the store layout.
    fr, fi = _cmatmul(br, bi, dc_ref[0].T, dc_ref[1].T, acc_dtype)
    o_ref[0, 0] = fr.T.reshape(M)
    o_ref[0, 1] = fi.T.reshape(M)


def _inv_kernel(s_ref, twist_ref, dr_ref, dc_ref, tw_ref, o_ref, *, R, C, M,
                acc_dtype):
    sr = s_ref[0, 0].reshape(C, R).T               # undo output transpose
    si = s_ref[0, 1].reshape(C, R).T
    # inverse stage B
    br, bi = _cmatmul(sr, si, dc_ref[0].T, dc_ref[1].T, acc_dtype)
    # un-twiddle
    er = br * tw_ref[0] - bi * tw_ref[1]
    ei = br * tw_ref[1] + bi * tw_ref[0]
    # inverse stage A
    ar, ai = _cmatmul(dr_ref[0], dr_ref[1], er, ei, acc_dtype)
    ur, ui = ar.reshape(M), ai.reshape(M)
    # untwist + unfold
    xr = ur * twist_ref[0] - ui * twist_ref[1]
    xi = ur * twist_ref[1] + ui * twist_ref[0]
    o_ref[0] = jnp.concatenate([xr, xi])


def _const_specs(R, C, M):
    full = lambda shape: pl.BlockSpec(shape, lambda b: (0,) * len(shape))
    return [
        full((2, M)),          # twist
        full((2, R, R)),       # DFT_R
        full((2, C, C)),       # DFT_C
        full((2, R, C)),       # twiddle
    ]


@functools.partial(jax.jit, static_argnames=("interpret", "dtype"))
def fft_forward(x: jax.Array, *, interpret: bool = True,
                dtype=jnp.float32) -> jax.Array:
    """Negacyclic forward transform: real (B, N) -> (B, 2, N/2) planes."""
    B, N = x.shape
    M = N // 2
    R, C = factor_m(M)
    dtype = jnp.dtype(dtype)
    _, _, twist, dr, dc, tw = _constants(N, False, dtype.name)
    kernel = functools.partial(_fwd_kernel, R=R, C=C, M=M, acc_dtype=dtype)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, 2, M), dtype),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N), lambda b: (b, 0))] + _const_specs(R, C, M),
        out_specs=pl.BlockSpec((1, 2, M), lambda b: (b, 0, 0)),
        interpret=interpret,
    )(x.astype(dtype), twist, dr, dc, tw)


@functools.partial(jax.jit, static_argnames=("interpret", "dtype"))
def fft_inverse(spec: jax.Array, *, interpret: bool = True,
                dtype=jnp.float32) -> jax.Array:
    """Inverse: (B, 2, M) planes -> real coeffs (B, 2M)."""
    B, _, M = spec.shape
    N = 2 * M
    R, C = factor_m(M)
    dtype = jnp.dtype(dtype)
    _, _, twist, dr, dc, tw = _constants(N, True, dtype.name)
    kernel = functools.partial(_inv_kernel, R=R, C=C, M=M, acc_dtype=dtype)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, N), dtype),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, 2, M), lambda b: (b, 0, 0))] + _const_specs(R, C, M),
        out_specs=pl.BlockSpec((1, N), lambda b: (b, 0)),
        interpret=interpret,
    )(spec.astype(dtype), twist, dr, dc, tw)
