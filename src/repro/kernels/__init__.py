"""Pallas TPU kernels for the compute hot-spots the paper builds silicon for.

  fourstep_fft      — the paper's heterogeneous FFT-A(256)xFFT-B(128)
                      cluster, recast as MXU matmuls (four-step FFT).
  external_product  — the BRU transform-domain MAC with round-robin
                      (batched) BSK reuse.
  keyswitch         — the LPU key-switch MAC; 64-bit torus arithmetic
                      synthesized from uint32 limbs (TPU has no u64).
  fused_pbs         — the three kernels fused into the batched PBS hot
                      path with resident transform-domain keys; this is
                      what `TaurusEngine(kernel_backend="pallas")` runs.

Each kernel ships jit wrappers in `ops.py` and a pure-jnp oracle in
`ref.py`; tests sweep shapes/dtypes in interpret mode and grade the
fused path differentially against the reference engine.
"""
