"""The fused Pallas PBS engine room: kernels wired into one hot path.

This module is what `TaurusEngine(kernel_backend="pallas")` runs.  It
fuses the three Pallas kernels into the batched KS-first PBS pipeline
(paper Fig. 3, steps A-D) with the paper's key-reuse strategy made
explicit as RESIDENT operands:

    keyswitch     `kernels.keyswitch` — uint32-limb 64-bit MAC over the
                  gadget digits of the whole batch (exact mod 2^64, so
                  this stage is BIT-IDENTICAL to `repro.core.lwe`).
    blind rotate  per scan step: decompose the CMux difference, forward
                  `kernels.fourstep_fft`, one `kernels.external_product`
                  MAC against the resident BSK slice, inverse FFT back
                  to torus coefficients.
    extract       `repro.core.glwe.sample_extract` (LPU layout work).

`FusedPbsPack` is the residency contract: the Fourier BSK is decomposed
into the kernels' stacked re/im plane layout ONCE per key, and the KSK
is limb-split into (hi, lo) uint32 planes ONCE — every subsequent
`lut_batch` round of every fused wave consumes the same device arrays.
That is the paper's §III-B round-robin key reuse: arithmetic intensity
on the key stream scales with the fused batch size because the operand
never has to be re-derived (and on hardware, re-fetched) per round.

Precision: the transform-domain planes default to f64.  Interpret mode
(this container) executes f64 natively and the 64-bit torus needs it —
an f32-only transform would put ~2^60+ of error into the accumulator,
voiding decryption.  On a real TPU the same kernels run f32 planes with
the paper's 48-bit fixed-point operand split (Obs. 4); the `dtype`
switch is the seam where that lands.  The keyswitch limb kernel is
uint32 end to end and therefore exact on any hardware.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import decompose as dec, glwe, lwe, torus
from repro.core import batch as batch_mod
from repro.core.params import TFHEParams
from repro.kernels import external_product, fourstep_fft, keyswitch, ref

U64 = jnp.uint64


def bsk_to_planes(bsk_f: jax.Array, dtype=jnp.float64) -> jax.Array:
    """Fourier BSK (n, k+1, level, k+1, M) complex -> kernel plane layout
    (n, 2, J, K, M) with J = (k+1)*level rows matching the decomposition
    order `external_product_mac` consumes."""
    n, kp1, level, _, M = bsk_f.shape
    flat = bsk_f.reshape(n, kp1 * level, kp1, M)
    return jnp.stack([flat.real, flat.imag], axis=1).astype(dtype)


def ksk_to_limbs(ksk: jax.Array) -> tuple[jax.Array, jax.Array]:
    """KSK (n_from, level, n_to+1) uint64 -> (hi, lo) (S, T) uint32 limb
    planes, S = n_from*level flattened in the digit order
    `lwe.keyswitch` contracts over."""
    n_from, level, t = ksk.shape
    return ref.split_u64(ksk.reshape(n_from * level, t))


@functools.partial(jax.jit,
                   static_argnames=("params", "block_s", "interpret"))
def keyswitch_fused(big_cts: jax.Array, ksk_hi: jax.Array, ksk_lo: jax.Array,
                    params: TFHEParams, *, block_s: int = 1024,
                    interpret: bool = True) -> jax.Array:
    """Batched big->small key switch through the limb MAC kernel.

    (B, big_n+1) -> (B, n+1); exact mod 2^64, bit-identical to
    `lwe.keyswitch` (pinned by tests/test_kernels.py).
    """
    a, b = big_cts[..., :-1], big_cts[..., -1]
    digits = dec.decompose(a, params.ks_base_log, params.ks_level)
    digits = digits.reshape(digits.shape[0], -1).astype(jnp.int32)
    hi, lo = keyswitch.keyswitch_mac(digits, ksk_hi, ksk_lo,
                                     block_s=block_s, interpret=interpret)
    out = -ref.merge_u64(hi, lo)
    return out.at[..., -1].add(b)


def external_product_planes(bsk_i: jax.Array, glwe_cts: jax.Array,
                            params: TFHEParams, *, dtype=jnp.float64,
                            block_f: int = 2048,
                            interpret: bool = True) -> jax.Array:
    """One resident BSK slice (2, J, K, M) applied to a GLWE batch
    (B, K, N) — decompose, forward FFT kernel, BRU MAC kernel, inverse
    FFT kernel, back onto the torus."""
    B, K, N = glwe_cts.shape
    M = N // 2
    J = K * params.pbs_level
    digs = dec.decompose(glwe_cts, params.pbs_base_log, params.pbs_level)
    digs = jnp.moveaxis(digs, -1, -2).reshape(B, J, N)      # (B, K, level, N)
    spec = fourstep_fft.fft_forward(digs.reshape(B * J, N).astype(dtype),
                                    interpret=interpret, dtype=dtype)
    dig_planes = spec.reshape(B, J, 2, M).transpose(0, 2, 1, 3)
    out = external_product.external_product_mac(
        dig_planes, bsk_i, block_f=min(block_f, M), interpret=interpret,
        dtype=dtype)                                        # (B, 2, K, M)
    coeffs = fourstep_fft.fft_inverse(
        out.transpose(0, 2, 1, 3).reshape(B * K, 2, M),
        interpret=interpret, dtype=dtype)
    return torus.float_to_torus(coeffs.astype(jnp.float64)).reshape(B, K, N)


@functools.partial(jax.jit,
                   static_argnames=("params", "dtype", "block_f", "interpret"))
def blind_rotate_fused(lut_glwes: jax.Array, ms_cts: jax.Array,
                       bsk_planes: jax.Array, params: TFHEParams, *,
                       dtype=jnp.float64, block_f: int = 2048,
                       interpret: bool = True) -> jax.Array:
    """Batched blind rotation over the RESIDENT plane-layout BSK.

    lut_glwes (B, k+1, N); ms_cts (B, n+1) mod-switched to [0, 2N);
    bsk_planes (n, 2, J, K, M) — scanned once, shared by the whole
    batch (the fused wave's key-reuse MAC).
    """
    N = params.N
    a, b = ms_cts[:, :-1], ms_cts[:, -1]
    acc = batch_mod.rotate_batch(lut_glwes, (2 * N - b) % (2 * N), N)

    def step(acc, inp):
        a_i, bsk_i = inp                                    # a_i: (B,)
        rotated = batch_mod.rotate_batch(acc, a_i, N)
        acc = acc + external_product_planes(
            bsk_i, rotated - acc, params, dtype=dtype, block_f=block_f,
            interpret=interpret)
        return acc, None

    acc, _ = jax.lax.scan(step, acc, (a.T, bsk_planes))
    return acc


@functools.partial(jax.jit,
                   static_argnames=("params", "dtype", "block_f", "block_s",
                                    "interpret"))
def pbs_batch_fused(big_cts: jax.Array, lut_polys: jax.Array,
                    bsk_planes: jax.Array, ksk_hi: jax.Array,
                    ksk_lo: jax.Array, params: TFHEParams, *,
                    dtype=jnp.float64, block_f: int = 2048,
                    block_s: int = 1024, interpret: bool = True) -> jax.Array:
    """The fused fast path for `TaurusEngine.lut_batch`:
    (B, k*N+1) + (B, N) LUT polys -> (B, k*N+1), all four PBS stages on
    the Pallas kernels with resident key operands."""
    small = keyswitch_fused(big_cts, ksk_hi, ksk_lo, params,
                            block_s=block_s, interpret=interpret)
    ms = lwe.mod_switch(small, params.log2_N + 1)
    luts = glwe.trivial(lut_polys, params.k)
    acc = blind_rotate_fused(luts, ms, bsk_planes, params, dtype=dtype,
                             block_f=block_f, interpret=interpret)
    return glwe.sample_extract(acc)


@functools.partial(jax.jit,
                   static_argnames=("params", "dtype", "block_f",
                                    "interpret"))
def pbs_small_fused(small_cts: jax.Array, lut_polys: jax.Array,
                    bsk_planes: jax.Array, params: TFHEParams, *,
                    dtype=jnp.float64, block_f: int = 2048,
                    interpret: bool = True) -> jax.Array:
    """`pbs_batch_fused` minus the keyswitch: (B, n+1) small-key cts +
    (B, N) LUT polys -> (B, k*N+1).  `keyswitch_fused` followed by this
    function runs exactly the stages of `pbs_batch_fused`, so the
    serving scheduler's KS-level partial dedup (key-switch unique
    ciphertexts once, blind-rotate every table) stays decrypt-identical
    on the pallas backend too."""
    ms = lwe.mod_switch(small_cts, params.log2_N + 1)
    luts = glwe.trivial(lut_polys, params.k)
    acc = blind_rotate_fused(luts, ms, bsk_planes, params, dtype=dtype,
                             block_f=block_f, interpret=interpret)
    return glwe.sample_extract(acc)


@dataclasses.dataclass
class FusedPbsPack:
    """Resident kernel operands for one evaluation-key pair.

    Built once per engine (`TaurusEngine` caches it on first pallas
    `lut_batch`) and reused by every subsequent round — the arrays here
    ARE the key-reuse residency the paper banks on, so tests assert the
    same objects service multiple rounds.
    """
    params: TFHEParams
    bsk_planes: jax.Array            # (n, 2, J, K, M) dtype planes
    ksk_hi: jax.Array                # (S, T) uint32
    ksk_lo: jax.Array                # (S, T) uint32
    dtype: object = jnp.float64
    block_f: int = 2048
    block_s: int = 1024
    interpret: bool = True

    @classmethod
    def build(cls, bsk_f: jax.Array, ksk: jax.Array, params: TFHEParams, *,
              dtype=jnp.float64, block_f: int = 2048, block_s: int = 1024,
              interpret: bool = True) -> "FusedPbsPack":
        dtype = jnp.dtype(dtype)
        hi, lo = ksk_to_limbs(ksk)
        return cls(params, bsk_to_planes(bsk_f, dtype), hi, lo,
                   dtype=dtype, block_f=block_f, block_s=block_s,
                   interpret=interpret)

    # -- the engine entry points -------------------------------------------
    def pbs_batch(self, big_cts: jax.Array, lut_polys: jax.Array) -> jax.Array:
        return pbs_batch_fused(big_cts, lut_polys, self.bsk_planes,
                               self.ksk_hi, self.ksk_lo, self.params,
                               dtype=self.dtype, block_f=self.block_f,
                               block_s=self.block_s, interpret=self.interpret)

    def keyswitch(self, big_cts: jax.Array) -> jax.Array:
        return keyswitch_fused(big_cts, self.ksk_hi, self.ksk_lo, self.params,
                               block_s=self.block_s, interpret=self.interpret)

    def blind_rotate(self, lut_glwes: jax.Array,
                     ms_cts: jax.Array) -> jax.Array:
        return blind_rotate_fused(lut_glwes, ms_cts, self.bsk_planes,
                                  self.params, dtype=self.dtype,
                                  block_f=self.block_f,
                                  interpret=self.interpret)

    def pbs_from_small(self, small_cts: jax.Array,
                       lut_polys: jax.Array) -> jax.Array:
        """PBS resumed after `keyswitch`: the KS-level-dedup half-round."""
        return pbs_small_fused(small_cts, lut_polys, self.bsk_planes,
                               self.params, dtype=self.dtype,
                               block_f=self.block_f,
                               interpret=self.interpret)

    # -- bandwidth accounting (gated by launch/roofline.py) -----------------
    @property
    def resident_key_bytes(self) -> tuple[int, int]:
        """(bsk_bytes, ksk_bytes) of the resident operands — what one
        fused round streams from HBM exactly once, regardless of B."""
        bsk = int(self.bsk_planes.size) * self.bsk_planes.dtype.itemsize
        ksk = (int(self.ksk_hi.size) + int(self.ksk_lo.size)) * 4
        return bsk, ksk

    def bytes_streamed_per_round(self, batch: int) -> int:
        """Key-reuse traffic model of ONE fused `lut_batch` round: the
        resident keys once, plus per-ciphertext input/LUT/output rows.
        `launch.roofline.pbs_round_model` computes the same quantity
        analytically; `benchmarks/kernels_bench.py` asserts this never
        exceeds that bound."""
        p = self.params
        bsk, ksk = self.resident_key_bytes
        per_ct = (2 * (p.big_n + 1) + p.N) * 8   # ct in + ct out + LUT poly
        return bsk + ksk + batch * per_ct
