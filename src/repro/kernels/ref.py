"""Pure-jnp oracles for every Pallas kernel (the grading contract).

These run in f64 / uint64 (CPU gold path) and define bit-level or
tolerance-level expectations for the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fft as core_fft

U64 = jnp.uint64


def fft_forward_ref(x: jax.Array) -> jax.Array:
    """real (B, N) -> (B, 2, N/2) f64 stacked re/im (same layout as kernel)."""
    spec = core_fft.forward(x.astype(jnp.float64))
    return jnp.stack([jnp.real(spec), jnp.imag(spec)], axis=1)


def fft_inverse_ref(spec: jax.Array) -> jax.Array:
    """(B, 2, M) -> real (B, 2M) f64."""
    z = spec[:, 0].astype(jnp.float64) + 1j * spec[:, 1].astype(jnp.float64)
    return core_fft.inverse(z)


def external_product_mac_ref(dig: jax.Array, bsk: jax.Array) -> jax.Array:
    """dig (B,2,J,F), bsk (2,J,K,F) -> (B,2,K,F), f64 complex math."""
    d = dig[:, 0].astype(jnp.float64) + 1j * dig[:, 1].astype(jnp.float64)
    w = bsk[0].astype(jnp.float64) + 1j * bsk[1].astype(jnp.float64)
    out = jnp.einsum("bjf,jkf->bkf", d, w)
    return jnp.stack([jnp.real(out), jnp.imag(out)], axis=1)


def keyswitch_mac_ref(digits: jax.Array, ksk: jax.Array) -> jax.Array:
    """digits (B, S) int32, ksk (S, T) uint64 -> (B, T) uint64 mod 2^64.

    Exact uint64 oracle for the limb kernel.
    """
    d = digits.astype(jnp.int64).astype(U64)     # two's complement mod 2^64
    return jnp.einsum("bs,st->bt", d, ksk)       # wraparound dot


def split_u64(x: jax.Array):
    """uint64 -> (hi, lo) uint32 planes (kernel input format)."""
    return (x >> U64(32)).astype(jnp.uint32), (x & U64(0xFFFFFFFF)).astype(jnp.uint32)


def merge_u64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    return (hi.astype(U64) << U64(32)) | lo.astype(U64)
