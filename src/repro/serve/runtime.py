"""Multi-tenant serving runtime: request queue, admission control,
per-client fairness, fault retry.

`ServeRuntime.submit(graph, enc_inputs, client_id)` returns a
`RequestHandle` immediately (async queue semantics — `handle.wait()`
joins the result).  Admission pulls queued requests round-robin across
clients, so one client flooding the queue cannot starve another: a
request is admitted within (#clients x its position in its own client's
queue + #clients) admissions, which `tests/test_serve.py` bounds.  At
most `max_inflight` requests execute concurrently (each on a worker
thread whose PBS rounds fuse through `FusedLutScheduler`), and each
client's backlog is capped at `max_queued_per_client` — beyond it
`submit` raises `AdmissionError` (shed load at the door, not mid-round).

Failures retry through `repro.runtime.fault.StepRunner`: a request whose
execution raises (a poisoned round, a device loss) is re-run from its
encrypted inputs up to `fault.max_retries` times; a failed fused round
fans its error out to every participating request, and each retries
independently.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Optional

from repro.compiler.ir import Graph
from repro.core.engine import TaurusEngine
from repro.runtime.fault import FaultConfig, StepRunner
from repro.serve.interpreter import IrInterpreter
from repro.serve.scheduler import FusedLutScheduler


class AdmissionError(RuntimeError):
    """A client's queue is full — the request was not accepted."""


class SubmitValidationError(ValueError):
    """The request is malformed (input count/shape vs the graph's input
    nodes) — rejected at submit, before any worker thread runs.  Without
    this check a bad request would only fail DEEP in execution, and the
    fault layer would burn `max_retries` re-runs on a request that can
    never succeed."""


class RuntimeClosedError(RuntimeError):
    """submit() after close() — the runtime no longer admits work."""


@dataclasses.dataclass
class ServeRequest:
    """One queued unit of work: a compiled IR graph plus the client's
    encrypted inputs (one big-key LWE array per graph input node).  The
    runtime assigns `request_id` at submit."""
    client_id: str
    graph: Graph
    enc_inputs: list
    request_id: int = -1


class RequestHandle:
    """Async result handle for one submitted request.

    Example::

        h = runtime.submit(graph, enc_inputs, client_id="alice")
        while not h.done():
            ...                       # overlap client-side work
        cts = h.outputs()             # graph outputs, in order

    `wait()` re-raises the request's terminal error (after the fault
    layer exhausted its retries); `retries` counts the re-runs."""

    def __init__(self, request: ServeRequest):
        self.request = request
        self.result: Optional[dict] = None
        self.error: Optional[BaseException] = None
        self.retries = 0
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> dict:
        """Block until executed; returns {node_id: ciphertext array}."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id} still queued/running")
        if self.error is not None:
            raise self.error
        return self.result

    def outputs(self) -> list:
        """Graph outputs of the finished request, in order."""
        vals = self.wait()
        return [vals[i] for i in self.request.graph.outputs]


class ServeRuntime:
    """The multi-tenant FHE serving front door.

    Args (all keyword-only beyond ctx/engine):
      ctx        TFHEContext whose evaluation keys execute the traffic.
      engine     TaurusEngine to dispatch batched PBS on (defaults to a
                 fresh engine over ctx's keys).
      fused      barrier concurrent requests' PBS rounds into shared
                 `lut_batch` dispatches via a `FusedLutScheduler`.
      dedup      online (ciphertext, table) row dedup inside fused rounds.
      max_inflight            concurrent worker threads.
      max_queued_per_client   backlog cap per client; beyond it `submit`
                              raises `AdmissionError`.
      fault / fault_hook      retry policy (`runtime.fault.FaultConfig`)
                              and a chaos hook called per attempt.
      start_paused            queue without executing until `resume()`.
      intra_fuse              fan one request's tensor-level radix nodes
                              out per vector so they fuse intra-request.

    Example (see also `examples/serve_requests.py` and the encrypted-ML
    traffic in `examples/fhe_gpt2.py` / `benchmarks/fhe_ml_serve.py`)::

        rt = ServeRuntime(ctx, max_inflight=8)
        h = rt.submit(graph, enc_inputs, client_id="alice")
        outputs = h.outputs()        # blocks; ciphertext arrays
        rt.close()

    Most callers go through `repro.api.Session(ctx, backend="serve")`,
    which wraps submit/wait behind the portable Program contract.
    """

    def __init__(self, ctx, engine: Optional[TaurusEngine] = None, *,
                 fused: bool = True, dedup: bool = True,
                 max_inflight: int = 8,
                 max_queued_per_client: Optional[int] = None,
                 fault: Optional[FaultConfig] = None,
                 fault_hook: Optional[Callable] = None,
                 start_paused: bool = False,
                 intra_fuse: bool = True):
        self.ctx = ctx
        self.engine = engine if engine is not None \
            else TaurusEngine.from_context(ctx)
        self.fused = fused
        self.scheduler = FusedLutScheduler(dedup=dedup) if fused else None
        self.fault = fault if fault is not None else FaultConfig(max_retries=2)
        # fuse the per-vector rounds of one request's tensor-level radix
        # nodes through the shared scheduler (IrInterpreter fan-out)
        self.intra_fuse = intra_fuse
        # test/chaos hook: called as fault_hook(request, attempt) at the
        # start of every execution attempt; raising simulates a failure
        self.fault_hook = fault_hook
        self.max_inflight = max_inflight
        self.max_queued_per_client = max_queued_per_client
        self._lock = threading.Lock()
        self._queues: dict = {}                  # client -> deque[handle]
        self._client_ring: list = []             # round-robin order
        self._rr = 0
        self._inflight = 0
        self._next_id = 0
        self._paused = start_paused
        self._closed = False
        self._threads: list = []
        # "admitted" is an observability log (fairness tests/monitoring),
        # bounded so a long-lived server doesn't grow per-request state
        self.stats = {"admitted": collections.deque(maxlen=10_000),
                      "completed": 0, "failed": 0,
                      "retries": 0, "rejected": 0, "invalid": 0}

    # -- client API ----------------------------------------------------------
    def _validate_submit(self, graph: Graph, enc_inputs: list) -> None:
        """Typed, submit-time request validation: mismatches raise
        `SubmitValidationError` at the door instead of surfacing as
        worker-thread failures that the fault layer retries."""
        in_nodes = [n for n in graph.nodes if n.op == "input"]
        if len(enc_inputs) != len(in_nodes):
            self.stats["invalid"] += 1
            raise SubmitValidationError(
                f"graph has {len(in_nodes)} input nodes but "
                f"{len(enc_inputs)} encrypted inputs were submitted")
        ct_width = self.ctx.params.big_n + 1
        for node, arr in zip(in_nodes, enc_inputs):
            shape = tuple(getattr(arr, "shape", ()))
            if len(shape) != 2 or shape != (node.n_elements, ct_width):
                self.stats["invalid"] += 1
                raise SubmitValidationError(
                    f"input for node {node.id} (shape {node.shape}): "
                    f"expected a ({node.n_elements}, {ct_width}) big-key "
                    f"LWE array, got {shape or type(arr).__name__}")

    def submit(self, graph: Graph, enc_inputs: list,
               client_id: str = "client-0") -> RequestHandle:
        """Queue one request; returns its `RequestHandle` immediately.

        enc_inputs: one (n_elements, k*N+1) big-key LWE array per graph
        input node (shape-checked at the door; mismatches raise
        `SubmitValidationError`, a full client queue `AdmissionError`,
        a closed runtime `RuntimeClosedError`).  The request executes on
        a worker thread as soon as admission (round-robin across
        clients, at most `max_inflight` in flight) picks it."""
        with self._lock:
            if self._closed:
                raise RuntimeClosedError(
                    "runtime is closed — create a new ServeRuntime")
            self._validate_submit(graph, enc_inputs)
            queued = len(self._queues.get(client_id, ()))
            if (self.max_queued_per_client is not None
                    and queued >= self.max_queued_per_client):
                self.stats["rejected"] += 1
                raise AdmissionError(
                    f"client {client_id!r} already has {queued} queued "
                    f"requests (cap {self.max_queued_per_client})")
            q = self._queues.setdefault(client_id, collections.deque())
            req = ServeRequest(client_id, graph, enc_inputs, self._next_id)
            self._next_id += 1
            handle = RequestHandle(req)
            q.append(handle)
            if client_id not in self._client_ring:
                self._client_ring.append(client_id)
            self._admit_locked()
        return handle

    def pause(self) -> None:
        """Stop admitting (in-flight requests finish); queue keeps filling."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        """Start (or restart) admitting queued requests."""
        with self._lock:
            self._paused = False
            self._admit_locked()

    def drain(self) -> None:
        """Block until every queued/in-flight request has completed."""
        while True:
            with self._lock:
                queued = sum(len(q) for q in self._queues.values())
                busy = self._inflight
                if queued and not busy and self._paused:
                    raise RuntimeError(
                        "drain() on a paused runtime with queued requests "
                        "— call resume() first")
            if not queued and not busy:
                return
            for t in list(self._threads):
                t.join(timeout=0.05)

    def close(self) -> None:
        self.drain()
        with self._lock:
            self._closed = True
        for t in self._threads:
            t.join()

    # -- admission (round-robin across clients) ------------------------------
    def _admit_locked(self) -> None:
        while not self._paused and self._inflight < self.max_inflight:
            handle = self._next_handle_locked()
            if handle is None:
                return
            self._inflight += 1
            if self.fused:
                # register BEFORE the worker starts so a wave of
                # admissions forms one full fusion barrier
                self.scheduler.register()
            self.stats["admitted"].append(
                (handle.request.client_id, handle.request.request_id))
            t = threading.Thread(target=self._worker, args=(handle,),
                                 daemon=True)
            self._threads.append(t)
            t.start()

    def _next_handle_locked(self) -> Optional[RequestHandle]:
        ring = self._client_ring
        nclients = len(ring)
        for step in range(nclients):
            idx = (self._rr + step) % nclients
            cid = ring[idx]
            q = self._queues.get(cid)
            if q:
                handle = q.popleft()
                if q:
                    self._rr = (idx + 1) % nclients
                else:
                    # drop the drained client so a long-lived server's
                    # ring/queue map doesn't grow with every client ever
                    # seen (resubmits re-enter at the ring's tail)
                    del self._queues[cid]
                    ring.pop(idx)
                    self._rr = idx % len(ring) if ring else 0
                return handle
        return None

    # -- execution -----------------------------------------------------------
    def _worker(self, handle: RequestHandle) -> None:
        req = handle.request
        try:
            eng = self.scheduler.proxy(self.engine) if self.fused \
                else self.engine
            interp = IrInterpreter(self.ctx, eng,
                                   intra_fuse=self.intra_fuse,
                                   holds_slot=self.fused)
            attempt = {"n": 0}

            def step():
                attempt["n"] += 1
                if self.fault_hook is not None:
                    self.fault_hook(req, attempt["n"])
                return interp.run(req.graph, req.enc_inputs)

            runner = StepRunner(step, self.fault)
            try:
                handle.result = runner.run()
            finally:
                # count retries whether the request ultimately succeeded
                # or exhausted its budget — retry storms from poisoned
                # requests must show up in the stats
                handle.retries = runner.stats["retries"]
        except BaseException as err:  # noqa: BLE001 — surfaced via handle
            handle.error = err
        finally:
            if self.fused:
                self.scheduler.unregister()
            with self._lock:
                self._inflight -= 1
                self.stats["retries"] += handle.retries
                if handle.error is None:
                    self.stats["completed"] += 1
                else:
                    self.stats["failed"] += 1
                self._threads = [t for t in self._threads
                                 if t.is_alive()
                                 and t is not threading.current_thread()]
                self._admit_locked()
            handle._done.set()
