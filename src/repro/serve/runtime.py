"""Multi-tenant serving runtime: a front-door ROUTER over N engine
shards — request queue, admission control, per-client fairness, request
placement, fault retry.

`ServeRuntime.submit(graph, enc_inputs, client_id)` returns a
`RequestHandle` immediately (async queue semantics — `handle.wait()`
joins the result).  Admission pulls queued requests round-robin across
clients, so one client flooding the queue cannot starve another: a
request is admitted within (#clients x its position in its own client's
queue + #clients) admissions, which `tests/test_serve.py` bounds.

Execution is SHARDED (ISSUE 10): the router places each admitted
request on an `EngineShard` (`repro.serve.shard`) — parameter-set
filter, then least-loaded, then lowest index — and each shard runs its
own engine group, fusion barrier, and resident evaluation keys.  At
most `max_inflight` requests execute concurrently PER SHARD (each on a
worker thread whose PBS rounds fuse through the shard's
`FusedLutScheduler`); with `elastic=True` the per-shard limit is a live
`ElasticAdmission` grant driven by queue depth and recent fused-wave
occupancy, with `max_inflight` as the hard ceiling.  Each client's
backlog is capped at `max_queued_per_client` — beyond it `submit`
raises `AdmissionError` (shed load at the door, not mid-round).
`shards=1` (the default) is the single-shard special case and behaves
exactly like the pre-shard runtime.

Failures retry through `repro.runtime.fault.StepRunner`: a request whose
execution raises (a poisoned round, a device loss) is re-run from its
encrypted inputs up to `fault.max_retries` times; a failed fused round
fans its error out to every participating request, and each retries
independently.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Optional

from repro.compiler.ir import Graph
from repro.core.engine import TaurusEngine
from repro.obs import StatsView, Telemetry
from repro.runtime.fault import FaultConfig, StepRunner
from repro.serve.interpreter import IrInterpreter
from repro.serve.shard import EngineShard, build_shards


class AdmissionError(RuntimeError):
    """A client's queue is full — the request was not accepted."""


class SubmitValidationError(ValueError):
    """The request is malformed (input count/shape vs the graph's input
    nodes) — rejected at submit, before any worker thread runs.  Without
    this check a bad request would only fail DEEP in execution, and the
    fault layer would burn `max_retries` re-runs on a request that can
    never succeed."""


class RuntimeClosedError(RuntimeError):
    """submit() after close() — the runtime no longer admits work.  Also
    the terminal error of requests still queued when `close(drain=False)`
    shuts the runtime down: their waiters unblock immediately instead of
    hanging on a handle nobody will ever execute."""


class RequestAbandonedError(RuntimeError):
    """The request was canceled while still queued (`ServeRuntime.cancel`
    / `RequestHandle.abandon`) — e.g. a client's deadline expired before
    admission.  Waiters see this instead of blocking forever."""


@dataclasses.dataclass
class ServeRequest:
    """One queued unit of work: a compiled IR graph plus the client's
    encrypted inputs (one big-key LWE array per graph input node).  The
    runtime assigns `request_id` at submit."""
    client_id: str
    graph: Graph
    enc_inputs: list
    request_id: int = -1


class OutputFuture:
    """Completion handle for ONE graph output of one request.

    Resolves the moment the interpreter materializes its node — possibly
    rounds before the whole request finishes — with a `completed_at`
    timestamp (perf_counter timebase) that feeds the request's trace
    span.  Early resolution is sound because graph execution is
    deterministic over immutable encrypted inputs: an output computed
    before a later step fails is still the output, and a fault-layer
    retry skips already-resolved futures.  Only outputs still unresolved
    when the request exhausts its retries `fail()`."""

    __slots__ = ("node_id", "index", "value", "error", "completed_at",
                 "_done")

    def __init__(self, node_id: int, index: int):
        self.node_id = node_id
        self.index = index                 # position in graph.outputs
        self.value = None
        self.error: Optional[BaseException] = None
        self.completed_at: Optional[float] = None
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None):
        """Block until this output is ready; returns its ciphertext array."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"output {self.index} (node {self.node_id}) "
                               f"not ready")
        if self.error is not None:
            raise self.error
        return self.value

    def resolve(self, value, ts: float) -> bool:
        """First resolution wins (retries re-visit nodes); returns whether
        this call was the one that resolved it."""
        if self._done.is_set():
            return False
        self.value = value
        self.completed_at = ts
        self._done.set()
        return True

    def fail(self, err: BaseException) -> None:
        if not self._done.is_set():
            self.error = err
            self._done.set()


class RequestHandle:
    """Async result handle for one submitted request.

    Example::

        h = runtime.submit(graph, enc_inputs, client_id="alice")
        while not h.done():
            ...                       # overlap client-side work
        cts = h.outputs()             # graph outputs, in order

    `wait()` re-raises the request's terminal error (after the fault
    layer exhausted its retries); `retries` counts the re-runs.

    `output_futures` holds one `OutputFuture` per graph output (in
    output order): each resolves as soon as its node is computed, so a
    client can stream early outputs while later ones still execute."""

    def __init__(self, request: ServeRequest):
        self.request = request
        self.result: Optional[dict] = None
        self.error: Optional[BaseException] = None
        self.retries = 0
        self.submitted_at: Optional[float] = None   # perf_counter stamps
        self.admitted_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self._runtime = None                        # set by submit()
        self._done = threading.Event()
        self.output_futures = [
            OutputFuture(nid, i)
            for i, nid in enumerate(request.graph.outputs)]
        # node id -> futures (a node may be listed as an output twice)
        self._out_map: dict = {}
        for f in self.output_futures:
            self._out_map.setdefault(f.node_id, []).append(f)

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> dict:
        """Block until executed; returns {node_id: ciphertext array}."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id} still queued/running")
        if self.error is not None:
            raise self.error
        return self.result

    def outputs(self) -> list:
        """Graph outputs of the finished request, in order."""
        vals = self.wait()
        return [vals[i] for i in self.request.graph.outputs]

    def abandon(self) -> bool:
        """Cancel this request if it is still queued (deadline expired,
        client gave up).  True if it was removed before admission — the
        handle then terminates with `RequestAbandonedError`.  False if
        already executing or done: an in-flight request cannot be
        stopped mid-round, so the caller decides whether to keep
        waiting."""
        rt = self._runtime
        return rt.cancel(self) if rt is not None else False


class ServeRuntime:
    """The multi-tenant FHE serving front door: router + engine shards.

    Args (all keyword-only beyond ctx/engine):
      ctx        TFHEContext whose evaluation keys execute the traffic.
      engine     TaurusEngine shard 0 dispatches batched PBS on
                 (defaults to a fresh engine over ctx's keys); shards
                 beyond the first always build their own engine from ctx
                 with the same kernel backend (per-shard key residency).
      kernel_backend  "reference" | "pallas" engine room for the shard
                 engines (see `repro.core.engine`); invalid alongside a
                 prebuilt engine.  Fused waves inherit it because the
                 scheduler proxy dispatches through `engine.lut_batch`.
      shards     number of engine shards.  The router places each
                 admitted request on the least-loaded shard that accepts
                 its parameter set; `shards=1` (default) is the
                 single-shard special case, behaviorally identical to
                 the pre-shard runtime.
      elastic    None/False: static per-shard limit (`max_inflight`).
                 True: per-shard `ElasticAdmission` controllers
                 (`repro.runtime.elastic`) grow the limit under backlog
                 (occupancy permitting) and shrink it when idle, with
                 `max_inflight` as the hard ceiling.  Or pass an
                 `ElasticPolicy` for explicit knobs.
      shard_devices  one device tuple per shard (defaults to
                 `launch.mesh.shard_devices(shards)`); multi-device
                 shards run the reference backend over a data mesh,
                 and pallas shards are routed to a single device (the
                 `ConfigError` combination, avoided at construction).
      fused      barrier concurrent requests' PBS rounds into shared
                 `lut_batch` dispatches via each shard's
                 `FusedLutScheduler`.
      dedup      online (ciphertext, table) row dedup inside fused rounds.
      ks_dedup   KS-level partial dedup: fused rows sharing a ciphertext
                 but not a table key-switch once (`ks_dedup_hits`).
      max_inflight            concurrent worker threads PER SHARD (the
                              elastic ceiling when `elastic` is set).
      max_queued_per_client   backlog cap per client; beyond it `submit`
                              raises `AdmissionError`.
      fault / fault_hook      retry policy (`runtime.fault.FaultConfig`)
                              and a chaos hook called per attempt.
      start_paused            queue without executing until `resume()`.
      intra_fuse              fan one request's tensor-level radix nodes
                              out per vector so they fuse intra-request.
      telemetry               a `repro.obs.Telemetry`; defaults to a
                              private metrics-only one (tracing off).
                              `metrics()` returns its snapshot.

    Example (see also `examples/serve_requests.py` and the encrypted-ML
    traffic in `examples/fhe_gpt2.py` / `benchmarks/fhe_ml_serve.py`)::

        rt = ServeRuntime(ctx, shards=2, max_inflight=8)
        h = rt.submit(graph, enc_inputs, client_id="alice")
        outputs = h.outputs()        # blocks; ciphertext arrays
        rt.close()

    Most callers go through `repro.api.Session(ctx, backend="serve")`,
    which wraps submit/wait behind the portable Program contract (the
    `shards=` knob threads through it like `max_inflight` does).
    """

    def __init__(self, ctx, engine: Optional[TaurusEngine] = None, *,
                 fused: bool = True, dedup: bool = True,
                 ks_dedup: bool = True,
                 shards: int = 1,
                 elastic=None,
                 shard_devices: Optional[list] = None,
                 max_inflight: int = 8,
                 max_queued_per_client: Optional[int] = None,
                 fault: Optional[FaultConfig] = None,
                 fault_hook: Optional[Callable] = None,
                 start_paused: bool = False,
                 intra_fuse: bool = True,
                 kernel_backend: Optional[str] = None,
                 telemetry: Optional[Telemetry] = None):
        self.ctx = ctx
        if kernel_backend is not None and engine is not None:
            raise TypeError("pass kernel_backend OR a prebuilt engine, "
                            "not both")
        self.fused = fused
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.fault = fault if fault is not None else FaultConfig(max_retries=2)
        # fuse the per-vector rounds of one request's tensor-level radix
        # nodes through the shared scheduler (IrInterpreter fan-out)
        self.intra_fuse = intra_fuse
        # test/chaos hook: called as fault_hook(request, attempt) at the
        # start of every execution attempt; raising simulates a failure
        self.fault_hook = fault_hook
        # per-shard limit (elastic ceiling when elastic is enabled)
        self.max_inflight = max_inflight
        self.max_queued_per_client = max_queued_per_client
        self.n_shards = shards
        self.shards = build_shards(
            ctx, engine, n_shards=shards, fused=fused, dedup=dedup,
            ks_dedup=ks_dedup, max_inflight=max_inflight, elastic=elastic,
            kernel_backend=kernel_backend, telemetry=self.telemetry,
            device_sets=shard_devices)
        self._lock = threading.Lock()
        self._queues: dict = {}                  # client -> deque[handle]
        self._client_ring: list = []             # round-robin order
        self._rr = 0
        self._next_id = 0
        self._paused = start_paused
        self._closed = False
        self._threads: list = []
        tel = self.telemetry
        self._c = {k: tel.counter(f"serve.{k}")
                   for k in ("admitted", "completed", "failed",
                             "retries", "rejected", "invalid",
                             "abandoned")}
        self._h_latency = tel.histogram("serve.request_latency_s")
        self._h_queue_wait = tel.histogram("serve.queue_wait_s")
        self._h_queue_depth = tel.histogram("serve.queue_depth")
        self._g_queue_depth = tel.gauge("serve.queue_depth")
        # "admitted" is an observability log (fairness tests/monitoring),
        # bounded so a long-lived server doesn't grow per-request state
        self._admitted_log: collections.deque = collections.deque(
            maxlen=10_000)

    # -- single-shard back-compat surface ------------------------------------
    @property
    def engine(self) -> TaurusEngine:
        """Shard 0's engine — THE engine of a `shards=1` runtime (the
        object the caller passed in), the first shard's otherwise."""
        return self.shards[0].engine

    @property
    def scheduler(self):
        """Shard 0's `FusedLutScheduler` (None when `fused=False`) —
        THE scheduler of a `shards=1` runtime.  Multi-shard callers read
        each shard's own `rt.shards[i].scheduler`."""
        return self.shards[0].scheduler

    @property
    def stats(self) -> StatsView:
        """Backward-compatible stats mapping: the historical dict keys
        (`admitted` deque log; `completed`/`failed`/`retries`/`rejected`/
        `invalid` counts), read live off the metrics registry."""
        sources: dict = dict(self._c)
        sources["admitted"] = self._admitted_log
        return StatsView(sources)

    def metrics(self) -> dict:
        """The full telemetry snapshot: serve.*, sched.*, integer.*
        counters/gauges/histograms plus the bandwidth ledger."""
        return self.telemetry.snapshot()

    # -- client API ----------------------------------------------------------
    def _validate_submit(self, graph: Graph, enc_inputs: list) -> None:
        """Typed, submit-time request validation: mismatches raise
        `SubmitValidationError` at the door instead of surfacing as
        worker-thread failures that the fault layer retries."""
        in_nodes = [n for n in graph.nodes if n.op == "input"]
        if len(enc_inputs) != len(in_nodes):
            self._c["invalid"].inc()
            raise SubmitValidationError(
                f"graph has {len(in_nodes)} input nodes but "
                f"{len(enc_inputs)} encrypted inputs were submitted")
        ct_width = self.ctx.params.big_n + 1
        for node, arr in zip(in_nodes, enc_inputs):
            shape = tuple(getattr(arr, "shape", ()))
            if len(shape) != 2 or shape != (node.n_elements, ct_width):
                self._c["invalid"].inc()
                raise SubmitValidationError(
                    f"input for node {node.id} (shape {node.shape}): "
                    f"expected a ({node.n_elements}, {ct_width}) big-key "
                    f"LWE array, got {shape or type(arr).__name__}")

    def submit(self, graph: Graph, enc_inputs: list,
               client_id: str = "client-0") -> RequestHandle:
        """Queue one request; returns its `RequestHandle` immediately.

        enc_inputs: one (n_elements, k*N+1) big-key LWE array per graph
        input node (shape-checked at the door; mismatches raise
        `SubmitValidationError`, a full client queue `AdmissionError`,
        a closed runtime `RuntimeClosedError`).  The request executes on
        a worker thread as soon as admission (round-robin across
        clients, at most `max_inflight` in flight) picks it."""
        with self._lock:
            if self._closed:
                raise RuntimeClosedError(
                    "runtime is closed — create a new ServeRuntime")
            self._validate_submit(graph, enc_inputs)
            queued = len(self._queues.get(client_id, ()))
            if (self.max_queued_per_client is not None
                    and queued >= self.max_queued_per_client):
                self._c["rejected"].inc()
                raise AdmissionError(
                    f"client {client_id!r} already has {queued} queued "
                    f"requests (cap {self.max_queued_per_client})")
            q = self._queues.setdefault(client_id, collections.deque())
            req = ServeRequest(client_id, graph, enc_inputs, self._next_id)
            self._next_id += 1
            handle = RequestHandle(req)
            handle._runtime = self
            handle.submitted_at = time.perf_counter()
            q.append(handle)
            if client_id not in self._client_ring:
                self._client_ring.append(client_id)
            self.telemetry.instant("submit", cat="serve",
                                   request=req.request_id, client=client_id)
            depth = sum(len(qq) for qq in self._queues.values())
            self._g_queue_depth.set(depth)
            self._h_queue_depth.observe(depth)
            self._admit_locked()
        return handle

    def pause(self) -> None:
        """Stop admitting (in-flight requests finish); queue keeps filling."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        """Start (or restart) admitting queued requests."""
        with self._lock:
            self._paused = False
            self._admit_locked()

    def drain(self) -> None:
        """Block until every queued/in-flight request has completed."""
        while True:
            with self._lock:
                queued = sum(len(q) for q in self._queues.values())
                busy = sum(s.inflight for s in self.shards)
                if queued and not busy and self._paused:
                    raise RuntimeError(
                        "drain() on a paused runtime with queued requests "
                        "— call resume() first")
            if not queued and not busy:
                return
            for t in list(self._threads):
                t.join(timeout=0.05)

    def cancel(self, handle: RequestHandle) -> bool:
        """Remove a still-queued request; True if it was canceled.

        A canceled handle terminates immediately with
        `RequestAbandonedError` (its waiters and output futures all
        unblock).  Returns False when the request was already admitted
        or finished — an executing request cannot be stopped mid-round."""
        req = handle.request
        with self._lock:
            q = self._queues.get(req.client_id)
            if q is None or handle not in q:
                return False
            q.remove(handle)
            if not q:
                del self._queues[req.client_id]
                ring = self._client_ring
                ring.remove(req.client_id)
                self._rr = self._rr % len(ring) if ring else 0
            self._c["abandoned"].inc()
            self._g_queue_depth.set(
                sum(len(qq) for qq in self._queues.values()))
        self._fail_handle(handle, RequestAbandonedError(
            f"request {req.request_id} (client {req.client_id!r}) "
            f"canceled while queued"))
        self.telemetry.instant("abandoned", cat="serve",
                               request=req.request_id, client=req.client_id)
        return True

    @staticmethod
    def _fail_handle(handle: RequestHandle, err: BaseException) -> None:
        handle.error = err
        handle.completed_at = time.perf_counter()
        for f in handle.output_futures:
            f.fail(err)
        handle._done.set()

    def close(self, drain: bool = True) -> None:
        """Shut the runtime down.

        drain=True (default) first waits for every queued/in-flight
        request to finish.  drain=False fails fast: requests still
        QUEUED terminate immediately with `RuntimeClosedError` (no
        waiter hangs on work that will never run); requests already
        executing run to completion (a PBS round can't be stopped
        mid-flight) and their handles resolve normally."""
        if drain:
            self.drain()
        with self._lock:
            self._closed = True
            dropped = [h for q in self._queues.values() for h in q]
            self._queues.clear()
            self._client_ring.clear()
            self._rr = 0
            if dropped:
                self._c["abandoned"].inc(len(dropped))
            self._g_queue_depth.set(0)
        for h in dropped:
            self._fail_handle(h, RuntimeClosedError(
                f"request {h.request.request_id} was still queued when the "
                f"runtime closed"))
        for t in list(self._threads):
            t.join()

    # -- admission (round-robin across clients) + placement ------------------
    def _queue_depth_locked(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _place_locked(self) -> Optional[EngineShard]:
        """Pick the shard for the next admission: parameter-set filter,
        then least-loaded (fewest in-flight), then lowest index.  None
        when every eligible shard is at its limit."""
        params = self.ctx.params
        best = None
        for s in self.shards:
            if s.capacity <= 0 or not s.accepts(params):
                continue
            if best is None or s.inflight < best.inflight:
                best = s
        return best

    def _admit_locked(self) -> None:
        if self._closed:
            return
        while not self._paused:
            shard = self._place_locked()
            if shard is None:
                # fleet saturated: with a backlog, give every shard's
                # elastic controller a grow look (queue depth + its own
                # recent occupancy) and retry if any limit rose — this
                # makes ramp-up synchronous with demand, not timer-driven
                depth = self._queue_depth_locked()
                if depth and any([s.elastic_observe(depth)
                                  for s in self.shards]):
                    continue
                return
            handle = self._next_handle_locked()
            if handle is None:
                return
            # registers with the shard's fusion barrier BEFORE the
            # worker starts, so a wave of admissions fuses fully
            shard.acquire()
            handle.admitted_at = time.perf_counter()
            self._c["admitted"].inc()
            self._admitted_log.append(
                (handle.request.client_id, handle.request.request_id))
            self.telemetry.instant("admit", cat="serve",
                                   request=handle.request.request_id,
                                   client=handle.request.client_id,
                                   shard=shard.index)
            self._g_queue_depth.set(self._queue_depth_locked())
            t = threading.Thread(target=self._worker, args=(handle, shard),
                                 daemon=True)
            self._threads.append(t)
            t.start()

    def _next_handle_locked(self) -> Optional[RequestHandle]:
        ring = self._client_ring
        nclients = len(ring)
        for step in range(nclients):
            idx = (self._rr + step) % nclients
            cid = ring[idx]
            q = self._queues.get(cid)
            if q:
                handle = q.popleft()
                if q:
                    self._rr = (idx + 1) % nclients
                else:
                    # drop the drained client so a long-lived server's
                    # ring/queue map doesn't grow with every client ever
                    # seen (resubmits re-enter at the ring's tail)
                    del self._queues[cid]
                    ring.pop(idx)
                    self._rr = idx % len(ring) if ring else 0
                return handle
        return None

    # -- execution -----------------------------------------------------------
    def _worker(self, handle: RequestHandle, shard: EngineShard) -> None:
        req = handle.request
        tel = self.telemetry
        # backfill the queue-wait interval (its endpoints were stamped by
        # the submitting thread and the admitting thread) onto this lane,
        # BEFORE the request span opens so the two stay disjoint siblings
        if handle.submitted_at is not None and handle.admitted_at is not None:
            wait_s = handle.admitted_at - handle.submitted_at
            tel.record("queue_wait", "serve", handle.submitted_at, wait_s,
                       request=req.request_id, client=req.client_id)
            self._h_queue_wait.observe(wait_s)
        span = tel.span("request", cat="serve", request=req.request_id,
                        client=req.client_id, shard=shard.index)
        with span:
            try:
                eng = shard.worker_engine()
                interp = IrInterpreter(self.ctx, eng,
                                       intra_fuse=self.intra_fuse,
                                       holds_slot=self.fused,
                                       telemetry=tel)
                attempt = {"n": 0}

                def on_node(node_id, value):
                    futs = handle._out_map.get(node_id)
                    if not futs:
                        return
                    ts = time.perf_counter()
                    for f in futs:
                        if f.resolve(value, ts):
                            tel.instant("output_ready", cat="serve",
                                        request=req.request_id,
                                        output=f.index)

                def step():
                    attempt["n"] += 1
                    if self.fault_hook is not None:
                        self.fault_hook(req, attempt["n"])
                    return interp.run(req.graph, req.enc_inputs,
                                      on_node=on_node)

                runner = StepRunner(step, self.fault, telemetry=tel)
                try:
                    handle.result = runner.run()
                finally:
                    # count retries whether the request ultimately succeeded
                    # or exhausted its budget — retry storms from poisoned
                    # requests must show up in the stats
                    handle.retries = runner.stats["retries"]
            except BaseException as err:  # noqa: BLE001 — via handle
                handle.error = err
            finally:
                handle.completed_at = time.perf_counter()
                if handle.error is None:
                    # outputs the interpreter resolved early keep their
                    # timestamps; the rest (e.g. passthrough inputs)
                    # resolve now from the final result
                    for f in handle.output_futures:
                        f.resolve(handle.result[f.node_id],
                                  handle.completed_at)
                else:
                    for f in handle.output_futures:
                        f.fail(handle.error)
                if shard.scheduler is not None:
                    shard.scheduler.unregister()
                outcome = "completed" if handle.error is None else "failed"
                span.set(retries=handle.retries, outcome=outcome)
                tel.instant(outcome, cat="serve", request=req.request_id,
                            client=req.client_id, shard=shard.index)
                if handle.submitted_at is not None:
                    self._h_latency.observe(
                        handle.completed_at - handle.submitted_at)
                with self._lock:
                    shard.release(outcome)
                    self._c["retries"].inc(handle.retries)
                    self._c[outcome].inc()
                    # a completion with an empty queue is the elastic
                    # controller's shrink opportunity (ramp-down to idle)
                    shard.elastic_observe(self._queue_depth_locked())
                    self._threads = [t for t in self._threads
                                     if t.is_alive()
                                     and t is not threading.current_thread()]
                    self._admit_locked()
                handle._done.set()
