"""IR interpreter: executes compiled `repro.compiler.ir` graphs on real
ciphertexts through an engine's batched PBS entry point.

This is the serving-side execution contract the compiler lowers to.  It
differs from `repro.fhe_ml.executor.FheExecutor` in two ways that matter
for a multi-tenant runtime:

  * every bootstrap goes through `engine.lut_batch` — hand it a
    `FusedEngineProxy` and all of a request's PBS rounds fuse with every
    other in-flight request's rounds (cross-request key reuse + dedup);
  * it executes the `radix_*` wide-integer ops that the compiler
    previously only lowered for scheduling/cost, by dispatching each
    digit vector through `IntegerContext` (ROADMAP: executor
    integration).

A radix node's tensor has its digit vector on the LAST axis; the
interpreter executes one `IntegerContext` op per leading-axis vector.
(Batching the vectors of one tensor into shared rounds is a recorded
serve-layer follow-up — cross-request fusion already recovers the
occupancy for the serving path.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.ir import Graph, RADIX_OPS
from repro.core import glwe
from repro.core.engine import TaurusEngine
from repro.core.integer import IntegerContext, RadixCiphertext
from repro.fhe_ml.executor import eval_linear_ct_op


class IrInterpreter:
    """Runs a compiled Graph on real ciphertexts via `engine.lut_batch`.

    `engine` is a TaurusEngine or a `FusedEngineProxy`; with a proxy,
    per-round padding is left to the fused scheduler (padding tiny
    per-request rounds would only dilute the fused batch)."""

    def __init__(self, ctx, engine=None, *,
                 pad_rounds: Optional[bool] = None):
        self.ctx = ctx
        self.engine = engine if engine is not None \
            else TaurusEngine.from_context(ctx)
        self.params = ctx.params
        if pad_rounds is None:
            pad_rounds = not getattr(self.engine, "fused", False)
        self.int_ctx = IntegerContext(ctx, self.engine,
                                      pad_batches=pad_rounds)
        self._poly_cache: dict = {}

    # -- helpers -------------------------------------------------------------
    def _lut_poly(self, table: np.ndarray) -> jax.Array:
        key = np.ascontiguousarray(table).tobytes()
        if key not in self._poly_cache:
            self._poly_cache[key] = glwe.make_lut_polys_cached(
                np.asarray(table)[None], self.params)[0]
        return self._poly_cache[key]

    def _radix(self, n, vals) -> jax.Array:
        m, d = n.attrs["msg_bits"], n.attrs["n_digits"]
        ic = self.int_ctx
        spec = ic.spec(m * d, m)
        width = self.params.big_n + 1
        a = vals[n.inputs[0]].reshape(-1, d, width)
        b = None
        if len(n.inputs) == 2:
            b = vals[n.inputs[1]].reshape(-1, d, width)
        outs = []
        for v in range(a.shape[0]):
            ra = RadixCiphertext(spec, a[v])
            if n.op == "radix_add":
                r = ic.add(ra, RadixCiphertext(spec, b[v])).digits
            elif n.op == "radix_sub":
                r = ic.sub(ra, RadixCiphertext(spec, b[v])).digits
            elif n.op == "radix_mul":
                r = ic.mul(ra, RadixCiphertext(spec, b[v])).digits
            elif n.op == "radix_relu":
                r = ic.relu_clamp(ra).digits
            elif n.op == "radix_cmp":
                r = ic.compare(ra, RadixCiphertext(spec, b[v]))[None]
            else:
                raise ValueError(n.op)
            outs.append(r)
        return jnp.concatenate(outs, axis=0)

    # -- run ------------------------------------------------------------------
    def run(self, g: Graph, enc_inputs: list) -> dict:
        """enc_inputs: one (n_elements, k*N+1) ciphertext array per input
        node.  Returns {node_id: ciphertext array} for every node."""
        vals: dict = {}
        it = iter(enc_inputs)
        for n in g.nodes:
            if n.op == "input":
                vals[n.id] = next(it)
                continue
            out = eval_linear_ct_op(n, vals, self.params)
            if out is not None:
                vals[n.id] = out
            elif n.op == "lut":
                cts = vals[n.inputs[0]]
                poly = self._lut_poly(n.attrs["table"])
                polys = jnp.broadcast_to(poly, (cts.shape[0],) + poly.shape)
                vals[n.id] = self.engine.lut_batch(cts, polys)
            elif n.op in RADIX_OPS:
                vals[n.id] = self._radix(n, vals)
            else:
                raise ValueError(n.op)
        return vals

    def run_outputs(self, g: Graph, enc_inputs: list) -> list:
        """Like `run`, but returns just the graph outputs, in order."""
        vals = self.run(g, enc_inputs)
        return [vals[i] for i in g.outputs]
