"""IR interpreter: executes compiled `repro.compiler.ir` graphs on real
ciphertexts through an engine's batched PBS entry point.

This is the serving-side execution contract the compiler lowers to.  It
differs from `repro.api.EagerBackend` in two ways that matter for a
multi-tenant runtime:

  * every bootstrap goes through `engine.lut_batch` — hand it a
    `FusedEngineProxy` and all of a request's PBS rounds fuse with every
    other in-flight request's rounds (cross-request key reuse + dedup).
    In the sharded runtime (ISSUE 10) that proxy is
    `EngineShard.worker_engine()`: the interpreter is the execution
    body of ONE shard's worker, its rounds barrier only with requests
    the router placed on the same shard, and the proxy's KS-level dedup
    shares keyswitches between rows that differ only in table;
  * a tensor-level radix node over V > 1 digit vectors FLATTENS into V
    per-vector round streams executed on concurrent worker threads, each
    registered with the shared `FusedLutScheduler` — so the vectors of
    ONE request fuse with each other (intra-request fusion) exactly the
    way concurrent requests already do, and the scheduler's dedup/
    padding applies unchanged (ROADMAP serve-layer follow-up).

A radix node's tensor has its digit vector on the LAST axis; each
vector executes through `IntegerContext`
(`repro.api.backends.eval_radix_vector`, shared with the eager backend
so the radix semantics has one definition).
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.backends import eval_linear_ct_op, eval_radix_vector
from repro.compiler.ir import Graph, RADIX_OPS
from repro.core import glwe
from repro.core.engine import TaurusEngine
from repro.core.integer import IntegerContext


class IrInterpreter:
    """Runs a compiled Graph on real ciphertexts via `engine.lut_batch`.

    `engine` is a TaurusEngine or a `FusedEngineProxy`; with a proxy,
    per-round padding is left to the fused scheduler (padding tiny
    per-request rounds would only dilute the fused batch).

    intra_fuse: with a fused engine, execute the V vectors of one
    tensor-level radix node on V concurrent threads (each holding its
    own scheduler registration) so their identical round schedules
    barrier into shared batches.

    holds_slot: True when the calling thread itself holds a scheduler
    registration (a `ServeRuntime` worker) — the vector fan-out then
    parks that slot while it joins, so the barrier never waits on a
    thread that is not computing rounds.

    Example (the in-process serving contract, no queue)::

        interp = IrInterpreter(ctx, engine)
        outs = interp.run_outputs(program.graph, enc_inputs)
    """

    def __init__(self, ctx, engine=None, *,
                 pad_rounds: Optional[bool] = None,
                 intra_fuse: bool = True,
                 holds_slot: bool = False,
                 telemetry=None):
        self.ctx = ctx
        self.engine = engine if engine is not None \
            else TaurusEngine.from_context(ctx)
        self.params = ctx.params
        if pad_rounds is None:
            pad_rounds = not getattr(self.engine, "fused", False)
        self.telemetry = telemetry
        self.int_ctx = IntegerContext(ctx, self.engine,
                                      pad_batches=pad_rounds,
                                      telemetry=telemetry)
        self.intra_fuse = intra_fuse
        self.holds_slot = holds_slot
        self._poly_cache: dict = {}

    # -- helpers -------------------------------------------------------------
    def _lut_poly(self, table: np.ndarray) -> jax.Array:
        key = np.ascontiguousarray(table).tobytes()
        if key not in self._poly_cache:
            self._poly_cache[key] = glwe.make_lut_polys_cached(
                np.asarray(table)[None], self.params)[0]
        return self._poly_cache[key]

    # upper bound on fan-out threads per radix node: beyond this, each
    # worker takes a contiguous slice of vectors sequentially (rounds
    # still fuse MAX_FANOUT wide; unbounded V-wide threading would risk
    # thread exhaustion and stack churn on large tensors)
    MAX_FANOUT = 32

    def _radix_fanout(self, n, spec, a: jax.Array,
                      b: Optional[jax.Array], sched,
                      max_val: Optional[int] = None) -> list:
        """Per-vector rounds on concurrent threads sharing `sched`: the
        scheduler barrier fuses them like independent requests."""
        V = int(a.shape[0])
        outs: list = [None] * V
        errors: list = []
        nt = min(V, self.MAX_FANOUT)
        slices = [range(w, V, nt) for w in range(nt)]

        def work(idx) -> None:
            try:
                for v in idx:
                    outs[v] = eval_radix_vector(
                        self.int_ctx, n.op, spec, a[v],
                        None if b is None else b[v], max_val=max_val)
            except BaseException as err:  # noqa: BLE001 — re-raised below
                errors.append(err)
            finally:
                sched.unregister()

        threads = [threading.Thread(target=work, args=(idx,), daemon=True)
                   for idx in slices]
        # register every worker BEFORE any starts so the barrier width is
        # right from the first round; a started thread owns its slot (the
        # finally above releases it), slots of never-started threads are
        # released here so a start() failure can't inflate the barrier
        # forever
        for _ in threads:
            sched.register()
        started = 0
        try:
            for t in threads:
                t.start()
                started += 1
        finally:
            for _ in range(len(threads) - started):
                sched.unregister()
            # park the request's own slot while joining (this thread
            # computes no rounds meanwhile)
            if self.holds_slot:
                sched.unregister()
            try:
                for t in threads[:started]:
                    t.join()
            finally:
                if self.holds_slot:
                    sched.register()
        if errors:
            raise errors[0]
        return outs

    def _radix(self, n, vals) -> jax.Array:
        m, d = n.attrs["msg_bits"], n.attrs["n_digits"]
        ic = self.int_ctx
        spec = ic.spec(m * d, m)
        width = self.params.big_n + 1
        a = vals[n.inputs[0]].reshape(-1, d, width)
        b, mv = None, None
        if n.op == "radix_linear":
            # LPU combine + carry-save compress on the request thread (the
            # extraction rounds batch across ALL output columns, and still
            # fuse with other in-flight requests through the proxy); only
            # the final per-vector propagation fans out below
            a, mv = ic.linear_compress(a, n.attrs["W"], spec)
        elif n.op == "radix_norm":
            mv = n.attrs["max_val"]
        elif len(n.inputs) == 2:
            b = vals[n.inputs[1]].reshape(-1, d, width)
        sched = getattr(self.engine, "_scheduler", None)
        if self.intra_fuse and sched is not None and a.shape[0] > 1:
            outs = self._radix_fanout(n, spec, a, b, sched, max_val=mv)
        else:
            outs = [eval_radix_vector(ic, n.op, spec, a[v],
                                      None if b is None else b[v],
                                      max_val=mv)
                    for v in range(a.shape[0])]
        return jnp.concatenate(outs, axis=0)

    # -- run ------------------------------------------------------------------
    def run(self, g: Graph, enc_inputs: list,
            on_node=None) -> dict:
        """enc_inputs: one (n_elements, k*N+1) ciphertext array per input
        node.  Returns {node_id: ciphertext array} for every node.

        on_node: optional callback `on_node(node_id, value)` fired the
        moment each node's value materializes — `ServeRuntime` resolves
        per-output futures through it, so a request's early outputs are
        readable while later nodes still execute."""
        vals: dict = {}
        it = iter(enc_inputs)
        for n in g.nodes:
            if n.op == "input":
                vals[n.id] = next(it)
            else:
                out = eval_linear_ct_op(n, vals, self.params)
                if out is not None:
                    vals[n.id] = out
                elif n.op == "lut":
                    cts = vals[n.inputs[0]]
                    poly = self._lut_poly(n.attrs["table"])
                    polys = jnp.broadcast_to(poly,
                                             (cts.shape[0],) + poly.shape)
                    vals[n.id] = self.engine.lut_batch(cts, polys)
                elif n.op in RADIX_OPS:
                    vals[n.id] = self._radix(n, vals)
                else:
                    raise ValueError(n.op)
            if on_node is not None:
                on_node(n.id, vals[n.id])
        return vals

    def run_outputs(self, g: Graph, enc_inputs: list) -> list:
        """Like `run`, but returns just the graph outputs, in order."""
        vals = self.run(g, enc_inputs)
        return [vals[i] for i in g.outputs]
