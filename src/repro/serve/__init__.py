"""repro.serve — multi-tenant FHE serving runtime.

The compiler (`repro.compiler`) plans programs and the engine
(`repro.core.engine.TaurusEngine`) executes batched PBS; this package is
the layer between them that serves CONCURRENT clients, turning the
paper's two throughput levers — key-reuse-aware batching of bootstraps
and operation deduplication — into online, cross-request mechanisms:

  interpreter  `IrInterpreter` executes compiled `repro.compiler.ir`
               graphs (including the radix_* wide-integer ops) on real
               ciphertexts, routing every bootstrap through
               `engine.lut_batch`.
  scheduler    `FusedLutScheduler` barriers the in-flight requests'
               ready LUT rounds, groups them by parameter set /
               bootstrapping key, deduplicates identical
               (ciphertext, table) rows online
               (`repro.compiler.passes.fused_round_dedup`), and
               dispatches ONE fused `lut_batch` per group — the BSK
               streams once for everyone (paper §III-B, Fig. 13).
  runtime      `ServeRuntime` is the async front door: request queue,
               admission control (`max_inflight`,
               `max_queued_per_client`), round-robin per-client
               fairness, and fault retry through
               `repro.runtime.fault.StepRunner`.
  programs     client-side helpers that trace radix programs into IR
               and encrypt/decrypt their inputs/outputs —
               `fhe_ml_block_program` mints quantized-to-radix
               transformer blocks (encrypted-LLM traffic, ISSUE 4).

Typical serving loop (see `examples/serve_requests.py` and the
`benchmarks/serve_throughput.py` requests/sec benchmark):

    ctx = TFHEContext.create(key, params)          # client keys
    rt = ServeRuntime(ctx, max_inflight=8)         # server
    g = radix_binop_program("radix_add", bits=16, msg_bits=2)
    h = rt.submit(g, encrypt_request_inputs(ic, key, [a, b], 16), "alice")
    result = decrypt_radix_output(ic, h.outputs()[0], 16)   # client

Encrypted-LLM traffic rides the same queue: `fhe_ml_block_program`
(or `repro.fhe_ml.lower.lower_gpt2_block_radix` directly) lowers a
transformer block onto 16/32-bit radix activations whose rounds fuse
with every other in-flight request — see docs/ARCHITECTURE.md for the
full data path.  The runtime is SHARDED (ISSUE 10): `ServeRuntime` is
the router (admission, fairness, placement) over N `EngineShard`
workers, each owning its own engine group, fusion barrier, and resident
evaluation keys, with per-shard `max_inflight` resized live by
`repro.runtime.elastic.ElasticAdmission` when `elastic=True`.
"""
from repro.core.engine import ConfigError
from repro.serve.interpreter import IrInterpreter
from repro.serve.programs import (decrypt_radix_output,
                                  encrypt_request_inputs,
                                  fhe_ml_block_program,
                                  radix_binop_program, radix_unop_program)
from repro.serve.runtime import (AdmissionError, OutputFuture,
                                 RequestAbandonedError, RequestHandle,
                                 RuntimeClosedError, ServeRequest,
                                 ServeRuntime, SubmitValidationError)
from repro.serve.scheduler import FusedEngineProxy, FusedLutScheduler
from repro.serve.shard import EngineShard, build_shards

__all__ = [
    "AdmissionError", "ConfigError", "EngineShard", "FusedEngineProxy",
    "FusedLutScheduler",
    "IrInterpreter", "OutputFuture", "RequestAbandonedError",
    "RequestHandle", "RuntimeClosedError",
    "ServeRequest", "ServeRuntime", "SubmitValidationError",
    "build_shards",
    "decrypt_radix_output", "encrypt_request_inputs",
    "fhe_ml_block_program", "radix_binop_program", "radix_unop_program",
]
