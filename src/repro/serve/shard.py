"""Engine shards: the execution workers behind the `ServeRuntime` router.

ISSUE 10 splits the serving monolith into a front-door ROUTER
(admission, per-client fairness, placement — still `ServeRuntime`) and
N `EngineShard` workers.  Each shard owns

  * its own `TaurusEngine` — a private engine object, so the resident
    key operands (`FusedPbsPack` planes on the pallas backend, the
    cached key-bytes tuple on both) are PER SHARD: the paper's key-reuse
    story holds within a shard, and the scheduler's engine-id grouping
    keeps one shard's rounds from ever mixing into another's batches;
  * its own `FusedLutScheduler` barrier — the fusion width of a shard
    is the requests the router placed on it, so shards dispatch rounds
    independently (no global barrier across the fleet);
  * its own concurrency limit — static, or an `ElasticAdmission`
    controller (`repro.runtime.elastic`) resizing `max_inflight` from
    queue depth and recent fused-wave occupancy.

Device routing (`repro.launch.mesh.shard_devices`): a multi-device
shard runs the reference backend over a 1-D data mesh; the pallas
kernels run per-device, so a multi-device shard asking for pallas is
the documented-unsupported `ConfigError` combination — `build_shards`
routes AROUND it at construction time by pinning that shard to a
single-device pallas engine instead of letting the first `lut_batch`
blow up.

Observability: every shard mirrors its round counters into a
`serve.shard.<i>.*` namespace (admitted/completed/failed/inflight/
max_inflight here; fused_rounds/dedup_hits/ks_dedup_hits/
bsk_bytes_streamed via its scheduler's `shard_ns`), and the router
stamps `shard=<i>` on each request span.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.engine import ConfigError, TaurusEngine
from repro.obs import Telemetry
from repro.runtime.elastic import ElasticAdmission, ElasticPolicy
from repro.serve.scheduler import FusedLutScheduler


class EngineShard:
    """One serving shard: engine group + scheduler + concurrency limit.

    The router mutates `inflight` under ITS lock (`acquire`/`release`
    are called with the `ServeRuntime` admission lock held), so the
    shard itself needs no locking; the scheduler has its own barrier
    condition variable.
    """

    def __init__(self, index: int, ctx, engine: TaurusEngine, *,
                 fused: bool = True, dedup: bool = True,
                 ks_dedup: bool = True, max_inflight: int = 8,
                 elastic: Optional[ElasticAdmission] = None,
                 telemetry: Optional[Telemetry] = None,
                 devices: Sequence = ()):
        self.index = index
        self.ctx = ctx
        self.engine = engine
        self.devices = tuple(devices)
        self.fused = fused
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        ns = f"serve.shard.{index}"
        self.metrics_ns = ns
        self.scheduler = (FusedLutScheduler(dedup=dedup, ks_dedup=ks_dedup,
                                            telemetry=self.telemetry,
                                            shard_ns=ns)
                          if fused else None)
        self.elastic = elastic
        self._static_limit = max_inflight
        self.inflight = 0
        tel = self.telemetry
        self._c_admitted = tel.counter(f"{ns}.admitted")
        self._c_completed = tel.counter(f"{ns}.completed")
        self._c_failed = tel.counter(f"{ns}.failed")
        self._g_inflight = tel.gauge(f"{ns}.inflight")
        self._g_limit = tel.gauge(f"{ns}.max_inflight")
        self._g_limit.set(self.limit)

    # -- placement interface (read under the router lock) --------------------
    @property
    def limit(self) -> int:
        """Current concurrency limit: the elastic controller's grant, or
        the static `max_inflight`."""
        return (self.elastic.limit if self.elastic is not None
                else self._static_limit)

    @property
    def capacity(self) -> int:
        return self.limit - self.inflight

    def accepts(self, params) -> bool:
        """Parameter-set placement filter: a shard only serves requests
        whose evaluation keys match its engine's parameter set (today
        every shard is built from the router's one context, so this
        holds by construction — the hook is where heterogeneous
        parameter pools would route)."""
        return self.engine.params == params

    # -- worker interface ----------------------------------------------------
    def worker_engine(self):
        """The engine facade a request interpreter executes against:
        the shard scheduler's fusion proxy, or the bare engine."""
        return (self.scheduler.proxy(self.engine)
                if self.scheduler is not None else self.engine)

    def acquire(self) -> None:
        """Claim one slot (router lock held).  Registers the request
        with the shard's fusion barrier BEFORE its worker thread starts,
        so a wave of admissions forms one full barrier."""
        self.inflight += 1
        self._c_admitted.inc()
        self._g_inflight.set(self.inflight)
        if self.scheduler is not None:
            self.scheduler.register()

    def release(self, outcome: str) -> None:
        """Return one slot (router lock held); outcome is "completed" or
        "failed".  The scheduler unregister happens on the worker thread
        itself (it may complete the barrier for the remaining
        requests)."""
        self.inflight -= 1
        (self._c_completed if outcome == "completed"
         else self._c_failed).inc()
        self._g_inflight.set(self.inflight)

    # -- elastic control -----------------------------------------------------
    def recent_occupancy(self) -> Optional[float]:
        """Mean of the shard's last few fused-round occupancy samples
        (None when unfused or before the first round) — the controller's
        'are my barriers full?' signal."""
        if self.scheduler is None:
            return None
        occ = self.scheduler._occupancy
        if not occ:
            return None
        recent = list(occ)[-8:]
        return float(sum(recent) / len(recent))

    def elastic_observe(self, queue_depth: int) -> bool:
        """One controller step against the router's queue depth; returns
        True if this shard's limit changed (router lock held)."""
        if self.elastic is None:
            return False
        changed = self.elastic.observe(queue_depth, self.inflight,
                                       self.recent_occupancy())
        if changed:
            self._g_limit.set(self.limit)
        return changed


def build_shards(ctx, engine: Optional[TaurusEngine] = None, *,
                 n_shards: int = 1, fused: bool = True, dedup: bool = True,
                 ks_dedup: bool = True, max_inflight: int = 8,
                 elastic=None, kernel_backend: Optional[str] = None,
                 telemetry: Optional[Telemetry] = None,
                 device_sets: Optional[list] = None) -> list:
    """Construct a `ServeRuntime`'s shard list.

    Shard 0 adopts the caller's prebuilt `engine` when given (so
    `shards=1` serves through exactly the object the caller warmed);
    every other shard gets its own `TaurusEngine` over the same context
    and kernel backend — separate engine objects, hence per-shard
    resident keys and per-shard round batches.

    `elastic`: None/False for static limits, True for the default
    `ElasticPolicy` with `max_inflight` as ceiling, or an
    `ElasticPolicy` to share across shards (each shard still gets its
    OWN `ElasticAdmission` state).

    `device_sets` overrides `launch.mesh.shard_devices(n_shards)` —
    one device tuple per shard.
    """
    from repro.launch.mesh import shard_devices, shard_mesh
    if n_shards < 1:
        raise ConfigError(f"shards must be >= 1, got {n_shards}")
    kb = (engine.kernel_backend if engine is not None
          else (kernel_backend or "reference"))
    if device_sets is None:
        device_sets = shard_devices(n_shards)
    elif len(device_sets) != n_shards:
        raise ConfigError(
            f"device_sets has {len(device_sets)} entries for "
            f"{n_shards} shards")
    if elastic is True:
        policy: Optional[ElasticPolicy] = ElasticPolicy(ceiling=max_inflight)
    elif isinstance(elastic, ElasticPolicy):
        policy = elastic
    elif elastic in (None, False):
        policy = None
    else:
        raise TypeError(
            f"elastic must be None/False, True, or an ElasticPolicy, "
            f"got {elastic!r}")
    shards = []
    for i in range(n_shards):
        devs = tuple(device_sets[i])
        if i == 0 and engine is not None:
            eng = engine
        else:
            mesh = None
            if len(devs) > 1 and kb == "reference":
                mesh = shard_mesh(devs)
            # len(devs) > 1 and pallas: the ConfigError combination —
            # route around it with a single-device engine on devs[0]
            eng = TaurusEngine.from_context(ctx, mesh=mesh,
                                            kernel_backend=kb)
        shards.append(EngineShard(
            i, ctx, eng, fused=fused, dedup=dedup, ks_dedup=ks_dedup,
            max_inflight=max_inflight,
            elastic=ElasticAdmission(policy) if policy is not None else None,
            telemetry=telemetry, devices=devs))
    return shards
