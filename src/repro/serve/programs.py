"""Client-side program builders for the serving runtime.

Traces small wide-integer programs into `repro.compiler.ir` graphs and
encrypts/decrypts their radix inputs/outputs.  A client keeps the secret
key; the runtime only ever sees the compiled graph and big-key digit
ciphertexts.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.compiler.ir import Graph, trace
from repro.core.integer import IntegerContext, RadixCiphertext


def radix_binop_program(op: str, bits: int, msg_bits: int) -> Graph:
    """Graph of one radix binary op (radix_add/sub/mul/cmp) over two
    D-digit vectors."""
    d = bits // msg_bits

    def fn(a, b):
        return getattr(a, op)(b, msg_bits=msg_bits)

    return trace(fn, (d,), (d,))


def radix_unop_program(op: str, bits: int, msg_bits: int) -> Graph:
    """Graph of one radix unary op (radix_relu) over a D-digit vector."""
    d = bits // msg_bits

    def fn(a):
        return getattr(a, op)(msg_bits=msg_bits)

    return trace(fn, (d,))


def encrypt_request_inputs(ic: IntegerContext, key: jax.Array,
                           values: list, bits: int,
                           msg_bits: int | None = None) -> list:
    """Encrypt one integer per graph input; returns the (D, k*N+1) digit
    arrays the interpreter consumes."""
    out = []
    for v in values:
        key, sub = jax.random.split(key)
        out.append(ic.encrypt(sub, int(v), bits, msg_bits).digits)
    return out


def decrypt_radix_output(ic: IntegerContext, arr, bits: int,
                         msg_bits: int | None = None) -> list:
    """Decrypt an interpreter output of one or more digit vectors back to
    integers (client side)."""
    spec = ic.spec(bits, msg_bits)
    vecs = np.asarray(arr).reshape(-1, spec.n_digits, arr.shape[-1])
    return [ic.decrypt(RadixCiphertext(spec, v)) for v in vecs]
