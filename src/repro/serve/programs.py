"""Client-side program builders for the serving runtime.

Thin compatibility wrappers over the `repro.api` tracing front door:
the graphs are built by `repro.api.trace_program` from `EncryptedInt`
operator traces, so a program submitted to `ServeRuntime` is the SAME
object a `Session` traces — one program contract for every execution
path.  A client keeps the secret key; the runtime only ever sees the
compiled graph and big-key digit ciphertexts.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.api.session import trace_program
from repro.api.tracing import IntSpec
from repro.compiler.ir import Graph
from repro.core.integer import IntegerContext, RadixCiphertext

_BINOPS = {
    "radix_add": lambda a, b: a + b,
    "radix_sub": lambda a, b: a - b,
    "radix_mul": lambda a, b: a * b,
    "radix_cmp": lambda a, b: a.cmp(b),
}

_UNOPS = {
    "radix_relu": lambda a: a.relu(),
}


def radix_binop_program(op: str, bits: int, msg_bits: int) -> Graph:
    """Graph of one radix binary op (radix_add/sub/mul/cmp) over two
    D-digit vectors."""
    spec = IntSpec(bits, msg_bits)
    return trace_program(_BINOPS[op], (spec, spec)).graph


def radix_unop_program(op: str, bits: int, msg_bits: int) -> Graph:
    """Graph of one radix unary op (radix_relu) over a D-digit vector."""
    return trace_program(_UNOPS[op], (IntSpec(bits, msg_bits),)).graph


def fhe_ml_block_program(kind: str, d: int, bits: int, msg_bits: int,
                         seed: int = 0):
    """Mint encrypted-ML serving traffic: lower an `repro.fhe_ml`
    transformer block onto `bits`-wide radix activations, ready for
    `ServeRuntime.submit` / `Session.compile`.

    kind: "gpt2" (single-head block: radix_linear q/k/v, ct*ct attention
    via radix_mul, ReLU MLP) or "mlp" (two-layer ReLU MLP with random
    calibration weights).  Returns (graph, meta) exactly as the
    `repro.fhe_ml.lower` radix lowerings do — meta carries the
    `input_qmax` range certificate, IntSpec in/out specs and plaintext
    oracles.  Example::

        g, meta = fhe_ml_block_program("gpt2", d=2, bits=16, msg_bits=2)
        prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
        handle = sess.submit(prog, enc_inputs)       # backend="serve"
    """
    from repro.fhe_ml import lower
    if kind == "gpt2":
        return lower.lower_gpt2_block_radix(d, bits=bits, msg_bits=msg_bits,
                                            seed=seed)
    if kind == "mlp":
        rng = np.random.default_rng(seed)
        w1 = rng.normal(size=(d, 2 * d)) * 0.5
        w2 = rng.normal(size=(2 * d, d)) * 0.5
        return lower.lower_mlp_radix(w1, w2, bits=bits, msg_bits=msg_bits)
    raise ValueError(f"unknown fhe_ml block kind {kind!r} "
                     "(have 'gpt2', 'mlp')")


def encrypt_request_inputs(ic: IntegerContext, key: jax.Array,
                           values: list, bits: int,
                           msg_bits: int | None = None) -> list:
    """Encrypt one integer per graph input; returns the (D, k*N+1) digit
    arrays the interpreter consumes."""
    out = []
    for v in values:
        key, sub = jax.random.split(key)
        out.append(ic.encrypt(sub, int(v), bits, msg_bits).digits)
    return out


def decrypt_radix_output(ic: IntegerContext, arr, bits: int,
                         msg_bits: int | None = None) -> list:
    """Decrypt an interpreter output of one or more digit vectors back to
    integers (client side)."""
    spec = ic.spec(bits, msg_bits)
    vecs = np.asarray(arr).reshape(-1, spec.n_digits, arr.shape[-1])
    return [ic.decrypt(RadixCiphertext(spec, v)) for v in vecs]
