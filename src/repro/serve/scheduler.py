"""Cross-request PBS round scheduler — the paper's key-reuse batching,
applied ONLINE across concurrent clients.

Each in-flight request executes its compiled IR program on its own worker
thread; every nonlinear step blocks in `FusedLutScheduler.submit` instead
of dispatching its own `engine.lut_batch`.  The LAST active request to
block becomes the round leader (a barrier, no dispatcher thread): it
groups all pending rounds by engine — i.e. by parameter set and
bootstrapping key, so each fused `lut_batch` streams the BSK once for the
whole group — deduplicates identical (ciphertext, LUT) rows
(`repro.compiler.passes.fused_round_dedup`, the serving-time face of the
paper's dedup passes), pads the fused batch to a reusable compiled shape,
dispatches ONE batched PBS per group, and scatters the refreshed
ciphertexts back to every waiting request.

Why this wins (measured in `benchmarks/serve_throughput.py`): a fused
round replaces N small `lut_batch` calls with one large one, so the fixed
per-dispatch cost is paid once, per-ciphertext blind-rotation cost drops
with batch size (the Fig. 13 bandwidth argument), per-request padding
waste disappears, and duplicate work (request retries, replayed queries)
is bootstrapped exactly once.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.passes import fused_round_dedup
from repro.core import glwe
from repro.core.engine import TaurusEngine, validate_lut_tables
from repro.core.integer import _pad_batch
from repro.obs import StatsView, Telemetry, engine_key_bytes


@dataclasses.dataclass
class _Pending:
    """One request's blocked PBS round."""
    engine: object
    cts: jax.Array          # (B, k*N+1)
    polys: jax.Array        # (B, N)
    keys: Optional[list] = None     # per-row (ct, poly) dedup digests
    result: Optional[jax.Array] = None
    error: Optional[BaseException] = None
    round_id: Optional[int] = None  # fused batch id, set by the leader


def _row_keys(cts: jax.Array, polys: jax.Array) -> list:
    """Per-row (ciphertext, LUT-poly) dedup keys.  Computed on the
    REQUEST's own thread before it blocks at the barrier, so the round
    leader's critical path is a dict scan instead of a host sync + hash
    of the whole fused batch."""
    ct_rows, poly_rows = np.asarray(cts), np.asarray(polys)
    return [(ct_rows[i].tobytes(), poly_rows[i].tobytes())
            for i in range(ct_rows.shape[0])]


class FusedEngineProxy:
    """Engine facade handed to per-request interpreters.

    Linear ops run locally (LPU work needs no cross-request fusion);
    every `lut_batch` routes through the shared scheduler so concurrent
    requests' rounds fuse into one BSK-streaming batch."""

    fused = True

    def __init__(self, scheduler: "FusedLutScheduler", engine: TaurusEngine):
        self._scheduler = scheduler
        self._engine = engine

    @property
    def params(self):
        return self._engine.params

    @property
    def batch_size(self):
        return self._engine.batch_size

    def lut_batch(self, cts: jax.Array, lut_polys: jax.Array) -> jax.Array:
        if lut_polys.shape[0] != cts.shape[0]:
            raise ValueError(
                f"lut_batch: {cts.shape[0]} ciphertexts but "
                f"{lut_polys.shape[0]} LUT polynomials")
        keys = _row_keys(cts, lut_polys) if self._scheduler.dedup else None
        return self._scheduler.submit(self._engine, cts, lut_polys, keys)

    def lut_batch_tables(self, cts: jax.Array, tables) -> jax.Array:
        tables = validate_lut_tables(cts, tables, self.params)
        return self.lut_batch(
            cts, glwe.make_lut_polys_cached(tables, self.params))

    # -- linear ops delegate straight to the engine -------------------------
    def add(self, a, b):
        return self._engine.add(a, b)

    def sub(self, a, b):
        return self._engine.sub(a, b)

    def scalar_mul(self, a, c):
        return self._engine.scalar_mul(a, c)

    def add_plain(self, a, msg):
        return self._engine.add_plain(a, msg)

    def trivial(self, msg):
        return self._engine.trivial(msg)


class FusedLutScheduler:
    """Barrier-style round scheduler over any number of engines.

    `register()`/`unregister()` bracket each active request; `submit()`
    blocks a request's round until every active request is blocked (or
    `max_wait_s` elapses — stragglers stuck in long linear stretches
    can't stall the fleet forever), then the leader dispatches the fused
    round.  Used through `proxy(engine)`, which returns the engine facade
    request interpreters consume.

    Example (what `ServeRuntime` does per worker)::

        sched = FusedLutScheduler(dedup=True)
        eng = sched.proxy(engine)          # hand to an IrInterpreter
        sched.register()                   # request becomes barrier-width
        ...                                # eng.lut_batch calls now fuse
        sched.unregister()
        print(sched.dedup_hit_rate, sched.mean_occupancy)
    """

    def __init__(self, *, dedup: bool = True, pad_batches: bool = True,
                 max_wait_s: float = 10.0,
                 telemetry: Optional[Telemetry] = None):
        self.dedup = dedup
        self.pad_batches = pad_batches
        self.max_wait_s = max_wait_s
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._cv = threading.Condition()
        self._active = 0
        self._pending: list = []
        self._round_seq = 0
        tel = self.telemetry
        self._c = {
            "fused_rounds": tel.counter("sched.fused_rounds"),
            "logical_luts": tel.counter("sched.logical_luts"),
            "dispatched_luts": tel.counter("sched.dispatched_luts"),
            "padded_luts": tel.counter("sched.padded_luts"),
            "dedup_hits": tel.counter("sched.dedup_hits"),
        }
        self._occ_hist = tel.histogram("sched.occupancy")
        # blocked requests / active requests, bounded observability log
        self._occupancy: collections.deque = collections.deque(maxlen=10_000)
        # per-engine (bsk, ksk) byte sizes, resolved once per engine
        self._key_bytes: dict = {}

    @property
    def stats(self) -> StatsView:
        """Backward-compatible stats mapping: the historical dict keys,
        now read live off the metrics registry counters.

        fused_rounds      engine-group dispatches
        logical_luts      rows requested by interpreters
        dispatched_luts   rows after dedup, before padding
        padded_luts       rows entering engine.lut_batch
        dedup_hits        rows removed by online (ct, LUT) dedup
        occupancy         bounded deque of per-round occupancy samples
        """
        sources: dict = dict(self._c)
        sources["occupancy"] = self._occupancy
        return StatsView(sources)

    # -- lifecycle -----------------------------------------------------------
    def proxy(self, engine: TaurusEngine) -> FusedEngineProxy:
        return FusedEngineProxy(self, engine)

    def register(self) -> None:
        """Mark one request as actively executing (fusion barrier width)."""
        with self._cv:
            self._active += 1

    def unregister(self) -> None:
        with self._cv:
            self._active -= 1
            # a finishing request may complete the barrier for the rest
            self._cv.notify_all()

    # -- metrics -------------------------------------------------------------
    @property
    def dedup_hit_rate(self) -> float:
        n = self._c["logical_luts"].value
        return self._c["dedup_hits"].value / n if n else 0.0

    @property
    def mean_occupancy(self) -> float:
        occ = self._occupancy
        return float(np.mean(occ)) if occ else 0.0

    # -- the blocking round entry -------------------------------------------
    def submit(self, engine: TaurusEngine, cts: jax.Array,
               polys: jax.Array, keys: Optional[list] = None) -> jax.Array:
        entry = _Pending(engine, cts, polys,
                         keys if self.dedup else None)
        deadline = time.monotonic() + self.max_wait_s
        with self.telemetry.span("pbs_round", cat="sched",
                                 rows=int(cts.shape[0])) as sp:
            with self._cv:
                self._pending.append(entry)
                while entry.result is None and entry.error is None:
                    if self._pending and len(self._pending) >= self._active:
                        self._dispatch_locked()     # barrier complete: lead
                        continue
                    if time.monotonic() >= deadline:
                        if entry in self._pending:
                            # straggler timeout: flush a partial round rather
                            # than stall the fleet forever
                            self._dispatch_locked()
                            continue
                        # our entry is owned by an in-flight dispatch (lock
                        # released by its leader) — don't flush OTHER
                        # requests' fresh entries solo or spin; just wait
                        deadline = time.monotonic() + self.max_wait_s
                    # leaders/unregister notify promptly; the timeout only
                    # bounds how late a deadline-triggered partial dispatch
                    # can fire
                    self._cv.wait(timeout=0.25)
            # the fused batch id this round landed in (the leader stamps it)
            sp.set(round=entry.round_id)
        if entry.error is not None:
            raise RuntimeError("fused PBS round failed") from entry.error
        return entry.result

    # -- leader dispatch (called with the lock held) ------------------------
    def _dispatch_locked(self) -> None:
        pending, self._pending = self._pending, []
        if not pending:
            return
        occupancy = len(pending) / max(self._active, len(pending))
        self._occupancy.append(occupancy)
        self._occ_hist.observe(occupancy)
        groups: dict = {}
        for e in pending:
            groups.setdefault(id(e.engine), []).append(e)
        # assign fused batch ids while the lock is still held (the seq
        # counter is lock-protected state) so blocked requests see them
        # the moment their result lands
        rounds: list = []
        for entries in groups.values():
            rid = self._round_seq
            self._round_seq += 1
            for e in entries:
                e.round_id = rid
            rounds.append((rid, entries))
        # the heavy part (the dispatch may trigger an XLA compile) runs
        # with the lock RELEASED so new requests can register/enqueue for
        # the next round meanwhile; the popped entries are owned by this
        # leader alone, and the metric counters take their own locks (a
        # straggler-timeout leader can run concurrently)
        self._cv.release()
        try:
            for rid, entries in rounds:
                try:
                    self._dispatch_group(entries[0].engine, entries, rid,
                                         occupancy)
                except BaseException as err:  # noqa: BLE001 — fan it out
                    for e in entries:
                        e.error = err
        finally:
            self._cv.acquire()
        self._cv.notify_all()

    def _engine_key_bytes(self, engine: TaurusEngine) -> tuple:
        kb = self._key_bytes.get(id(engine))
        if kb is None:
            kb = self._key_bytes[id(engine)] = (
                engine.key_bytes if hasattr(engine, "key_bytes")
                else engine_key_bytes(engine))
        return kb

    def _dispatch_group(self, engine: TaurusEngine, entries: list,
                        round_id: int, occupancy: float) -> None:
        """One fused lut_batch for every round sharing this engine's BSK;
        publishes round composition metrics and the bandwidth ledger row."""
        tel = self.telemetry
        cts = jnp.concatenate([e.cts for e in entries], axis=0)
        polys = jnp.concatenate([e.polys for e in entries], axis=0)
        n = int(cts.shape[0])
        hits = 0
        with tel.span("fused_round", cat="sched", round=round_id,
                      participants=len(entries), rows=n,
                      occupancy=occupancy) as sp:
            inverse = None
            if self.dedup:
                keys: list = []
                for e in entries:  # workers pre-hash; direct submits fall back
                    keys.extend(e.keys if e.keys is not None
                                else _row_keys(e.cts, e.polys))
                unique_idx, inverse, hits = fused_round_dedup(keys)
                if hits:
                    sel = np.asarray(unique_idx)
                    cts, polys = cts[sel], polys[sel]
                else:
                    inverse = None
            nb = int(cts.shape[0])
            if self.pad_batches:
                p = _pad_batch(nb)
                if p > nb:                      # tile real rows to a reusable
                    reps = -(-p // nb)          # compiled batch shape
                    cts = jnp.tile(cts, (reps, 1))[:p]
                    polys = jnp.tile(polys, (reps, 1))[:p]
            padded = int(cts.shape[0])
            sp.set(dedup_hits=hits, dispatched=nb, padded=padded)
            out = engine.lut_batch(cts, polys)[:nb]
        self._c["fused_rounds"].inc()
        self._c["logical_luts"].inc(n)
        self._c["dedup_hits"].inc(hits)
        self._c["dispatched_luts"].inc(nb)
        self._c["padded_luts"].inc(padded)
        bsk_b, ksk_b = self._engine_key_bytes(engine)
        tel.bandwidth.account_round(
            participants=len(entries), rows_logical=n, rows_dispatched=nb,
            rows_padded=padded, bsk_bytes=bsk_b, ksk_bytes=ksk_b)
        if inverse is not None:
            out = out[np.asarray(inverse)]
        ofs = 0
        for e in entries:
            b = int(e.cts.shape[0])
            e.result = out[ofs:ofs + b]
            ofs += b
