"""Cross-request PBS round scheduler — the paper's key-reuse batching,
applied ONLINE across concurrent clients.

Each in-flight request executes its compiled IR program on its own worker
thread; every nonlinear step blocks in `FusedLutScheduler.submit` instead
of dispatching its own `engine.lut_batch`.  The LAST active request to
block becomes the round leader (a barrier, no dispatcher thread): it
groups all pending rounds by engine — i.e. by parameter set and
bootstrapping key, so each fused `lut_batch` streams the BSK once for the
whole group — deduplicates identical (ciphertext, LUT) rows
(`repro.compiler.passes.fused_round_dedup`, the serving-time face of the
paper's dedup passes), pads the fused batch to a reusable compiled shape,
dispatches ONE batched PBS per group, and scatters the refreshed
ciphertexts back to every waiting request.

Why this wins (measured in `benchmarks/serve_throughput.py`): a fused
round replaces N small `lut_batch` calls with one large one, so the fixed
per-dispatch cost is paid once, per-ciphertext blind-rotation cost drops
with batch size (the Fig. 13 bandwidth argument), per-request padding
waste disappears, and duplicate work (request retries, replayed queries)
is bootstrapped exactly once.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.passes import fused_round_dedup
from repro.core import glwe
from repro.core.engine import TaurusEngine, validate_lut_tables
from repro.core.integer import _pad_batch
from repro.obs import StatsView, Telemetry, engine_key_bytes


@dataclasses.dataclass
class _Pending:
    """One request's blocked PBS round."""
    engine: object
    cts: jax.Array          # (B, k*N+1)
    polys: jax.Array        # (B, N)
    keys: Optional[list] = None     # per-row (ct, poly) dedup digests
    result: Optional[jax.Array] = None
    error: Optional[BaseException] = None
    round_id: Optional[int] = None  # fused batch id, set by the leader


def _row_keys(cts: jax.Array, polys: jax.Array) -> list:
    """Per-row (ciphertext, LUT-poly) dedup keys.  Computed on the
    REQUEST's own thread before it blocks at the barrier, so the round
    leader's critical path is a dict scan instead of a host sync + hash
    of the whole fused batch."""
    ct_rows, poly_rows = np.asarray(cts), np.asarray(polys)
    return [(ct_rows[i].tobytes(), poly_rows[i].tobytes())
            for i in range(ct_rows.shape[0])]


class FusedEngineProxy:
    """Engine facade handed to per-request interpreters.

    Linear ops run locally (LPU work needs no cross-request fusion);
    every `lut_batch` routes through the shared scheduler so concurrent
    requests' rounds fuse into one BSK-streaming batch."""

    fused = True

    def __init__(self, scheduler: "FusedLutScheduler", engine: TaurusEngine):
        self._scheduler = scheduler
        self._engine = engine

    @property
    def params(self):
        return self._engine.params

    @property
    def batch_size(self):
        return self._engine.batch_size

    def lut_batch(self, cts: jax.Array, lut_polys: jax.Array) -> jax.Array:
        if lut_polys.shape[0] != cts.shape[0]:
            raise ValueError(
                f"lut_batch: {cts.shape[0]} ciphertexts but "
                f"{lut_polys.shape[0]} LUT polynomials")
        sched = self._scheduler
        # pre-hash for full-row dedup AND the KS-level partial dedup —
        # both consume these digests on the leader's dict-scan path
        keys = (_row_keys(cts, lut_polys)
                if (sched.dedup or sched.ks_dedup) else None)
        return sched.submit(self._engine, cts, lut_polys, keys)

    def lut_batch_tables(self, cts: jax.Array, tables) -> jax.Array:
        tables = validate_lut_tables(cts, tables, self.params)
        return self.lut_batch(
            cts, glwe.make_lut_polys_cached(tables, self.params))

    # -- linear ops delegate straight to the engine -------------------------
    def add(self, a, b):
        return self._engine.add(a, b)

    def sub(self, a, b):
        return self._engine.sub(a, b)

    def scalar_mul(self, a, c):
        return self._engine.scalar_mul(a, c)

    def add_plain(self, a, msg):
        return self._engine.add_plain(a, msg)

    def trivial(self, msg):
        return self._engine.trivial(msg)


class FusedLutScheduler:
    """Barrier-style round scheduler over any number of engines.

    `register()`/`unregister()` bracket each active request; `submit()`
    blocks a request's round until every active request is blocked (or
    `max_wait_s` elapses — stragglers stuck in long linear stretches
    can't stall the fleet forever), then the leader dispatches the fused
    round.  Used through `proxy(engine)`, which returns the engine facade
    request interpreters consume.

    Example (what `ServeRuntime` does per worker)::

        sched = FusedLutScheduler(dedup=True)
        eng = sched.proxy(engine)          # hand to an IrInterpreter
        sched.register()                   # request becomes barrier-width
        ...                                # eng.lut_batch calls now fuse
        sched.unregister()
        print(sched.dedup_hit_rate, sched.mean_occupancy)
    """

    def __init__(self, *, dedup: bool = True, ks_dedup: bool = True,
                 pad_batches: bool = True,
                 max_wait_s: float = 10.0,
                 telemetry: Optional[Telemetry] = None,
                 shard_ns: Optional[str] = None):
        self.dedup = dedup
        # KS-level partial dedup: rows sharing a CIPHERTEXT but not a
        # table key-switch once and fan the small-key result out across
        # their tables (engines exposing keyswitch/lut_batch_small only)
        self.ks_dedup = ks_dedup
        self.pad_batches = pad_batches
        self.max_wait_s = max_wait_s
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        # per-shard metric namespace (e.g. "serve.shard.0"): every round
        # counter below lands in the shared sched.* aggregate AND, when
        # set, in this shard's own serve.shard.<i>.* counters
        self.shard_ns = shard_ns
        self._cv = threading.Condition()
        self._active = 0
        self._pending: list = []
        self._round_seq = 0
        tel = self.telemetry
        names = ("fused_rounds", "logical_luts", "dispatched_luts",
                 "padded_luts", "dedup_hits", "ks_dedup_hits")
        self._c = {k: tel.counter(f"sched.{k}") for k in names}
        self._shard_c = ({k: tel.counter(f"{shard_ns}.{k}") for k in names}
                         if shard_ns else None)
        self._occ_hist = tel.histogram("sched.occupancy")
        # blocked requests / active requests, bounded observability log
        self._occupancy: collections.deque = collections.deque(maxlen=10_000)
        # per-engine (bsk, ksk) byte sizes, resolved once per engine
        self._key_bytes: dict = {}

    @property
    def stats(self) -> StatsView:
        """Backward-compatible stats mapping: the historical dict keys,
        now read live off the metrics registry counters.

        fused_rounds      engine-group dispatches
        logical_luts      rows requested by interpreters
        dispatched_luts   rows after dedup, before padding
        padded_luts       rows entering engine.lut_batch
        dedup_hits        rows removed by online (ct, LUT) dedup
        ks_dedup_hits     rows whose keyswitch was shared (same ct,
                          different table — KS-level partial dedup)
        occupancy         bounded deque of per-round occupancy samples
        """
        sources: dict = dict(self._c)
        sources["occupancy"] = self._occupancy
        return StatsView(sources)

    def _inc(self, key: str, n: int = 1) -> None:
        """Bump one round counter in the shared sched.* aggregate and,
        for a shard-owned scheduler, in its serve.shard.<i>.* mirror."""
        self._c[key].inc(n)
        if self._shard_c is not None:
            self._shard_c[key].inc(n)

    # -- lifecycle -----------------------------------------------------------
    def proxy(self, engine: TaurusEngine) -> FusedEngineProxy:
        return FusedEngineProxy(self, engine)

    def register(self) -> None:
        """Mark one request as actively executing (fusion barrier width)."""
        with self._cv:
            self._active += 1

    def unregister(self) -> None:
        with self._cv:
            self._active -= 1
            # a finishing request may complete the barrier for the rest
            self._cv.notify_all()

    # -- metrics -------------------------------------------------------------
    @property
    def dedup_hit_rate(self) -> float:
        n = self._c["logical_luts"].value
        return self._c["dedup_hits"].value / n if n else 0.0

    @property
    def mean_occupancy(self) -> float:
        occ = self._occupancy
        return float(np.mean(occ)) if occ else 0.0

    # -- the blocking round entry -------------------------------------------
    def submit(self, engine: TaurusEngine, cts: jax.Array,
               polys: jax.Array, keys: Optional[list] = None) -> jax.Array:
        entry = _Pending(engine, cts, polys,
                         keys if self.dedup else None)
        deadline = time.monotonic() + self.max_wait_s
        with self.telemetry.span("pbs_round", cat="sched",
                                 rows=int(cts.shape[0])) as sp:
            with self._cv:
                self._pending.append(entry)
                while entry.result is None and entry.error is None:
                    if self._pending and len(self._pending) >= self._active:
                        self._dispatch_locked()     # barrier complete: lead
                        continue
                    if time.monotonic() >= deadline:
                        if entry in self._pending:
                            # straggler timeout: flush a partial round rather
                            # than stall the fleet forever
                            self._dispatch_locked()
                            continue
                        # our entry is owned by an in-flight dispatch (lock
                        # released by its leader) — don't flush OTHER
                        # requests' fresh entries solo or spin; just wait
                        deadline = time.monotonic() + self.max_wait_s
                    # leaders/unregister notify promptly; the timeout only
                    # bounds how late a deadline-triggered partial dispatch
                    # can fire
                    self._cv.wait(timeout=0.25)
            # the fused batch id this round landed in (the leader stamps it)
            sp.set(round=entry.round_id)
        if entry.error is not None:
            raise RuntimeError("fused PBS round failed") from entry.error
        return entry.result

    # -- leader dispatch (called with the lock held) ------------------------
    def _dispatch_locked(self) -> None:
        pending, self._pending = self._pending, []
        if not pending:
            return
        occupancy = len(pending) / max(self._active, len(pending))
        self._occupancy.append(occupancy)
        self._occ_hist.observe(occupancy)
        groups: dict = {}
        for e in pending:
            groups.setdefault(id(e.engine), []).append(e)
        # assign fused batch ids while the lock is still held (the seq
        # counter is lock-protected state) so blocked requests see them
        # the moment their result lands
        rounds: list = []
        for entries in groups.values():
            rid = self._round_seq
            self._round_seq += 1
            for e in entries:
                e.round_id = rid
            rounds.append((rid, entries))
        # the heavy part (the dispatch may trigger an XLA compile) runs
        # with the lock RELEASED so new requests can register/enqueue for
        # the next round meanwhile; the popped entries are owned by this
        # leader alone, and the metric counters take their own locks (a
        # straggler-timeout leader can run concurrently)
        self._cv.release()
        try:
            for rid, entries in rounds:
                try:
                    self._dispatch_group(entries[0].engine, entries, rid,
                                         occupancy)
                except BaseException as err:  # noqa: BLE001 — fan it out
                    for e in entries:
                        e.error = err
        finally:
            self._cv.acquire()
        self._cv.notify_all()

    def _engine_key_bytes(self, engine: TaurusEngine) -> tuple:
        kb = self._key_bytes.get(id(engine))
        if kb is None:
            kb = self._key_bytes[id(engine)] = (
                engine.key_bytes if hasattr(engine, "key_bytes")
                else engine_key_bytes(engine))
        return kb

    def _dispatch_group(self, engine: TaurusEngine, entries: list,
                        round_id: int, occupancy: float) -> None:
        """One fused lut_batch for every round sharing this engine's BSK;
        publishes round composition metrics and the bandwidth ledger row."""
        tel = self.telemetry
        cts = jnp.concatenate([e.cts for e in entries], axis=0)
        polys = jnp.concatenate([e.polys for e in entries], axis=0)
        n = int(cts.shape[0])
        hits = 0
        with tel.span("fused_round", cat="sched", round=round_id,
                      participants=len(entries), rows=n,
                      occupancy=occupancy) as sp:
            all_keys: Optional[list] = None
            if self.dedup or self.ks_dedup:
                all_keys = []
                for e in entries:  # workers pre-hash; direct submits fall back
                    all_keys.extend(e.keys if e.keys is not None
                                    else _row_keys(e.cts, e.polys))
            inverse = None
            sel = None
            if self.dedup:
                unique_idx, inverse, hits = fused_round_dedup(all_keys)
                if hits:
                    sel = np.asarray(unique_idx)
                    cts, polys = cts[sel], polys[sel]
                else:
                    inverse = None
            nb = int(cts.shape[0])
            # KS-level partial dedup (ISSUE 10): among the dispatched
            # rows, those sharing a CIPHERTEXT but not a table (the radix
            # carry rounds' msg/carry table pairs are the canonical case)
            # key-switch once; the small-key result fans out across their
            # tables and the round resumes through lut_batch_small.
            # Decrypt-identical: keyswitch∘lut_batch_small IS lut_batch.
            ks_hits = 0
            ks_plan = None
            if (self.ks_dedup and nb > 1
                    and getattr(engine, "supports_ks_split", False)):
                if all_keys is not None:
                    rows = sel if sel is not None else range(n)
                    ct_keys = [all_keys[j][0] for j in rows]
                else:
                    arr = np.asarray(cts)
                    ct_keys = [arr[i].tobytes() for i in range(nb)]
                uq, ct_inv, ks_hits = fused_round_dedup(ct_keys)
                if ks_hits:
                    ks_plan = (np.asarray(uq), np.asarray(ct_inv))
            if ks_plan is not None:
                uq_idx, ct_inv = ks_plan
                u = int(uq_idx.shape[0])
                ucts = cts[uq_idx]
                if self.pad_batches:        # quantize the KS batch shape too
                    pu = _pad_batch(u)
                    if pu > u:
                        reps = -(-pu // u)
                        ucts = jnp.tile(ucts, (reps, 1))[:pu]
                body = engine.keyswitch(ucts)[:u][ct_inv]
            else:
                body = cts
            if self.pad_batches:
                p = _pad_batch(nb)
                if p > nb:                      # tile real rows to a reusable
                    reps = -(-p // nb)          # compiled batch shape
                    body = jnp.tile(body, (reps, 1))[:p]
                    polys = jnp.tile(polys, (reps, 1))[:p]
            padded = int(body.shape[0])
            sp.set(dedup_hits=hits, ks_dedup_hits=ks_hits,
                   dispatched=nb, padded=padded)
            if ks_plan is not None:
                out = engine.lut_batch_small(body, polys)[:nb]
            else:
                out = engine.lut_batch(body, polys)[:nb]
        self._inc("fused_rounds")
        self._inc("logical_luts", n)
        self._inc("dedup_hits", hits)
        self._inc("ks_dedup_hits", ks_hits)
        self._inc("dispatched_luts", nb)
        self._inc("padded_luts", padded)
        bsk_b, ksk_b = self._engine_key_bytes(engine)
        tel.bandwidth.account_round(
            participants=len(entries), rows_logical=n, rows_dispatched=nb,
            rows_padded=padded, bsk_bytes=bsk_b, ksk_bytes=ksk_b)
        if self.shard_ns is not None:
            # the bandwidth ledger aggregates across shards; the per-shard
            # key-stream traffic lands in this shard's own namespace
            tel.counter(f"{self.shard_ns}.bsk_bytes_streamed").inc(bsk_b)
            tel.counter(f"{self.shard_ns}.ksk_bytes_streamed").inc(ksk_b)
        if inverse is not None:
            out = out[np.asarray(inverse)]
        ofs = 0
        for e in entries:
            b = int(e.cts.shape[0])
            e.result = out[ofs:ofs + b]
            ofs += b
