"""Cross-request PBS round scheduler — the paper's key-reuse batching,
applied ONLINE across concurrent clients.

Each in-flight request executes its compiled IR program on its own worker
thread; every nonlinear step blocks in `FusedLutScheduler.submit` instead
of dispatching its own `engine.lut_batch`.  The LAST active request to
block becomes the round leader (a barrier, no dispatcher thread): it
groups all pending rounds by engine — i.e. by parameter set and
bootstrapping key, so each fused `lut_batch` streams the BSK once for the
whole group — deduplicates identical (ciphertext, LUT) rows
(`repro.compiler.passes.fused_round_dedup`, the serving-time face of the
paper's dedup passes), pads the fused batch to a reusable compiled shape,
dispatches ONE batched PBS per group, and scatters the refreshed
ciphertexts back to every waiting request.

Why this wins (measured in `benchmarks/serve_throughput.py`): a fused
round replaces N small `lut_batch` calls with one large one, so the fixed
per-dispatch cost is paid once, per-ciphertext blind-rotation cost drops
with batch size (the Fig. 13 bandwidth argument), per-request padding
waste disappears, and duplicate work (request retries, replayed queries)
is bootstrapped exactly once.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.passes import fused_round_dedup
from repro.core import glwe
from repro.core.engine import TaurusEngine, validate_lut_tables
from repro.core.integer import _pad_batch


@dataclasses.dataclass
class _Pending:
    """One request's blocked PBS round."""
    engine: object
    cts: jax.Array          # (B, k*N+1)
    polys: jax.Array        # (B, N)
    keys: Optional[list] = None     # per-row (ct, poly) dedup digests
    result: Optional[jax.Array] = None
    error: Optional[BaseException] = None


def _row_keys(cts: jax.Array, polys: jax.Array) -> list:
    """Per-row (ciphertext, LUT-poly) dedup keys.  Computed on the
    REQUEST's own thread before it blocks at the barrier, so the round
    leader's critical path is a dict scan instead of a host sync + hash
    of the whole fused batch."""
    ct_rows, poly_rows = np.asarray(cts), np.asarray(polys)
    return [(ct_rows[i].tobytes(), poly_rows[i].tobytes())
            for i in range(ct_rows.shape[0])]


class FusedEngineProxy:
    """Engine facade handed to per-request interpreters.

    Linear ops run locally (LPU work needs no cross-request fusion);
    every `lut_batch` routes through the shared scheduler so concurrent
    requests' rounds fuse into one BSK-streaming batch."""

    fused = True

    def __init__(self, scheduler: "FusedLutScheduler", engine: TaurusEngine):
        self._scheduler = scheduler
        self._engine = engine

    @property
    def params(self):
        return self._engine.params

    @property
    def batch_size(self):
        return self._engine.batch_size

    def lut_batch(self, cts: jax.Array, lut_polys: jax.Array) -> jax.Array:
        if lut_polys.shape[0] != cts.shape[0]:
            raise ValueError(
                f"lut_batch: {cts.shape[0]} ciphertexts but "
                f"{lut_polys.shape[0]} LUT polynomials")
        keys = _row_keys(cts, lut_polys) if self._scheduler.dedup else None
        return self._scheduler.submit(self._engine, cts, lut_polys, keys)

    def lut_batch_tables(self, cts: jax.Array, tables) -> jax.Array:
        tables = validate_lut_tables(cts, tables, self.params)
        return self.lut_batch(
            cts, glwe.make_lut_polys_cached(tables, self.params))

    # -- linear ops delegate straight to the engine -------------------------
    def add(self, a, b):
        return self._engine.add(a, b)

    def sub(self, a, b):
        return self._engine.sub(a, b)

    def scalar_mul(self, a, c):
        return self._engine.scalar_mul(a, c)

    def add_plain(self, a, msg):
        return self._engine.add_plain(a, msg)

    def trivial(self, msg):
        return self._engine.trivial(msg)


class FusedLutScheduler:
    """Barrier-style round scheduler over any number of engines.

    `register()`/`unregister()` bracket each active request; `submit()`
    blocks a request's round until every active request is blocked (or
    `max_wait_s` elapses — stragglers stuck in long linear stretches
    can't stall the fleet forever), then the leader dispatches the fused
    round.  Used through `proxy(engine)`, which returns the engine facade
    request interpreters consume.

    Example (what `ServeRuntime` does per worker)::

        sched = FusedLutScheduler(dedup=True)
        eng = sched.proxy(engine)          # hand to an IrInterpreter
        sched.register()                   # request becomes barrier-width
        ...                                # eng.lut_batch calls now fuse
        sched.unregister()
        print(sched.dedup_hit_rate, sched.mean_occupancy)
    """

    def __init__(self, *, dedup: bool = True, pad_batches: bool = True,
                 max_wait_s: float = 10.0):
        self.dedup = dedup
        self.pad_batches = pad_batches
        self.max_wait_s = max_wait_s
        self._cv = threading.Condition()
        self._active = 0
        self._pending: list = []
        self.stats = {
            "fused_rounds": 0,       # engine-group dispatches
            "logical_luts": 0,       # rows requested by interpreters
            "dispatched_luts": 0,    # rows after dedup, before padding
            "padded_luts": 0,        # rows entering engine.lut_batch
            "dedup_hits": 0,
            # blocked requests / active requests, bounded observability log
            "occupancy": collections.deque(maxlen=10_000),
        }

    # -- lifecycle -----------------------------------------------------------
    def proxy(self, engine: TaurusEngine) -> FusedEngineProxy:
        return FusedEngineProxy(self, engine)

    def register(self) -> None:
        """Mark one request as actively executing (fusion barrier width)."""
        with self._cv:
            self._active += 1

    def unregister(self) -> None:
        with self._cv:
            self._active -= 1
            # a finishing request may complete the barrier for the rest
            self._cv.notify_all()

    # -- metrics -------------------------------------------------------------
    @property
    def dedup_hit_rate(self) -> float:
        n = self.stats["logical_luts"]
        return self.stats["dedup_hits"] / n if n else 0.0

    @property
    def mean_occupancy(self) -> float:
        occ = self.stats["occupancy"]
        return float(np.mean(occ)) if occ else 0.0

    # -- the blocking round entry -------------------------------------------
    def submit(self, engine: TaurusEngine, cts: jax.Array,
               polys: jax.Array, keys: Optional[list] = None) -> jax.Array:
        entry = _Pending(engine, cts, polys,
                         keys if self.dedup else None)
        deadline = time.monotonic() + self.max_wait_s
        with self._cv:
            self._pending.append(entry)
            while entry.result is None and entry.error is None:
                if self._pending and len(self._pending) >= self._active:
                    self._dispatch_locked()     # barrier complete: lead
                    continue
                if time.monotonic() >= deadline:
                    if entry in self._pending:
                        # straggler timeout: flush a partial round rather
                        # than stall the fleet forever
                        self._dispatch_locked()
                        continue
                    # our entry is owned by an in-flight dispatch (lock
                    # released by its leader) — don't flush OTHER
                    # requests' fresh entries solo or spin; just wait
                    deadline = time.monotonic() + self.max_wait_s
                # leaders/unregister notify promptly; the timeout only
                # bounds how late a deadline-triggered partial dispatch
                # can fire
                self._cv.wait(timeout=0.25)
        if entry.error is not None:
            raise RuntimeError("fused PBS round failed") from entry.error
        return entry.result

    # -- leader dispatch (called with the lock held) ------------------------
    def _dispatch_locked(self) -> None:
        pending, self._pending = self._pending, []
        if not pending:
            return
        self.stats["occupancy"].append(
            len(pending) / max(self._active, len(pending)))
        groups: dict = {}
        for e in pending:
            groups.setdefault(id(e.engine), []).append(e)
        # the heavy part (the dispatch may trigger an XLA compile) runs
        # with the lock RELEASED so new requests can register/enqueue for
        # the next round meanwhile; the popped entries are owned by this
        # leader alone, and stats deltas are folded back in UNDER the
        # lock (a straggler-timeout leader can run concurrently)
        deltas: list = []
        self._cv.release()
        try:
            for entries in groups.values():
                try:
                    deltas.append(
                        self._dispatch_group(entries[0].engine, entries))
                except BaseException as err:  # noqa: BLE001 — fan it out
                    for e in entries:
                        e.error = err
        finally:
            self._cv.acquire()
        for d in deltas:
            for k, v in d.items():
                self.stats[k] += v
        self._cv.notify_all()

    def _dispatch_group(self, engine: TaurusEngine, entries: list) -> dict:
        """One fused lut_batch for every round sharing this engine's BSK.
        Returns the stats delta (folded into self.stats under the lock)."""
        cts = jnp.concatenate([e.cts for e in entries], axis=0)
        polys = jnp.concatenate([e.polys for e in entries], axis=0)
        n = int(cts.shape[0])
        delta = {"fused_rounds": 1, "logical_luts": n, "dedup_hits": 0}
        inverse = None
        if self.dedup:
            keys: list = []
            for e in entries:   # workers pre-hash; direct submits fall back
                keys.extend(e.keys if e.keys is not None
                            else _row_keys(e.cts, e.polys))
            unique_idx, inverse, hits = fused_round_dedup(keys)
            delta["dedup_hits"] = hits
            if hits:
                sel = np.asarray(unique_idx)
                cts, polys = cts[sel], polys[sel]
            else:
                inverse = None
        nb = int(cts.shape[0])
        delta["dispatched_luts"] = nb
        if self.pad_batches:
            p = _pad_batch(nb)
            if p > nb:                      # tile real rows to a reusable
                reps = -(-p // nb)          # compiled batch shape
                cts = jnp.tile(cts, (reps, 1))[:p]
                polys = jnp.tile(polys, (reps, 1))[:p]
        delta["padded_luts"] = int(cts.shape[0])
        out = engine.lut_batch(cts, polys)[:nb]
        if inverse is not None:
            out = out[np.asarray(inverse)]
        ofs = 0
        for e in entries:
            b = int(e.cts.shape[0])
            e.result = out[ofs:ofs + b]
            ofs += b
        return delta
