"""Fault-tolerant step execution: retries, deadlines, checkpoint/restart.

On a real multi-pod deployment the failure modes are (a) device/host loss
(XLA raises), (b) stragglers (step wall-time far beyond the running
median), (c) data corruption (non-finite loss).  `StepRunner` wraps a
compiled step function with:

  * non-finite-loss skip (bad batch is dropped, step retried with the
    next batch — standard large-run hygiene),
  * straggler deadline: steps slower than `straggler_factor` x the
    running median are counted; persistent stragglers trigger a
    re-compile/re-shard callback (on TPU pods: reschedule the slice),
  * crash recovery: on exception the runner restores the latest
    checkpoint and continues (the driver loop in launch/train.py).

Everything is observable through `runner.stats`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class FaultConfig:
    max_retries: int = 3
    straggler_factor: float = 3.0
    straggler_patience: int = 5      # consecutive slow steps before action
    checkpoint_every: int = 100


class StepRunner:
    def __init__(self, step_fn: Callable, fault: FaultConfig = FaultConfig(),
                 on_failure: Optional[Callable] = None,
                 on_straggler: Optional[Callable] = None,
                 telemetry=None):
        self.step_fn = step_fn
        self.fault = fault
        self.on_failure = on_failure
        self.on_straggler = on_straggler
        # optional repro.obs.Telemetry: fault.* counters + retry instants
        # land in the same registry/trace as the serve-path spans
        self.telemetry = telemetry
        self.durations: list = []
        self.stats = {"retries": 0, "skipped_nonfinite": 0,
                      "straggler_events": 0, "failures": 0}
        self._slow_streak = 0

    def _count(self, key: str) -> None:
        self.stats[key] += 1
        if self.telemetry is not None:
            self.telemetry.counter(f"fault.{key}").inc()

    def _median(self) -> float:
        if len(self.durations) < 5:
            return float("inf")
        return float(np.median(self.durations[-50:]))

    def run(self, *args, **kwargs):
        """Execute one step with retry + straggler accounting.

        The wrapped step must return (..., metrics) with metrics["loss"]."""
        for attempt in range(self.fault.max_retries + 1):
            t0 = time.monotonic()
            try:
                out = self.step_fn(*args, **kwargs)
            except Exception:
                self._count("failures")
                if attempt >= self.fault.max_retries:
                    raise
                if self.on_failure is not None:
                    args, kwargs = self.on_failure(args, kwargs)
                self._count("retries")
                if self.telemetry is not None:
                    self.telemetry.instant("retry", cat="fault",
                                           attempt=attempt + 1)
                continue
            dt = time.monotonic() - t0
            metrics = out[-1] if isinstance(out, tuple) else None
            loss = metrics.get("loss") if isinstance(metrics, dict) else None
            if loss is not None and not bool(np.isfinite(np.asarray(loss))):
                self._count("skipped_nonfinite")
                return None  # caller advances to the next batch
            med = self._median()
            self.durations.append(dt)
            if dt > self.fault.straggler_factor * med:
                self._slow_streak += 1
                if self._slow_streak >= self.fault.straggler_patience:
                    self._count("straggler_events")
                    self._slow_streak = 0
                    if self.on_straggler is not None:
                        self.on_straggler()
            else:
                self._slow_streak = 0
            return out
        raise RuntimeError("unreachable")
