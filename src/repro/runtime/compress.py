"""Int8 gradient compression with error feedback (1-bit-Adam-style EF).

At 1000+-node scale the gradient all-reduce over the DP axes dominates
step latency for small per-device batches.  Compressing gradients to int8
with per-tensor scales cuts DP collective bytes 4x (vs f32) / 2x (vs
bf16); the quantization residual is carried in an error-feedback buffer so
the SGD direction stays unbiased over time (Karimireddy et al. 2019).

Usage is purely functional and jit-friendly:

    comp = Int8Compressor()
    ef = comp.init(params)
    grads_q, ef = comp.roundtrip(grads, ef)   # inside train_step

`roundtrip` = compress -> (collective happens on the int8 view via the
optimizer's existing psum/GSPMD reduction of the dequantized values) ->
decompress + error update.  On a real mesh the int8 view is what crosses
ICI; the dry-run HLO shows the reduced bytes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    clip_sigma: float = 4.0     # scale = clip_sigma * rms

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)

    def compress(self, g, ef):
        """-> (q int8, scale f32 scalar, new residual)."""
        x = g.astype(F32) + ef
        rms = jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-12)
        scale = self.clip_sigma * rms / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(F32) * scale
        return q, scale, x - deq

    def roundtrip(self, grads, ef_state):
        """Compress+decompress every gradient leaf, updating error feedback.

        Returns (decompressed grads, new ef_state)."""
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(ef_state)
        outs, errs = [], []
        for g, e in zip(flat_g, flat_e):
            q, scale, err = self.compress(g, e)
            outs.append((q.astype(F32) * scale).astype(g.dtype))
            errs.append(err)
        return treedef.unflatten(outs), treedef.unflatten(errs)
