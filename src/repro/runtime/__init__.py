"""Distributed runtime: fault tolerance, elasticity, straggler mitigation,
gradient compression."""
from repro.runtime.fault import StepRunner, FaultConfig  # noqa: F401
from repro.runtime.elastic import ElasticMesh  # noqa: F401
from repro.runtime.compress import Int8Compressor  # noqa: F401
