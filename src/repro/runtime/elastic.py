"""Elastic mesh management: rebuild the mesh when devices come and go,
re-shard live state onto the new topology.

Real deployment: `jax.devices()` shrinks when a host drops out of the
coordination service; training must continue on the survivors (possibly
with a smaller data axis) and re-expand later.  This module implements
the re-mesh + re-shard procedure; on a single host it is exercised by
carving sub-meshes out of the local device set (tests/test_runtime.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass
class ElasticMesh:
    model_parallel: int = 1
    axis_names: tuple = ("data", "model")

    def build(self, devices: Optional[Sequence] = None) -> Mesh:
        """Largest (data, model) mesh over the healthy device set.

        `model_parallel` is fixed (weights layout must survive restarts);
        the data axis absorbs device loss: data = n_devices // model.
        """
        devs = list(devices if devices is not None else jax.devices())
        mp = self.model_parallel
        dp = len(devs) // mp
        if dp < 1:
            raise RuntimeError(
                f"{len(devs)} devices cannot host model_parallel={mp}")
        devs = devs[: dp * mp]
        arr = np.array(devs).reshape(dp, mp)
        return Mesh(arr, self.axis_names)

    def reshard(self, tree, specs, new_mesh: Mesh):
        """Re-shard a live pytree onto a new mesh (device_put handles the
        cross-topology transfer; on real hardware this is a resharding
        collective, here a host round-trip at worst)."""
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
            tree, specs)

    def shrink_then_grow(self, tree, specs, lost: int):
        """Simulate losing `lost` devices then recovering (test helper).
        Returns (tree_on_small, small_mesh, tree_back, full_mesh)."""
        full = self.build()
        devs = list(jax.devices())
        small = self.build(devs[: len(devs) - lost])
        t_small = self.reshard(tree, specs, small)
        t_back = self.reshard(t_small, specs, full)
        return t_small, small, t_back, full
