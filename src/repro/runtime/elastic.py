"""Elastic capacity management, two faces of one idea:

`ElasticMesh`       rebuild the device mesh when devices come and go,
                    re-shard live state onto the new topology (training
                    survives host loss; tests/test_runtime.py).

`ElasticAdmission`  resize a serving shard's concurrency limit
                    (`max_inflight`) from observed queue depth and
                    recent fused-wave occupancy — the per-shard
                    controller behind `ServeRuntime(..., elastic=True)`
                    (ISSUE 10).  Deterministic and lock-free: the
                    runtime calls `observe` under its own admission
                    lock, so the controller is plain state + policy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    """Tuning knobs for `ElasticAdmission`.

    ceiling          hard upper bound on the shard's concurrency limit
                     (the configured `max_inflight` — never exceeded).
    floor            lower bound the limit decays toward when idle.
    step_up          slots added per grow decision (backlog present,
                     every current slot busy, occupancy healthy).
    step_down        slots removed per shrink decision (no backlog and
                     spare slots).
    occupancy_floor  minimum recent fused-wave occupancy for growing:
                     adding workers to a shard whose barrier rounds are
                     already running half-empty only dilutes them.  A
                     shard with no occupancy signal yet (unfused, or no
                     round dispatched) is allowed to grow.
    """
    ceiling: int = 8
    floor: int = 1
    step_up: int = 1
    step_down: int = 1
    occupancy_floor: float = 0.5

    def __post_init__(self):
        if not (1 <= self.floor <= self.ceiling):
            raise ValueError(
                f"need 1 <= floor ({self.floor}) <= ceiling "
                f"({self.ceiling})")
        if self.step_up < 1 or self.step_down < 1:
            raise ValueError("step_up and step_down must be >= 1")


class ElasticAdmission:
    """Queue-depth + occupancy driven `max_inflight` controller.

    One instance per `EngineShard`.  The serving router consults
    `limit` on every admission and calls `observe` at the two points
    where shard pressure changes: when admission stalls with work still
    queued (a grow opportunity) and when a worker finishes with the
    queue empty (a shrink opportunity).  `high_water` records the
    largest limit ever granted — the burst tests pin it against the
    ceiling.
    """

    def __init__(self, policy: Optional[ElasticPolicy] = None):
        self.policy = policy if policy is not None else ElasticPolicy()
        self._limit = self.policy.floor
        self.high_water = self._limit
        self.grows = 0
        self.shrinks = 0

    @property
    def limit(self) -> int:
        return self._limit

    def observe(self, queue_depth: int, inflight: int,
                occupancy: Optional[float] = None) -> bool:
        """One controller step; returns True if the limit changed.

        Grow when there is a backlog, every granted slot is busy, and
        the occupancy signal (when present) clears the policy floor.
        Shrink toward max(floor, inflight) when the queue is empty and
        slots sit idle — the limit never cuts below work already
        running."""
        p = self.policy
        if queue_depth > 0 and inflight >= self._limit:
            if occupancy is not None and occupancy < p.occupancy_floor:
                return False
            new = min(p.ceiling, self._limit + p.step_up)
            if new != self._limit:
                self._limit = new
                self.high_water = max(self.high_water, new)
                self.grows += 1
                return True
            return False
        if queue_depth == 0 and inflight < self._limit:
            new = max(p.floor, inflight, self._limit - p.step_down)
            if new != self._limit:
                self._limit = new
                self.shrinks += 1
                return True
        return False


@dataclasses.dataclass
class ElasticMesh:
    model_parallel: int = 1
    axis_names: tuple = ("data", "model")

    def build(self, devices: Optional[Sequence] = None) -> Mesh:
        """Largest (data, model) mesh over the healthy device set.

        `model_parallel` is fixed (weights layout must survive restarts);
        the data axis absorbs device loss: data = n_devices // model.
        """
        devs = list(devices if devices is not None else jax.devices())
        mp = self.model_parallel
        dp = len(devs) // mp
        if dp < 1:
            raise RuntimeError(
                f"{len(devs)} devices cannot host model_parallel={mp}")
        devs = devs[: dp * mp]
        arr = np.array(devs).reshape(dp, mp)
        return Mesh(arr, self.axis_names)

    def reshard(self, tree, specs, new_mesh: Mesh):
        """Re-shard a live pytree onto a new mesh (device_put handles the
        cross-topology transfer; on real hardware this is a resharding
        collective, here a host round-trip at worst)."""
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
            tree, specs)

    def shrink_then_grow(self, tree, specs, lost: int):
        """Simulate losing `lost` devices then recovering (test helper).
        Returns (tree_on_small, small_mesh, tree_back, full_mesh)."""
        full = self.build()
        devs = list(jax.devices())
        small = self.build(devs[: len(devs) - lost])
        t_small = self.reshard(tree, specs, small)
        t_back = self.reshard(t_small, specs, full)
        return t_small, small, t_back, full
