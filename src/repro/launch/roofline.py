"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

Hardware model: TPU v5e —
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute term    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes   / (chips * HBM_BW)
    collective term = coll_bytes  / (chips * ICI_BW)

``compiled.cost_analysis()`` ignores while-loop trip counts (scan bodies
counted once), so the terms here come from `repro.launch.hlo_analysis`,
which re-derives loop-weighted per-device FLOPs / HBM bytes / collective
bytes from the compiled HLO text.  All analyzer numbers are PER DEVICE;
the formulas below therefore divide by per-chip peaks only.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.launch import hlo_analysis

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops (loop-weighted)
    hbm_bytes: float           # per-device bytes accessed (loop-weighted)
    coll_bytes: float          # per-device collective bytes
    chips: int
    coll_breakdown: dict
    model_flops: float = 0.0   # global 6*N*D (or 6*N_active*D)
    hbm_bytes_major: float = 0.0  # perfectly-fused-elementwise bound

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def t_memory_major(self) -> float:
        """Optimistic memory term: only dot/gather/scatter/DUS-bearing ops
        touch HBM (elementwise perfectly fused).  A TPU backend lands
        between this and t_memory."""
        return self.hbm_bytes_major / HBM_BW

    @property
    def t_bound_major(self) -> float:
        return max(self.t_compute, self.t_memory_major, self.t_collective)

    @property
    def mfu_bound_major(self) -> float:
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / self.t_bound_major if self.t_bound_major else 0.0

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU at this lowering: useful-FLOPs
        time / roofline-dominant time."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / self.t_bound if self.t_bound else 0.0

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful.
        >1 would mean undercounting; <1 indicates remat/halo/dedup waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_major_s": self.t_memory_major,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck, "mfu_bound": self.mfu_bound,
            "mfu_bound_major": self.mfu_bound_major,
            "flops_ratio": self.flops_ratio,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for a forward pass/prefill, 2*N_active per
    decoded token (D = tokens processed)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    # decode: one token per sequence; attention reads the whole KV cache —
    # count the matmul FLOPs only (2*N_active per token)
    return 2.0 * n_active * shape.global_batch


def from_compiled(compiled, chips: int, mflops: float) -> Roofline:
    costs = hlo_analysis.analyze(compiled.as_text())
    return Roofline(
        flops=costs.flops, hbm_bytes=costs.hbm_bytes,
        coll_bytes=costs.coll_bytes, chips=chips,
        coll_breakdown=costs.coll_breakdown, model_flops=mflops,
        hbm_bytes_major=costs.hbm_bytes_major)
