"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

Hardware model: TPU v5e —
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute term    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes   / (chips * HBM_BW)
    collective term = coll_bytes  / (chips * ICI_BW)

``compiled.cost_analysis()`` ignores while-loop trip counts (scan bodies
counted once), so the terms here come from `repro.launch.hlo_analysis`,
which re-derives loop-weighted per-device FLOPs / HBM bytes / collective
bytes from the compiled HLO text.  All analyzer numbers are PER DEVICE;
the formulas below therefore divide by per-chip peaks only.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.launch import hlo_analysis

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops (loop-weighted)
    hbm_bytes: float           # per-device bytes accessed (loop-weighted)
    coll_bytes: float          # per-device collective bytes
    chips: int
    coll_breakdown: dict
    model_flops: float = 0.0   # global 6*N*D (or 6*N_active*D)
    hbm_bytes_major: float = 0.0  # perfectly-fused-elementwise bound

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def t_memory_major(self) -> float:
        """Optimistic memory term: only dot/gather/scatter/DUS-bearing ops
        touch HBM (elementwise perfectly fused).  A TPU backend lands
        between this and t_memory."""
        return self.hbm_bytes_major / HBM_BW

    @property
    def t_bound_major(self) -> float:
        return max(self.t_compute, self.t_memory_major, self.t_collective)

    @property
    def mfu_bound_major(self) -> float:
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / self.t_bound_major if self.t_bound_major else 0.0

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU at this lowering: useful-FLOPs
        time / roofline-dominant time."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / self.t_bound if self.t_bound else 0.0

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful.
        >1 would mean undercounting; <1 indicates remat/halo/dedup waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_major_s": self.t_memory_major,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck, "mfu_bound": self.mfu_bound,
            "mfu_bound_major": self.mfu_bound_major,
            "flops_ratio": self.flops_ratio,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for a forward pass/prefill, 2*N_active per
    decoded token (D = tokens processed)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    # decode: one token per sequence; attention reads the whole KV cache —
    # count the matmul FLOPs only (2*N_active per token)
    return 2.0 * n_active * shape.global_batch


@dataclasses.dataclass
class PbsRoundModel:
    """Analytic per-round traffic/bandwidth model of one fused `lut_batch`
    round — the bound that gates the Pallas engine-room win.

    `fused_bytes` is the paper's key-reuse traffic: the evaluation keys
    stream from HBM ONCE per round regardless of batch size, plus O(B)
    ciphertext/LUT rows.  `unfused_bytes` re-streams the keys per
    ciphertext (the Morphling-XPU baseline, `lut_batch_xpu`).  The
    measured `FusedPbsPack.bytes_streamed_per_round` must never exceed
    `fused_bytes` (asserted by `benchmarks/kernels_bench.py`) — if it
    does, the residency contract broke and the speedup story with it.
    """
    bsk_bytes: int
    ksk_bytes: int
    ct_in_bytes: int           # one (big_n+1) u64 row
    ct_out_bytes: int
    lut_bytes: int             # one (N,) u64 test polynomial
    batch: int

    @property
    def key_bytes(self) -> int:
        return self.bsk_bytes + self.ksk_bytes

    @property
    def per_ct_bytes(self) -> int:
        return self.ct_in_bytes + self.ct_out_bytes + self.lut_bytes

    @property
    def fused_bytes(self) -> int:
        """Keys once + per-ciphertext rows (key-reuse residency)."""
        return self.key_bytes + self.batch * self.per_ct_bytes

    @property
    def unfused_bytes(self) -> int:
        """Keys re-streamed per ciphertext (no reuse baseline)."""
        return self.batch * (self.key_bytes + self.per_ct_bytes)

    @property
    def reuse_factor(self) -> float:
        return self.unfused_bytes / self.fused_bytes

    @property
    def t_memory(self) -> float:
        """HBM-bound wall clock of one fused round on the v5e model."""
        return self.fused_bytes / HBM_BW

    @property
    def arithmetic_intensity_keys(self) -> float:
        """MAC ops per key byte — scales with B under residency."""
        return float(self.batch) / max(self.key_bytes, 1)

    def to_dict(self) -> dict:
        return {
            "bsk_bytes": self.bsk_bytes, "ksk_bytes": self.ksk_bytes,
            "per_ct_bytes": self.per_ct_bytes, "batch": self.batch,
            "fused_bytes": self.fused_bytes,
            "unfused_bytes": self.unfused_bytes,
            "reuse_factor": self.reuse_factor,
            "t_memory_s": self.t_memory,
        }


def pbs_round_model(params, batch: int) -> PbsRoundModel:
    """Build the per-round bandwidth model from TFHE parameters.

    Key bytes match `TaurusEngine.key_bytes` exactly: the Fourier BSK is
    (n, k+1, level, k+1, N/2) complex128 and the KSK is
    (big_n, ks_level, n+1) uint64 — the fused pack's plane/limb layouts
    are byte-identical re-interpretations (2xf64 = c128, 2xu32 = u64),
    so reference and pallas engines share one model.
    """
    n, k, N = params.n, params.k, params.N
    bsk = n * (k + 1) * params.pbs_level * (k + 1) * (N // 2) * 16
    ksk = params.big_n * params.ks_level * (n + 1) * 8
    ct = (params.big_n + 1) * 8
    return PbsRoundModel(bsk_bytes=bsk, ksk_bytes=ksk, ct_in_bytes=ct,
                         ct_out_bytes=ct, lut_bytes=N * 8, batch=batch)


def from_compiled(compiled, chips: int, mflops: float) -> Roofline:
    costs = hlo_analysis.analyze(compiled.as_text())
    return Roofline(
        flops=costs.flops, hbm_bytes=costs.hbm_bytes,
        coll_bytes=costs.coll_bytes, chips=chips,
        coll_breakdown=costs.coll_breakdown, model_flops=mflops,
        hbm_bytes_major=costs.hbm_bytes_major)
