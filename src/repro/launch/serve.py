"""Batched serving driver: prefill + decode with a continuous batch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --batch 4 --prompt-len 32 --gen 64

Demonstrates the serving path the decode_* dry-run cells exercise: a KV
cache initialized at `max_len`, prefill via teacher-forced forward, then
token-by-token decode with greedy sampling.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_serve_step
from repro.launch.train import reduced_config
from repro.configs import get
from repro.models import build


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32, gen: int = 32,
          reduced: bool = True, model_parallel: int = 1, seed: int = 0):
    cfg = reduced_config(arch) if reduced else get(arch)
    model = build(cfg)
    mesh = mesh_lib.make_host_mesh(model_parallel)
    max_len = prompt_len + gen

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        params = jax.tree.map(jax.device_put, params,
                              mesh_lib.param_shardings(mesh, params))
        rng = np.random.default_rng(seed)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

        step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        cache = model.init_cache(batch, max_len)

        # prefill token-by-token through the decode path (exercises the
        # cache exactly as production does; a fused prefill is an
        # optimization the roofline prefill cells cover separately)
        t0 = time.time()
        logits = None
        for t in range(prompt_len):
            pos = jnp.full((batch, 1), t, jnp.int32)
            logits, cache = step(params, cache, prompts[:, t:t + 1], pos)
        t_prefill = time.time() - t0

        out_tokens = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for t in range(prompt_len, max_len):
            out_tokens.append(np.asarray(tok)[:, 0])
            pos = jnp.full((batch, 1), t, jnp.int32)
            logits, cache = step(params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        t_decode = time.time() - t0

        toks = np.stack(out_tokens, axis=1)
        print(f"[serve] prefill {prompt_len} toks x{batch} in {t_prefill:.2f}s; "
              f"decode {gen} toks x{batch} in {t_decode:.2f}s "
              f"({batch * gen / max(t_decode, 1e-9):.1f} tok/s)")
        print(f"[serve] first generated tokens: {toks[:, :8].tolist()}")
        return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen=args.gen, reduced=not args.full,
          model_parallel=args.model_parallel)


if __name__ == "__main__":
    main()
