"""Production meshes + sharding rules for the assigned-architecture pool.

Mesh axes:
    single-pod:  (data=16, model=16)            = 256 chips (one v5e pod)
    multi-pod :  (pod=2, data=16, model=16)     = 512 chips

Sharding strategy (FSDP × TP hybrid, ZeRO-style):
  * 2-D weights shard BOTH axes: the reduction/input axis over "data"
    (fully-sharded-data-parallel: optimizer state and master weights come
    down 256×) and the output/head/ff axis over "model" (tensor
    parallelism: activations stay sharded through the matmul).
  * the batch axis of activations shards over ("pod", "data"),
  * vocab shards over "model" for the embedding table and LM head,
  * MoE expert tensors shard (experts: none, d: data, ff: model) so any
    expert count (60, 64) works without padding,
  * small vectors (norms, gates, SSD decay constants) replicate.

`make_production_mesh` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def shard_devices(n_shards: int, devices=None) -> list:
    """Device -> serving-shard assignment for the sharded `ServeRuntime`
    (ISSUE 10): partition the healthy device list into `n_shards`
    per-shard device tuples.

    With >= n_shards devices, each shard gets a contiguous slice of
    len(devices) // n_shards devices (remainder devices are left idle so
    shards stay symmetric — a lopsided shard would cap the fleet's
    near-linear scaling).  With FEWER devices than shards (the CPU test
    container: one device, several shards), shards share devices
    round-robin — shard i gets device i % n_devices; oversubscription is
    explicit in the returned assignment rather than hidden.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    devs = list(devices if devices is not None else jax.devices())
    if not devs:
        raise RuntimeError("no devices available for shard assignment")
    if len(devs) >= n_shards:
        per = len(devs) // n_shards
        return [tuple(devs[i * per:(i + 1) * per]) for i in range(n_shards)]
    return [(devs[i % len(devs)],) for i in range(n_shards)]


def shard_mesh(devices) -> Mesh:
    """A 1-D ("data",) mesh over one shard's devices — the engine-group
    topology a multi-device `EngineShard` runs its SPMD PBS rounds on."""
    import numpy as _np
    return Mesh(_np.array(list(devices)), ("data",))


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------------------
# parameter sharding rules
# --------------------------------------------------------------------------

_RULES_2D = {
    # name-suffix -> (axis0, axis1)
    "embed": ("model", "data"),          # (V, d)
    "lm_head": ("data", "model"),        # (d, V)
    "frontend_proj": ("data", None),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w_in": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_out": ("model", "data"),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "gate_a": ("data", "model"),
    "gate_x": ("data", "model"),
    "router": ("data", None),
    "conv_w": (None, "model"),
}

_RULES_3D = {
    # MoE expert stacks: (E, d, ff) / (E, ff, d)
    "w_in": (None, "data", "model"),
    "w_gate": (None, "data", "model"),
    "w_out": (None, "model", "data"),
}


def _spec_for(path, leaf) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    nd = leaf.ndim
    if nd <= 1:
        return P()
    if nd == 2 and name in _RULES_2D:
        return P(*_RULES_2D[name])
    if nd == 3 and name in _RULES_3D and "moe" in names:
        return P(*_RULES_3D[name])
    # stacked-over-blocks variants: leading scan axis, shift rules right
    if nd == 3 and name in _RULES_2D:
        return P(None, *_RULES_2D[name])
    if nd == 4 and name in _RULES_3D and "moe" in names:
        return P(None, *_RULES_3D[name])
    if nd == 3 and name == "conv_w":
        return P(None, None, "model")
    if nd == 2:  # stacked 1-D (norms etc.)
        return P(None, None)
    return P(*([None] * nd))


def param_specs(params, mesh: Optional[Mesh] = None,
                mode: str = "train") -> dict:
    """Pytree of PartitionSpec matching `params` (works for stacked blocks:
    the leading scan axis is never sharded).  With `mesh`, axes whose
    dimension is not divisible by the mesh-axis size fall back to
    replicated (e.g. mamba2's in_proj out-dim 3352 on model=16).

    mode="serve" drops the FSDP ('data') axis: weights replicate across
    the data ranks and stay HBM-resident, killing the per-token
    all-gather that dominates the decode collective term (§Perf A)."""
    specs = jax.tree_util.tree_map_with_path(_spec_for, params)
    if mode == "serve":
        def unfsdp(spec):
            return P(*[None if ax == "data" else ax for ax in spec])
        specs = jax.tree.map(unfsdp, specs)
    if mesh is None:
        return specs

    def fit(leaf, spec):
        dims = []
        for i, ax in enumerate(spec):
            if ax is None:
                dims.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            dims.append(ax if leaf.shape[i] % size == 0 else None)
        return P(*dims)
    return jax.tree.map(fit, params, specs)


def param_shardings(mesh: Mesh, params, mode: str = "train"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, mode))


def cache_specs(cache, mesh: Mesh, global_batch: int) -> dict:
    """Decode-cache shardings: batch over dp axes (if divisible), kv-heads /
    channels over model where the layout allows."""
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bax = dp if global_batch % dp_size == 0 else None

    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        nd = leaf.ndim
        stacked = "blocks" in names   # leading scan axis
        off = 1 if stacked else 0
        if name == "index":
            return P(*([None] * nd))
        body = [None] * (nd - off)
        if body:
            body[0] = bax            # batch axis first in every cache leaf
        if name in ("k", "v") and nd - off == 4:
            if leaf.shape[-2] % mesh.shape["model"] == 0:
                body[2] = "model"          # kv-head sharding
            elif leaf.shape[-1] % mesh.shape["model"] == 0:
                body[3] = "model"          # GQA G < TP: shard head_dim
                                           # (§Perf A: avoids replicating
                                           # the cache TP-fold times)
        if name in ("conv", "h", "H") and nd - off >= 2:
            # channel/head axis over model when divisible
            ch = leaf.shape[-1] if name != "H" else leaf.shape[off + 1]
            pos = (nd - off - 1) if name != "H" else 1
            if ch % mesh.shape["model"] == 0:
                body[pos] = "model"
        return P(*([None] * off), *body)
    return jax.tree_util.tree_map_with_path(spec, cache)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Optional[Mesh] = None):
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train/prefill: {"tokens","labels"[, "frontend"]} of (B, S);
    decode: {"tokens": (B,1), "pos": (B,1)} + the KV/state cache comes from
    `Model.init_cache` ShapeDtypeStructs (built by the caller via eval_shape).
    """
    B, S = shape.global_batch, shape.seq_len
    dp = batch_axes(mesh) if mesh is not None else None

    def sharded(st, spec):
        if mesh is None:
            return st
        return jax.ShapeDtypeStruct(st.shape, st.dtype,
                                    sharding=NamedSharding(mesh, spec))

    bspec = dp if (mesh is not None and B % _dp_size(mesh) == 0) else None
    if shape.kind in ("train", "prefill"):
        out = {
            "tokens": sharded(jax.ShapeDtypeStruct((B, S), jnp.int32), P(bspec)),
            "labels": sharded(jax.ShapeDtypeStruct((B, S), jnp.int32), P(bspec)),
        }
        if cfg.frontend != "none":
            out["frontend"] = sharded(
                jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.frontend_dim),
                                     jnp.float32), P(bspec, None, None))
        return out
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": sharded(jax.ShapeDtypeStruct((B, 1), jnp.int32), P(bspec)),
        "pos": sharded(jax.ShapeDtypeStruct((B, 1), jnp.int32), P(bspec)),
    }


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
