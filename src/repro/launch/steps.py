"""jit-able train/serve step factories with explicit shardings.

These are the functions the multi-pod dry-run lowers and compiles, and the
ones `train.py` / `serve.py` execute.  Grad reduction over the data axes,
optimizer-state sharding, and activation layout all come from GSPMD given
the in/out shardings built from `repro.launch.mesh` rules.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch import mesh as mesh_lib
from repro.models import build
from repro.optim import AdamW

F32 = jnp.float32


def make_train_step(cfg: ArchConfig, opt: AdamW, *, loss_chunk: int = 512,
                    compress=None):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  `compress` optionally wraps gradients (int8
    gradient compression with error feedback — see repro.runtime.compress)."""
    model = build(cfg)

    def train_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, loss_chunk=loss_chunk))(params)
        if compress is not None:
            grads, opt_state = compress(grads, opt_state)
        params, opt_state, metrics = opt.update(params, opt_state, grads, step)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, loss_chunk: int = 512):
    """Forward-only scoring step (the inference-prefill shape cells)."""
    model = build(cfg)

    def prefill_step(params, batch):
        h, _ = model.forward(params, batch["tokens"], batch.get("frontend"),
                             remat=False)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        # last-position logits only (prefill hands off to decode)
        logits = h[:, -1].astype(F32) @ head.astype(F32)
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode step against a deep KV/state cache."""
    model = build(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


# --------------------------------------------------------------------------
# sharded jit wrappers (what dryrun lowers)
# --------------------------------------------------------------------------

def shaped_params(cfg: ArchConfig, mesh: Optional[Mesh] = None,
                  mode: str = "train"):
    """ShapeDtypeStructs of the param pytree (optionally with shardings)."""
    model = build(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if mesh is None:
        return shapes
    shard = mesh_lib.param_shardings(mesh, shapes, mode)
    return jax.tree.map(
        lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype, sharding=sh),
        shapes, shard)


def shaped_opt_state(cfg: ArchConfig, opt: AdamW, mesh: Optional[Mesh] = None):
    p = shaped_params(cfg, mesh)
    st = jax.eval_shape(lambda q: opt.init(q), p)
    if mesh is None:
        return st
    shard = jax.tree.map(
        lambda s: s.sharding,
        {"m": p, "v": p})
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        st, shard)


def shaped_cache(cfg: ArchConfig, shape: ShapeSpec, mesh: Optional[Mesh] = None):
    model = build(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    if mesh is None:
        return cache
    specs = mesh_lib.cache_specs(cache, mesh, shape.global_batch)
    return jax.tree.map(
        lambda st, sp: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                            sharding=NamedSharding(mesh, sp)),
        cache, specs)


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, *,
               loss_chunk: int = 512, donate: bool = True):
    """Lower the appropriate step for one (arch, shape, mesh) cell.

    Returns the `jax.stages.Lowered` object (call .compile() on it).
    """
    opt = AdamW()
    inputs = mesh_lib.input_specs(cfg, shape, mesh)
    with mesh:
        if shape.kind == "train":
            fn = make_train_step(cfg, opt, loss_chunk=loss_chunk)
            p = shaped_params(cfg, mesh)
            st = shaped_opt_state(cfg, opt, mesh)
            step = jax.ShapeDtypeStruct((), jnp.int32)
            jfn = jax.jit(fn, donate_argnums=(0, 1) if donate else ())
            return jfn.lower(p, st, inputs, step)
        if shape.kind == "prefill":
            fn = make_prefill_step(cfg, loss_chunk=loss_chunk)
            p = shaped_params(cfg, mesh, mode="serve")
            return jax.jit(fn).lower(p, inputs)
        # decode
        fn = make_serve_step(cfg)
        p = shaped_params(cfg, mesh, mode="serve")
        cache = shaped_cache(cfg, shape, mesh)
        jfn = jax.jit(fn, donate_argnums=(1,) if donate else ())
        return jfn.lower(p, cache, inputs["tokens"], inputs["pos"])
