"""Loop-aware roofline accounting over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (trip
counts are ignored) and reports per-device numbers.  Scan-over-layers and
chunked-loss scans would therefore undercount a 62-layer model by ~60x.
This module re-derives the three roofline inputs from the compiled HLO
text itself, weighting every computation by its execution count:

  * FLOPs        — 2 * prod(result_shape) * prod(contracting_dims) per
                   `dot` (x4 for complex), times the execution multiplier.
  * HBM bytes    — sum of (operands + result) bytes of every non-fused,
                   memory-touching op, times the multiplier.  Fusion
                   internals are skipped (XLA materializes only fusion
                   boundaries); fused `dot`s still contribute FLOPs.
  * collective   — result bytes of all-gather / all-reduce /
    bytes          reduce-scatter / all-to-all / collective-permute ops,
                   times the multiplier.

Execution multipliers come from ``backend_config={"known_trip_count":...}``
on `while` ops, traversed from ENTRY through while/call/conditional/fusion
edges.  All numbers are PER DEVICE (the compiled module is the per-device
SPMD program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# ops that don't touch HBM on their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_CALL_REF_ONE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)")
_CALL_REF_LIST = re.compile(r"branch_computations=\{([^}]*)\}")
# one operand: optional inline type signature (newer XLA prints operands
# typed: `dot(f32[512,1024]{1,0} %call.1, ...)`), then the %name.
_OPND_RE = re.compile(
    r"(?:([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+)?%([\w.\-]+)")


def _call_refs(text: str):
    refs = list(_CALL_REF_ONE.findall(text))
    for grp in _CALL_REF_LIST.findall(text):
        refs.extend(nm.strip().lstrip("%") for nm in grp.split(",") if nm.strip())
    return refs
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _shapes_in(sig: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, [int(d) for d in dims.split(",") if d], n))
    return out


def _sig_bytes(sig: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, _, n in _shapes_in(sig))


@dataclasses.dataclass
class Op:
    name: str
    sig: str                  # result type signature text
    opcode: str
    rest: str                 # argument + attribute text


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    defs: dict                # op name -> result sig


def parse_computations(hlo: str) -> dict:
    comps: dict = {}
    cur = None
    header_buf = ""
    comment = re.compile(r"/\*.*?\*/")
    for raw in hlo.splitlines():
        line = comment.sub("", raw).rstrip()
        if cur is None:
            if line.endswith("{"):
                header_buf += " " + line
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [], {})
                header_buf = ""
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.defs[op.name] = op.sig
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _entry_name(hlo: str, comps: dict) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: the computation no one references
    referenced = set()
    for c in comps.values():
        for op in c.ops:
            referenced.update(_call_refs(op.rest))
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _param_shapes(comp: Computation) -> dict:
    """Parameter ops carry their own sigs; already in defs."""
    return comp.defs


def _operands(op: Op, comp: Computation) -> list:
    """(sig, name) per operand; sig comes inline when the HLO prints typed
    operands (newer XLA), else from the defining op in this computation.
    Dumps that omit the '%' sigil entirely fall back to comma splitting."""
    args = op.rest.split(")")[0]
    out = []
    for sig, name in _OPND_RE.findall(args):
        out.append((sig or comp.defs.get(name, ""), name))
    if not out:
        for a in args.split(","):
            a = a.strip()
            if a:
                out.append((comp.defs.get(a, ""), a))
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    shapes = _shapes_in(op.sig)
    if not shapes:
        return 0.0
    dt, rdims, rn = shapes[0]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    opnds = _operands(op, comp)
    contract = 1
    if m and opnds:
        lsh = _shapes_in(opnds[0][0])
        if lsh:
            _, ldims, _ = lsh[0]
            for d in m.group(1).split(","):
                if d and int(d) < len(ldims):
                    contract *= ldims[int(d)]
    mult = 8 if dt in ("c64", "c128") else 2
    return float(mult * rn * contract)


def _operand_bytes(op: Op, comp: Computation) -> list:
    return [_sig_bytes(sig) for sig, _ in _operands(op, comp) if sig]


def _op_bytes(op: Op, comp: Computation, *, dus: bool = False) -> int:
    """HBM bytes touched by one op (result + operands).

    dus=True marks in-place dynamic-update-slice semantics: the big buffer
    is aliased (only the update window is read+written), so the largest
    operand and the result are NOT full traffic — approximate as twice the
    remaining operand bytes (read update + write window)."""
    if op.opcode in _FREE_OPS:
        return 0
    opnds = _operand_bytes(op, comp)
    if dus and opnds:
        big = max(opnds)
        return 2 * (sum(opnds) - big)
    return _sig_bytes(op.sig) + sum(opnds)


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # fusion-boundary traffic (pessimistic)
    hbm_bytes_major: float = 0.0  # dot/gather/scatter/DUS-bearing ops only:
                                  # the perfectly-fused-elementwise bound
                                  # (optimistic; a TPU backend lies between)
    coll_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(default_factory=dict)


def analyze(hlo: str) -> HloCosts:
    comps = parse_computations(hlo)
    entry = _entry_name(hlo, comps)
    # accumulate execution multipliers per computation
    mult = defaultdict(float)
    fused = set()

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for op in comp.ops:
            refs = _call_refs(op.rest)
            if not refs:
                continue
            child_m = m
            if op.opcode == "while":
                t = _TRIP_RE.search(op.rest)
                child_m = m * (int(t.group(1)) if t else 1)
            for r in refs:
                if op.opcode == "fusion":
                    fused.add(r)
                visit(r, child_m)

    visit(entry, 1.0)

    # computations that update buffers in place (contain a DUS)
    has_dus = {name for name, comp in comps.items()
               if any(o.opcode == "dynamic-update-slice" for o in comp.ops)}
    _MAJOR = {"dot", "convolution", "gather", "scatter", "dynamic-slice",
              "dynamic-update-slice"}
    has_major = {name for name, comp in comps.items()
                 if any(o.opcode in _MAJOR for o in comp.ops)}

    out = HloCosts()
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in fused
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                out.flops += m * _dot_flops(op, comp)
            if in_fusion:
                continue  # fusion internals don't touch HBM
            if op.opcode == "fusion" or op.opcode not in _FREE_OPS:
                refs = _call_refs(op.rest) if op.opcode == "fusion" else ()
                dus = (op.opcode == "dynamic-update-slice"
                       or any(r in has_dus for r in refs))
                b = _op_bytes(op, comp, dus=dus)
                if op.opcode in ("while", "call", "conditional"):
                    b = 0  # control ops: children already accounted
                out.hbm_bytes += m * b
                if op.opcode in _MAJOR or any(r in has_major for r in refs):
                    out.hbm_bytes_major += m * b
            if op.opcode in _COLLECTIVES:
                cb = _sig_bytes(op.sig)
                out.coll_bytes += m * cb
                out.coll_breakdown[op.opcode] = (
                    out.coll_breakdown.get(op.opcode, 0.0) + m * cb)
    return out
