"""Production launch layer: meshes, sharding rules, dry-run, drivers."""
