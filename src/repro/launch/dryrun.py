import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

The first two lines above MUST precede any jax import: jax locks the
device count at first init, and the production meshes need 512 host
placeholder devices.  Smoke tests and benches never import this module.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, loss_chunk: int = 512) -> dict:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    lowered = steps.lower_cell(cfg, shape, mesh, loss_chunk=loss_chunk)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    roof = rl.from_compiled(compiled, chips, rl.model_flops(cfg, shape))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)
                                + getattr(mem, "temp_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        **roof.to_dict(),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] "
              f"compile={rec['compile_s']}s "
              f"args/dev={rec['arg_bytes']/2**30:.2f}GiB "
              f"temp/dev={rec['temp_bytes']/2**30:.2f}GiB "
              f"Tc={roof.t_compute:.3e}s Tm={roof.t_memory:.3e}s "
              f"(maj {roof.t_memory_major:.3e}) "
              f"Tcoll={roof.t_collective:.3e}s -> {roof.bottleneck} "
              f"(mfu<= {roof.mfu_bound:.2f}..{roof.mfu_bound_major:.2f}, "
              f"useful={roof.flops_ratio:.2f})", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--loss-chunk", type=int, default=512)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in applicable_shapes(get(arch)):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        loss_chunk=args.loss_chunk))
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape,
                                 "multi_pod": mp, "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
