"""Render dryrun_results.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
from __future__ import annotations

import json
import sys


def fmt_table(rows, mesh):
    out = []
    out.append(f"\n### Mesh {mesh}\n")
    out.append("| arch | shape | Tc (s) | Tm pess (s) | Tm fused (s) | "
               "Tcoll (s) | bottleneck | mfu ≤ (pess..fused) | useful | "
               "GiB/dev | collectives |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        coll = ", ".join(f"{k.split('-')[0]}:{v / 1e9:.1f}GB"
                         for k, v in sorted(r["coll_breakdown"].items(),
                                            key=lambda kv: -kv[1])[:3])
        gib = (r["arg_bytes"] + r["temp_bytes"]) / 2 ** 30
        tmm = r.get("t_memory_major_s", 0.0)
        mfum = r.get("mfu_bound_major", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {tmm:.2e} | "
            f"{r['t_collective_s']:.2e} | "
            f"{r['bottleneck']} | {r['mfu_bound']:.3f}..{mfum:.3f} | "
            f"{r['flops_ratio']:.2f} | {gib:.1f} | {coll} |")
    return "\n".join(out)


def main(path="dryrun_results.json"):
    d = json.load(open(path))
    rows = d["results"]
    print(f"{len(rows)} cells, {len(d['failures'])} failures")
    for mesh in ("16x16", "2x16x16"):
        print(fmt_table(rows, mesh))


if __name__ == "__main__":
    main(*sys.argv[1:])
