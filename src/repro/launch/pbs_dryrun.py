import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Roofline the FHE engine itself on the production mesh (§Perf D).

Lowers the batched PBS (paper-faithful: round-robin BSK reuse == batch
dimension, keys replicated via the NoC analogue) and the XPU-style
per-ciphertext loop on the 16x16 mesh, and derives the roofline terms of
each from the compiled HLO.  This is the paper's Fig. 7 comparison as a
lowered-IR measurement:

    PYTHONPATH=src python -m repro.launch.pbs_dryrun [--params gpt2]
"""
import argparse   # noqa: E402
import json       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import batch as batch_mod  # noqa: E402
from repro.core.params import PAPER_PARAMS, TEST_PARAMS_4BIT  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

U64 = jnp.uint64


def pbs_flops(params, B):
    """Useful FLOPs of B bootstraps: n iterations x (FFT + MAC + IFFT)."""
    p = params
    M = p.N // 2
    j = (p.k + 1) * p.pbs_level
    fft = (j + (p.k + 1)) * 5 * M * (M.bit_length() - 1)   # 5 N log N
    mac = 8 * j * (p.k + 1) * M                            # complex MAC
    return float(B * p.n * (fft + mac))


def lower_variant(params, B, mesh, *, batched: bool):
    data = NamedSharding(mesh, P("data"))
    repl = NamedSharding(mesh, P())
    sd = jax.ShapeDtypeStruct
    cts = sd((B, params.big_n + 1), U64, sharding=data)
    polys = sd((B, params.N), U64, sharding=data)
    bsk = sd((params.n, params.k + 1, params.pbs_level, params.k + 1,
              params.N // 2), jnp.complex128, sharding=repl)
    ksk = sd((params.big_n, params.ks_level, params.n + 1), U64,
             sharding=repl)
    fn = batch_mod.pbs_batch if batched else batch_mod.pbs_unbatched_loop
    with mesh:
        lowered = jax.jit(fn, static_argnames=("params",)).lower(
            cts, polys, bsk, ksk, params=params)
        return lowered.compile()


def run(params_name: str, B: int = 192):
    params = (PAPER_PARAMS[params_name] if params_name in PAPER_PARAMS
              else TEST_PARAMS_4BIT)
    mesh = make_production_mesh()
    rows = []
    for batched in (True, False):
        compiled = lower_variant(params, B, mesh, batched=batched)
        roof = rl.from_compiled(compiled, mesh.size, pbs_flops(params, B))
        rows.append({
            "variant": "taurus-batched" if batched else "xpu-per-ct",
            "params": params.name, "B": B, **roof.to_dict(),
            "per_pbs_bound_ms": roof.t_bound / B * mesh.size / 4 * 1e3,
        })
        print(f"[{rows[-1]['variant']:14s}] Tc={roof.t_compute:.3e}s "
              f"Tm={roof.t_memory:.3e}s Tcoll={roof.t_collective:.3e}s "
              f"-> {roof.bottleneck} useful={roof.flops_ratio:.2f}",
              flush=True)
    if rows[0]["t_memory_s"] > 0:
        gain = rows[1]["t_memory_s"] / rows[0]["t_memory_s"]
        print(f"BSK-reuse memory-term gain (batched vs per-ct): {gain:.1f}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="cnn20")
    ap.add_argument("--batch", type=int, default=192)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(args.params, args.batch)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
