"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt [--reduced]

Wires together: model zoo -> sharding rules -> AdamW -> synthetic data ->
checkpoint/restart -> StepRunner (retry + straggler watch) -> optional
int8 gradient compression.  Works on any mesh (CPU host mesh by default).
"""
from __future__ import annotations

import argparse
import dataclasses
import importlib
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import DataConfig, SyntheticLMData
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_train_step
from repro.models import build
from repro.optim import AdamW, cosine_schedule
from repro.runtime import FaultConfig, Int8Compressor, StepRunner


def reduced_config(arch: str):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.reduced()


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 256,
          ckpt_dir: str | None = None, reduced: bool = True,
          model_parallel: int = 1, lr: float = 3e-3, log_every: int = 10,
          compress_grads: bool = False, resume: bool = True,
          fail_at_step: int | None = None):
    cfg = reduced_config(arch) if reduced else get(arch)
    model = build(cfg)
    mesh = mesh_lib.make_host_mesh(model_parallel)
    opt = AdamW(lr=cosine_schedule(lr, warmup=steps // 10, total=steps))
    data = SyntheticLMData(DataConfig(cfg.vocab_size, seq, batch))
    comp = Int8Compressor() if compress_grads else None

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        shardings = mesh_lib.param_shardings(mesh, params)
        params = jax.tree.map(jax.device_put, params, shardings)
        opt_state = opt.init(params)
        if comp is not None:
            opt_state["ef"] = comp.init(params)

        compress = None
        if comp is not None:
            def compress(grads, state):
                g, ef = comp.roundtrip(grads, state["ef"])
                return g, {**state, "ef": ef}
        raw_step = jax.jit(make_train_step(cfg, opt, loss_chunk=min(seq, 512),
                                           compress=compress),
                           donate_argnums=(0, 1))

        ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start = 0
        if ckpt is not None and resume and ckpt.latest_step() is not None:
            (params, opt_state), start = ckpt.restore((params, opt_state))
            print(f"[train] resumed from step {start}")

        state = {"params": params, "opt": opt_state}
        inject = {"step": fail_at_step}

        def one_step(step_i):
            batch_i = data.batch(step_i)
            if inject["step"] is not None and step_i == inject["step"]:
                raise RuntimeError("injected failure (fault-tolerance test)")
            p, o, metrics = raw_step(state["params"], state["opt"], batch_i,
                                     jnp.asarray(step_i, jnp.int32))
            state["params"], state["opt"] = p, o
            return p, o, metrics

        runner = StepRunner(one_step, FaultConfig())
        losses = []
        t0 = time.time()
        step_i = start
        while step_i < steps:
            try:
                out = runner.run(step_i)
            except Exception as e:
                if ckpt is None or ckpt.latest_step() is None:
                    raise
                print(f"[train] step {step_i} failed ({e}); restoring")
                (state["params"], state["opt"]), step_i = ckpt.restore(
                    (state["params"], state["opt"]))
                inject["step"] = None      # the failed node was replaced
                continue
            if out is not None:
                metrics = out[-1]
                losses.append(float(metrics["loss"]))
                if step_i % log_every == 0:
                    print(f"[train] step {step_i} loss={losses[-1]:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f}",
                          flush=True)
            if ckpt is not None and (step_i + 1) % FaultConfig().checkpoint_every == 0:
                ckpt.save(step_i + 1, (state["params"], state["opt"]))
            step_i += 1
        if ckpt is not None:
            ckpt.save(steps, (state["params"], state["opt"]))
        dt = time.time() - t0
        print(f"[train] {steps - start} steps in {dt:.1f}s "
              f"({(steps - start) / max(dt, 1e-9):.2f} it/s); "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
              f"runner stats {runner.stats}")
        return losses, runner.stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (default: reduced)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          ckpt_dir=args.ckpt_dir, reduced=not args.full,
          model_parallel=args.model_parallel, lr=args.lr,
          compress_grads=args.compress_grads)


if __name__ == "__main__":
    main()
