"""Composable LM builder for the assigned architecture pool.

`build(cfg)` returns a `Model` whose methods cover the whole lifecycle:

    init(rng)                         -> params
    forward(params, tokens, frontend) -> (B, S, d) final hidden
    loss(params, batch)               -> scalar (chunked CE, no (B,S,V))
    init_cache(batch, max_len)        -> decode cache pytree
    decode_step(params, cache, tok, pos) -> (logits, cache)

Layer stacking: layers are grouped into macro-blocks of
``period = len(cfg.layer_pattern)``; ``L // period`` macro-blocks run under
one `jax.lax.scan` with stacked params (bounds compile time and HLO size at
62-layer scale), and the ``L % period`` remainder runs unrolled.  Every
sub-layer is pre-norm residual; MoE configs replace the dense MLP.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import rglru as R
from repro.models import sharding as sh
from repro.models import ssd as S

F32 = jnp.float32
POS_SENTINEL = 1 << 30  # unwritten KV slots: fails the causal mask


def _dtype(cfg: ArchConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# --------------------------------------------------------------------------
# per-layer params / apply
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, kind: str, dtype):
    k_mix, k_mlp = jax.random.split(key)
    p: dict = {"pre_norm": jnp.ones((cfg.d_model,), dtype),
               "mlp_norm": jnp.ones((cfg.d_model,), dtype)}
    if kind in ("attn", "local"):
        p["mixer"] = L.AttnParams.init(k_mix, cfg, dtype)
    elif kind == "ssd":
        p["mixer"] = S.SsdParams.init(k_mix, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = R.RgLruParams.init(k_mix, cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.is_moe:
        p["moe"] = L.MoeParams.init(k_mlp, cfg, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = L.MlpParams.init(k_mlp, cfg, dtype)
    else:
        del p["mlp_norm"]       # mixer-only layer (e.g. mamba2)
    return p


def _apply_layer(p, x, pos, cfg: ArchConfig, kind: str, cache=None):
    """One (mixer + MLP) residual pair.  Returns (x, aux, new_cache)."""
    h = L.rms_norm(x, p["pre_norm"], cfg.norm_eps, plus_one=cfg.embed_scale)
    if kind in ("attn", "local"):
        window = cfg.local_window if kind == "local" else 0
        mix, new_cache = L.attention_block(p["mixer"], h, pos, cfg,
                                           cache=cache, window=window)
    elif kind == "ssd":
        mix, new_cache = S.ssd_block(p["mixer"], h, cfg, cache=cache)
    else:  # rglru
        mix, new_cache = R.rglru_block(p["mixer"], h, cfg, cache=cache)
    x = x + mix
    aux = jnp.zeros((), F32)
    if cfg.is_moe or cfg.d_ff > 0:
        h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps, plus_one=cfg.embed_scale)
        if cfg.is_moe:
            y, aux = L.moe_block(p["moe"], h, cfg)
        else:
            y = L.mlp_block(p["mlp"], h, cfg)
        x = x + y
    return x, aux, new_cache


def _init_cache_layer(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("attn", "local"):
        size = min(max_len, cfg.local_window) if kind == "local" else max_len
        G, hd = cfg.num_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros((batch, size, G, hd), dtype),
            "v": jnp.zeros((batch, size, G, hd), dtype),
            "pos": jnp.full((batch, size), POS_SENTINEL, jnp.int32),
            "index": jnp.zeros((), jnp.int32),
        }
    if kind == "ssd":
        return S.ssd_init_cache(cfg, batch, dtype)
    return R.rglru_init_cache(cfg, batch, dtype)


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---- init -------------------------------------------------------------
    def init(self, rng: jax.Array):
        cfg = self.cfg
        dtype = _dtype(cfg)
        period = len(cfg.layer_pattern)
        n_scan = cfg.num_layers // period
        n_tail = cfg.num_layers % period
        k_emb, k_blocks, k_tail, k_head, k_fe = jax.random.split(rng, 5)

        params: dict = {
            "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), F32)
                      * 0.02).astype(dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(
                k_head, (cfg.d_model, cfg.vocab_size), F32)
                / jnp.sqrt(cfg.d_model)).astype(dtype)
        if cfg.frontend != "none":
            params["frontend_proj"] = L.dense_init(
                k_fe, (cfg.frontend_dim, cfg.d_model), dtype)

        def init_block(key):
            ks = jax.random.split(key, period)
            return {f"l{i}": _init_layer(ks[i], cfg, cfg.layer_pattern[i], dtype)
                    for i in range(period)}

        params["blocks"] = jax.vmap(init_block)(jax.random.split(k_blocks, n_scan))
        if n_tail:
            ks = jax.random.split(k_tail, n_tail)
            params["tail"] = [
                _init_layer(ks[i], cfg, cfg.layer_pattern[i % period], dtype)
                for i in range(n_tail)]
        return params

    # ---- embedding / unembedding -------------------------------------------
    def _embed(self, params, tokens, frontend=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        x = sh.constrain(x, "batch", None, None)
        if cfg.embed_scale:
            x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
        if frontend is not None and cfg.frontend != "none":
            fe = frontend @ params["frontend_proj"]
            x = jax.lax.dynamic_update_slice(x, fe.astype(x.dtype), (0, 0, 0))
        return x

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # ---- forward -----------------------------------------------------------
    def forward(self, params, tokens, frontend=None, *, remat: bool = True,
                remat_policy: str = "full"):
        """tokens (B, S) -> final hidden (B, S, d), plus MoE aux loss.

        remat_policy: "full" recomputes everything in bwd (min memory);
        "dots" saves matmul outputs so the backward pass skips re-running
        projections and their collectives.  §Perf C measured: "dots" cut
        Tc -16% / Tcoll -12% but grew the DOMINANT memory term +35%
        (79 GiB temp) — hypothesis refuted for the memory-bound regime,
        so "full" stays the default; "dots" remains available for
        compute-bound deployments.
        """
        cfg = self.cfg
        B, Sq = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
        x = self._embed(params, tokens, frontend)
        period = len(cfg.layer_pattern)

        def block_fn(x, bp):
            aux = jnp.zeros((), F32)
            for i in range(period):
                x, a, _ = _apply_layer(bp[f"l{i}"], x, pos, cfg,
                                       cfg.layer_pattern[i])
                aux = aux + a
            return x, aux
        if remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if remat_policy == "dots" else None)
            block_fn = jax.checkpoint(block_fn, policy=policy)

        def scan_step(x, bp):
            return block_fn(x, bp)
        x, auxs = jax.lax.scan(scan_step, x, params["blocks"])
        aux = jnp.sum(auxs)
        for i, lp in enumerate(params.get("tail", [])):
            x, a, _ = _apply_layer(lp, x, pos, cfg,
                                   cfg.layer_pattern[i % period])
            aux = aux + a
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps,
                       plus_one=cfg.embed_scale)
        return x, aux

    # ---- loss (chunked CE over the vocab-sharded head) ----------------------
    def loss(self, params, batch, *, loss_chunk: int = 512,
             aux_weight: float = 0.01):
        """batch: {"tokens": (B,S) int32, "labels": (B,S) int32,
        optional "frontend"}.  Never materializes (B, S, V)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        h, aux = self.forward(params, tokens, batch.get("frontend"))
        head = self._head(params)
        B, Sq, d = h.shape
        C = min(loss_chunk, Sq)
        nc = Sq // C
        assert Sq % nc == 0
        hc = h.reshape(B, nc, C, d).swapaxes(0, 1)          # (nc, B, C, d)
        lc = labels.reshape(B, nc, C).swapaxes(0, 1)

        @jax.checkpoint  # recompute chunk logits in bwd: never store (B,C,V)
        def chunk_loss(args):
            hx, lx = args
            # bf16 x bf16 -> f32 accumulation: no f32 copy of the head
            # table ever materializes (§Perf C)
            logits = jnp.matmul(hx, head, preferred_element_type=F32)
            logits = sh.constrain(logits, "batch", None, "model")
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
            return jnp.sum(logz - gold)
        total = jnp.sum(jax.lax.map(chunk_loss, (hc, lc)))
        return total / (B * Sq) + aux_weight * aux

    # ---- decode -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dtype = _dtype(cfg)
        period = len(cfg.layer_pattern)
        n_scan = cfg.num_layers // period
        n_tail = cfg.num_layers % period

        def one_block(_):
            return {f"l{i}": _init_cache_layer(cfg, cfg.layer_pattern[i],
                                               batch, max_len, dtype)
                    for i in range(period)}
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), one_block(0))
        cache = {"blocks": stacked}
        if n_tail:
            cache["tail"] = [
                _init_cache_layer(cfg, cfg.layer_pattern[i % period],
                                  batch, max_len, dtype)
                for i in range(n_tail)]
        return cache

    def decode_step(self, params, cache, tokens, pos):
        """One decode step.  tokens (B, 1) int32; pos (B, 1) int32 absolute.

        Returns (logits (B, V) f32, new_cache).
        """
        cfg = self.cfg
        x = self._embed(params, tokens)
        period = len(cfg.layer_pattern)

        def scan_step(x, inp):
            bp, bc = inp
            new_c = {}
            for i in range(period):
                x, _, nc = _apply_layer(bp[f"l{i}"], x, pos, cfg,
                                        cfg.layer_pattern[i],
                                        cache=bc[f"l{i}"])
                new_c[f"l{i}"] = nc
            return x, new_c
        x, new_blocks = jax.lax.scan(scan_step, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}
        if "tail" in cache:
            new_cache["tail"] = []
            for i, (lp, lc) in enumerate(zip(params["tail"], cache["tail"])):
                x, _, nc = _apply_layer(lp, x, pos, cfg,
                                        cfg.layer_pattern[i % period], cache=lc)
                new_cache["tail"].append(nc)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps,
                       plus_one=cfg.embed_scale)
        logits = (x[:, -1].astype(F32) @ self._head(params).astype(F32))
        return logits, new_cache


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)
