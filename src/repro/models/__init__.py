"""Plaintext LM model zoo (assigned architectures) — pure JAX, dtype-explicit.

This package never imports `repro.core` (which enables x64); it is the
substrate the multi-pod dry-run and roofline deliverables exercise, and
the source of quantized blocks for `repro.fhe_ml`.
"""
from repro.models.model import Model, build  # noqa: F401
