"""Mamba-2 SSD (state-space duality) mixer — chunked scan, pure JAX.

Implements the SSD parameterization of arXiv:2405.21060: per-head scalar
decay a_t = exp(-softplus(dt) * A), matrix state H in R^{P x S} updated as

    H_t = a_t * H_{t-1} + dt_t * x_t b_t^T
    y_t = H_t c_t + D * x_t

Training/prefill uses the chunked (block) form: intra-chunk attention-like
term + inter-chunk recurrence over chunk states, O(T * chunk) memory.
Decode is the plain one-step recurrence over a carried state.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import sharding as sh

F32 = jnp.float32


@dataclasses.dataclass
class SsdParams:
    @staticmethod
    def init(key, cfg: ArchConfig, dtype):
        d = cfg.d_model
        di = cfg.ssm_expand * d          # inner width
        nh = di // cfg.ssm_head_dim      # heads
        S = cfg.ssm_state_dim
        ks = jax.random.split(key, 4)
        proj_out = 2 * di + 2 * S + nh   # [z, x, B, C, dt]
        std = 1.0 / math.sqrt(d)
        p = {
            "in_proj": (jax.random.normal(ks[0], (d, proj_out), F32) * std).astype(dtype),
            "out_proj": (jax.random.normal(ks[1], (di, d), F32) / math.sqrt(di)).astype(dtype),
            # conv over [x, B, C] features, width 4 (mamba2 default)
            "conv_w": (jax.random.normal(ks[2], (4, di + 2 * S), F32) * 0.2).astype(dtype),
            "conv_b": jnp.zeros((di + 2 * S,), dtype),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(F32),
            "D": jnp.ones((nh,), F32),
            "dt_bias": jnp.full((nh,), math.log(math.e - 1), F32),  # softplus^-1(1)
            "norm": jnp.ones((di,), dtype),
        }
        return p


def _split(pre, di, S, nh):
    z = pre[..., :di]
    xBC = pre[..., di:di + di + 2 * S]
    dt = pre[..., -nh:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv width K over (B, T, C); state (B, K-1, C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(xBC.shape[:-2] + (K - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xBC], axis=-2)             # (B, T+K-1, C)
    out = sum(xp[..., i:i + xBC.shape[-2], :] * w[i] for i in range(K)) + b
    new_state = xp[..., -(K - 1):, :]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a_log, B, C, D, chunk: int):
    """Chunked SSD scan.

    x: (Bt, T, nh, P)   dt: (Bt, T, nh)  softplus-ed already
    B, C: (Bt, T, S)    (single group, broadcast over heads)
    Returns y: (Bt, T, nh, P).
    """
    Bt, T, nh, P = x.shape
    S = B.shape[-1]
    nc = T // chunk
    assert T % chunk == 0
    A = -jnp.exp(a_log)                                    # (nh,) negative
    dA = dt * A                                            # (Bt, T, nh) log-decay
    xr = x.reshape(Bt, nc, chunk, nh, P)
    dtr = dt.reshape(Bt, nc, chunk, nh)
    dAr = dA.reshape(Bt, nc, chunk, nh)
    Br = B.reshape(Bt, nc, chunk, S)
    Cr = C.reshape(Bt, nc, chunk, S)

    # cumulative log-decay within each chunk (inclusive)
    seg = jnp.cumsum(dAr, axis=2)                          # (Bt, nc, chunk, nh)

    # 1) intra-chunk (dual "attention" form):
    #    y_t += sum_{s<=t} exp(seg_t - seg_s) * dt_s * (c_t . b_s) x_s
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]    # (Bt,nc,t,s,nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask the EXPONENT, not the product: exp of masked (s>t) entries would
    # overflow (rel > 0 there) and poison the backward pass with inf*0=NaN.
    rel = jnp.where(tri[None, None, :, :, None], rel, -jnp.inf)
    decay = jnp.exp(rel)
    scores = jnp.einsum("bnti,bnui->bntu", Cr, Br)         # (Bt,nc,t,u)
    w = scores[..., None] * decay * dtr[:, :, None, :, :]  # (Bt,nc,t,u,nh)
    y_intra = jnp.einsum("bntuh,bnuhp->bnthp", w, xr)

    # 2) chunk states: G_n = sum_s exp(seg_last - seg_s) dt_s b_s x_s^T
    last = seg[:, :, -1:, :]                               # (Bt,nc,1,nh)
    w_in = jnp.exp(last - seg) * dtr                       # (Bt,nc,chunk,nh)
    G = jnp.einsum("bnsh,bnsi,bnshp->bnhip", w_in, Br, xr)  # (Bt,nc,nh,S,P)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(last[:, :, 0, :])                # (Bt,nc,nh)

    def step(H, inp):
        G_n, dec_n = inp                                   # (Bt,nh,S,P), (Bt,nh)
        H_new = H * dec_n[..., None, None] + G_n
        return H_new, H                                    # emit PREVIOUS state
    H0 = jnp.zeros((Bt, nh, S, P), x.dtype)
    H_last, H_prev = jax.lax.scan(
        step, H0, (jnp.moveaxis(G, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    H_prev = jnp.moveaxis(H_prev, 0, 1)                    # (Bt,nc,nh,S,P)

    # 4) inter-chunk contribution: y_t += exp(seg_t) * c_t . H_prev
    y_inter = jnp.einsum("bnth,bnti,bnhip->bnthp",
                         jnp.exp(seg), Cr, H_prev)
    y = (y_intra + y_inter).reshape(Bt, T, nh, P)
    y = y + D[None, None, :, None] * x
    return y, H_last


def ssd_block(p, x, cfg: ArchConfig, *, cache=None):
    """Mamba-2 mixer sub-layer. x: (B, T, d).

    cache: None (train/prefill) or {"conv": (B,3,C), "H": (B,nh,S,P)} for
    decode (T small, typically 1); returns (out, new_cache).
    """
    Bt, T, d = x.shape
    di = cfg.ssm_expand * d
    S = cfg.ssm_state_dim
    P = cfg.ssm_head_dim
    nh = di // P
    pre = sh.constrain(x @ p["in_proj"], "batch", None, "model")
    z, xBC, dt = _split(pre, di, S, nh)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])

    conv_state = None if cache is None else cache["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs = xBC[..., :di].reshape(Bt, T, nh, P)
    B = xBC[..., di:di + S].astype(F32)
    C = xBC[..., di + S:].astype(F32)

    if cache is None:
        y, H = ssd_chunked(xs.astype(F32), dt, p["A_log"], B, C, p["D"],
                           min(cfg.ssm_chunk, T))
        new_cache = None
    else:
        # one-step recurrence (decode): T steps sequential (T==1 typical)
        A = -jnp.exp(p["A_log"])

        def step(H, inp):
            x_t, dt_t, b_t, c_t = inp
            dec = jnp.exp(dt_t * A)                        # (Bt,nh)
            H = H * dec[..., None, None] + jnp.einsum(
                "bh,bi,bhp->bhip", dt_t, b_t, x_t)
            y_t = jnp.einsum("bi,bhip->bhp", c_t, H)
            return H, y_t
        H, ys = jax.lax.scan(
            step, cache["H"],
            (jnp.moveaxis(xs.astype(F32), 1, 0), jnp.moveaxis(dt, 1, 0),
             jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1) + p["D"][None, None, :, None] * xs.astype(F32)
        new_cache = {"conv": new_conv, "H": H}

    y = y.reshape(Bt, T, di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype) * p["norm"]
    out = y @ p["out_proj"]
    return out, new_cache


def ssd_init_cache(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    S = cfg.ssm_state_dim
    P = cfg.ssm_head_dim
    nh = di // P
    return {
        "conv": jnp.zeros((batch, 3, di + 2 * S), dtype),
        "H": jnp.zeros((batch, nh, S, P), F32),
    }
