"""GPipe-style pipeline parallelism over the `pod` mesh axis.

For multi-pod deployments where cross-pod ICI is the scarce resource,
pipelining sends only (B_micro, S, d) activations across the pod link
once per microbatch instead of all-reducing every gradient across pods.

Implementation: `shard_map` over the `pod` axis; each pod holds
`num_layers / n_stages` layers (the stage axis is the leading axis of a
stacked block pytree).  The classic GPipe schedule runs
`n_micro + n_stages - 1` ticks; activations hop stages via
`jax.lax.ppermute`.  Losses are computed on the last stage and summed.

This is an OPTIONAL execution mode (train_step_pipelined); the default
data/tensor-parallel path in `repro.launch.steps` remains primary.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

F32 = jnp.float32


def pipeline_apply(stage_fn: Callable, params_stacked, x_micro, *,
                   n_stages: int, axis_name: str = "pod"):
    """Run microbatches through pipeline stages laid over `axis_name`.

    stage_fn(stage_params, x) -> x           (one stage's layers)
    params_stacked: pytree with leading stage axis, sharded over pod.
    x_micro: (n_micro, B_micro, S, d) — all microbatches, replicated.
    n_stages: static size of the pod axis (the schedule length and the
    ppermute ring need it at trace time).

    Returns (n_micro, B_micro, S, d) outputs as produced by the LAST
    stage (other stages contribute zeros; caller psums or selects).
    """
    stage = jax.lax.axis_index(axis_name)
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    # each pod's slice of the stacked params has a singleton stage axis
    my_params = jax.tree.map(lambda a: a[0], params_stacked)

    def tick(carry, t):
        inflight, outputs = carry
        # stage 0 ingests microbatch t (when valid); others take the
        # activation forwarded from the previous stage
        feed = jnp.where(t < n_micro, t, 0)
        x_in = jnp.where(stage == 0, x_micro[feed], inflight)
        y = stage_fn(my_params, x_in)
        # forward to the next stage (ring permute; last->first unused)
        fwd = jax.lax.ppermute(
            y, axis_name,
            perm=[(i, (i + 1) % n_stages) for i in range(n_stages)])
        # the LAST stage emits microbatch (t - n_stages + 1)
        out_idx = t - (n_stages - 1)
        is_out = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
        outputs = jax.lax.cond(
            is_out,
            lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
            lambda o: o, outputs)
        return (fwd, outputs), None

    out0 = jnp.zeros_like(x_micro)
    (_, outputs), _ = jax.lax.scan(
        tick, (jnp.zeros_like(x_micro[0]), out0), jnp.arange(ticks))
    # broadcast last stage's outputs to every pod member
    return jax.lax.psum(
        jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
        axis_name)


def make_pipelined_fwd(stage_fn: Callable, mesh: Mesh, *, n_micro: int,
                       axis_name: str = "pod"):
    """Wrap pipeline_apply in shard_map over the pod axis.

    params_stacked leaves must have leading dim == pod size.
    x: (B, S, d) global; split into n_micro microbatches internally.
    """
    def fwd(params_stacked, x):
        B = x.shape[0]
        assert B % n_micro == 0
        xm = x.reshape(n_micro, B // n_micro, *x.shape[1:])

        inner = functools.partial(pipeline_apply, stage_fn,
                                  n_stages=mesh.shape[axis_name],
                                  axis_name=axis_name)
        specs_p = jax.tree.map(lambda _: P(axis_name), params_stacked)
        y = shard_map(
            inner, mesh=mesh,
            in_specs=(specs_p, P()),
            out_specs=P(),
            check_rep=False,
        )(params_stacked, xm)
        return y.reshape(B, *x.shape[1:])
    return fwd
