"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrent sub-layer is:  x -> [linear branch (gate), recurrent branch]
  recurrent branch: temporal conv1d(width 4) -> RG-LRU -> out
  RG-LRU:  r_t = sigmoid(W_a x_t + b_a)       (recurrence gate)
           i_t = sigmoid(W_x x_t + b_x)       (input gate)
           a_t = a^(c * r_t),  a = sigmoid(Lambda)  (per-channel, c=8)
           h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan over (a, b) pairs; decode is the
one-step recurrence.  Parallelism: the channel axis shards over 'model'.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import sharding as sh

F32 = jnp.float32
_C = 8.0  # Griffin's recurrence-gate exponent constant


@dataclasses.dataclass
class RgLruParams:
    @staticmethod
    def init(key, cfg: ArchConfig, dtype):
        d = cfg.d_model
        di = cfg.rglru_width or d
        ks = jax.random.split(key, 6)
        std = 1.0 / math.sqrt(d)
        # Lambda init so a in [0.9, 0.999] (Griffin appendix)
        u = jax.random.uniform(ks[4], (di,), F32, 0.9 ** 2, 0.999 ** 2)
        lam = jnp.log(jnp.sqrt(u) / (1 - jnp.sqrt(u)))
        return {
            "w_in": (jax.random.normal(ks[0], (d, di), F32) * std).astype(dtype),
            "w_gate": (jax.random.normal(ks[1], (d, di), F32) * std).astype(dtype),
            "w_out": (jax.random.normal(ks[2], (di, d), F32) / math.sqrt(di)).astype(dtype),
            "conv_w": (jax.random.normal(ks[3], (4, di), F32) * 0.2).astype(dtype),
            "conv_b": jnp.zeros((di,), dtype),
            "gate_a": (jax.random.normal(ks[5], (di, di), F32) * (1 / math.sqrt(di))).astype(dtype),
            "gate_x": (jax.random.normal(jax.random.fold_in(ks[5], 1), (di, di), F32)
                       * (1 / math.sqrt(di))).astype(dtype),
            "b_a": jnp.zeros((di,), F32),
            "b_x": jnp.zeros((di,), F32),
            "Lambda": lam,
        }


def _conv1d(x, w, b, state=None):
    K = w.shape[0]
    pad = (jnp.zeros(x.shape[:-2] + (K - 1, x.shape[-1]), x.dtype)
           if state is None else state)
    xp = jnp.concatenate([pad, x], axis=-2)
    out = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(K)) + b
    return out, xp[..., -(K - 1):, :]


def rglru_scan(x, a_log, gate_r, gate_i, h0=None):
    """x: (B, T, D) f32; a_log = c*r_t*log(a) (B,T,D) negative log-decay.

    Associative scan over h_t = a_t h_{t-1} + b_t.
    """
    a = jnp.exp(a_log)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * a_log), 1e-12)) * (gate_i * x)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh


def rglru_block(p, x, cfg: ArchConfig, *, cache=None):
    """Griffin recurrent sub-layer. x: (B, T, d) -> (out, new_cache)."""
    B, T, d = x.shape
    gate = jax.nn.gelu(sh.constrain(x @ p["w_gate"], "batch", None, "model"))
    u = sh.constrain(x @ p["w_in"], "batch", None, "model")
    conv_state = None if cache is None else cache["conv"]
    u, new_conv = _conv1d(u, p["conv_w"], p["conv_b"], conv_state)

    uf = u.astype(F32)
    r = jax.nn.sigmoid(uf @ p["gate_a"].astype(F32) + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["gate_x"].astype(F32) + p["b_x"])
    log_a = -_C * r * jax.nn.softplus(p["Lambda"])         # (B,T,D) <= 0

    if cache is None:
        h = rglru_scan(uf, log_a, r, i)
        new_cache = None
    else:
        def step(hprev, inp):
            u_t, la_t, i_t = inp
            a_t = jnp.exp(la_t)
            h_t = a_t * hprev + jnp.sqrt(jnp.maximum(1 - a_t ** 2, 1e-12)) * (i_t * u_t)
            return h_t, h_t
        hT, hs = jax.lax.scan(
            step, cache["h"],
            (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(log_a, 1, 0),
             jnp.moveaxis(i, 1, 0)))
        h = jnp.moveaxis(hs, 0, 1)
        new_cache = {"conv": new_conv, "h": hT}

    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, new_cache


def rglru_init_cache(cfg: ArchConfig, batch: int, dtype):
    di = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, di), dtype),
        "h": jnp.zeros((batch, di), F32),
    }
