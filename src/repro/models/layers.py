"""Shared transformer layers: norms, RoPE, streaming attention, MLP, MoE."""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import sharding as sh

F32 = jnp.float32


def rms_norm(x: jax.Array, scale: jax.Array, eps: float,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm: f32 statistics, bf16 elementwise (§Perf C iter 3).

    Only the variance reduction runs in f32; the normalize/scale
    multiplies stay in the residual dtype, halving the per-layer
    elementwise HBM streams the backward pass drags around."""
    dt = x.dtype
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    w = scale.astype(F32)
    if plus_one:
        w = w + 1.0
    return x * inv * w.astype(dt)


def rope(q: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding on (..., S, H, hd); pos (..., S) int32."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=F32) / half))
    ang = pos.astype(F32)[..., None] * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    q1, q2 = q[..., :half].astype(F32), q[..., half:].astype(F32)
    out = jnp.concatenate([q1 * cos - q2 * sin, q2 * cos + q1 * sin], axis=-1)
    return out.astype(q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    kv_chunk: int = 1024, softmax_scale: Optional[float] = None
                    ) -> jax.Array:
    """Streaming (flash-style) attention with GQA and optional local window.

    q: (B, Sq, H, hd); k/v: (B, Skv, G, hd), H % G == 0.
    q_pos: (B, Sq) absolute positions; kv_pos: (B, Skv).
    Scans kv chunks with running max/denominator — O(Sq * chunk) memory.

    Tensor parallelism: KV are repeated to H heads BEFORE the score einsum
    so the head axis shards cleanly over the 'model' mesh axis even when
    G < mesh_model (the (G, rep) split would otherwise force GSPMD to
    replicate all heads — a ~TP× flops blowup).  The KV cache still stores
    only G heads; the repeat happens on the fly per chunk.
    """
    B, Sq, H, hd = q.shape
    _, Skv, G, _ = k.shape
    rep = H // G
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)

    if Sq == 1:
        # DECODE fast path (§Perf A): one-shot grouped attention, no KV
        # repeat, no chunk scan.  With the cache head_dim-sharded over
        # 'model' (GQA G < TP), the score einsum contracts the sharded hd
        # axis — GSPMD inserts ONE small (B,G,rep,S) all-reduce per layer
        # instead of all-gathering the whole KV cache chunk by chunk.
        qg = (q.astype(F32) * scale).astype(k.dtype).reshape(B, 1, G, rep, hd)
        mesh = sh.current_mesh()
        if (mesh is not None and "model" in mesh.axis_names and rep > 1
                and G % mesh.shape["model"] != 0
                and hd % mesh.shape["model"] == 0):
            # cache is head_dim-sharded (launch.mesh.cache_specs): shard q
            # the same way so GSPMD contracts locally and all-reduces the
            # small score tensor instead of all-gathering the KV cache.
            qg = sh.constrain(qg, "batch", None, None, None, "model")
        s = jnp.einsum("bqgrh,bsgh->bgrqs", qg, k,
                       preferred_element_type=F32)
        s = sh.constrain(s, "batch", None, None, None, None)
        mask = q_pos[:, :, None] >= kv_pos[:, None, :]      # (B,1,S)
        if window:
            mask &= q_pos[:, :, None] - kv_pos[:, None, :] < window
        s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
        p = jnp.where(mask[:, None, None, :, :], p, 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bgrqs,bsgh->bqgrh", (p / jnp.maximum(l, 1e-30)
                                             ).astype(k.dtype), v,
                       preferred_element_type=F32)
        return o.reshape(B, 1, H, hd).astype(q.dtype)

    nk = max(1, Skv // kv_chunk)
    ck = Skv // nk
    assert Skv % nk == 0
    kc = k.reshape(B, nk, ck, G, hd)
    vc = v.reshape(B, nk, ck, G, hd)
    pc = kv_pos.reshape(B, nk, ck)

    # q scaled in f32 then cast to the KV dtype: einsums run in bf16 with
    # f32 accumulation (§Perf C — halves attention HBM traffic vs f32).
    qf = (q.astype(F32) * scale).astype(k.dtype)
    qf = sh.constrain(qf, "batch", None, "model", None)

    @jax.checkpoint  # §Perf C: recompute chunk scores/probs in bwd — the
    # (nk, B, H, Sq, ck) f32 probability stacks never materialize
    def step(carry, inp):
        m, l, o = carry
        kj, vj, pj = inp                                   # (B,ck,G,hd), ·, (B,ck)
        if rep > 1:
            kj = jnp.repeat(kj, rep, axis=2)               # (B,ck,H,hd)
            vj = jnp.repeat(vj, rep, axis=2)
        kj = sh.constrain(kj, "batch", None, "model", None)
        vj = sh.constrain(vj, "batch", None, "model", None)
        s = jnp.einsum("bshd,bchd->bhsc", qf, kj,
                       preferred_element_type=F32)
        mask = jnp.ones((B, Sq, ck), dtype=bool)
        if causal:
            mask &= q_pos[:, :, None] >= pj[:, None, :]
        if window:
            mask &= q_pos[:, :, None] - pj[:, None, :] < window
        s = jnp.where(mask[:, None, :, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard rows with no valid key yet
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, :, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        o = o * corr[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p.astype(k.dtype), vj,
            preferred_element_type=F32)
        return (m_new, l, o), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, dtype=F32)
    l0 = jnp.zeros((B, H, Sq), dtype=F32)
    o0 = jnp.zeros((B, H, Sq, hd), dtype=F32)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)),
    )
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.moveaxis(o, 1, 2)                              # (B,Sq,H,hd)
    return o.astype(q.dtype)


# --- parameter helpers --------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, F32) * std).astype(dtype)


@dataclasses.dataclass
class AttnParams:
    @staticmethod
    def init(key, cfg: ArchConfig, dtype):
        d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        ks = jax.random.split(key, 6)
        p = {
            "wq": dense_init(ks[0], (d, H * hd), dtype),
            "wk": dense_init(ks[1], (d, G * hd), dtype),
            "wv": dense_init(ks[2], (d, G * hd), dtype),
            "wo": dense_init(ks[3], (H * hd, d), dtype),
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((hd,), dtype)
            p["k_norm"] = jnp.ones((hd,), dtype)
        return p


def attention_block(p, x, pos, cfg: ArchConfig, *, cache=None, window=0):
    """Self-attention sub-layer.

    cache: None (train/prefill, causal over own seq) or dict with
    {"k","v": (B, S_cache, G, hd), "pos": (B, S_cache), "index": scalar} —
    decode: x is (B, 1, d), cache is updated functionally and returned.
    """
    B, Sq, d = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    k = (x @ p["wk"]).reshape(B, Sq, G, hd)
    v = (x @ p["wv"]).reshape(B, Sq, G, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    if cache is None:
        o = flash_attention(q, k, v, pos, pos, causal=True, window=window)
        new_cache = None
    else:
        idx = cache["index"]
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        S_cache = ck.shape[1]
        slot = (idx % S_cache if window else idx)  # ring buffer for local
        slot = slot.astype(jnp.int32)
        zero = jnp.zeros((), jnp.int32)            # uniform index dtype:
        # x64 mode (enabled process-wide by repro.core) would otherwise mix
        # int64 literals with the int32 cache index
        ck = jax.lax.dynamic_update_slice(ck, k, (zero, slot, zero, zero))
        cv = jax.lax.dynamic_update_slice(cv, v, (zero, slot, zero, zero))
        cpos = jax.lax.dynamic_update_slice(cpos, pos, (zero, slot))
        # mask out unwritten slots via pos sentinel handled by caller init=-1
        o = flash_attention(q, ck, cv, pos, cpos, causal=True, window=window)
        new_cache = {"k": ck, "v": cv, "pos": cpos, "index": idx + Sq}
    out = o.reshape(B, Sq, H * hd) @ p["wo"]
    return out, new_cache


@dataclasses.dataclass
class MlpParams:
    @staticmethod
    def init(key, cfg: ArchConfig, dtype, d_ff=None):
        d = cfg.d_model
        ff = d_ff or cfg.d_ff
        ks = jax.random.split(key, 3)
        p = {
            "w_in": dense_init(ks[0], (d, ff), dtype),
            "w_out": dense_init(ks[1], (ff, d), dtype),
        }
        if cfg.gated_mlp:
            p["w_gate"] = dense_init(ks[2], (d, ff), dtype)
        return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


def mlp_block(p, x, cfg: ArchConfig):
    h = sh.constrain(x @ p["w_in"], "batch", None, "model")
    if cfg.gated_mlp:
        h = _act(cfg.act)(sh.constrain(x @ p["w_gate"], "batch", None, "model")) * h
    else:
        h = _act(cfg.act)(h)
    return sh.constrain(h @ p["w_out"], "batch", None, None)


# --- Mixture of Experts -------------------------------------------------------

@dataclasses.dataclass
class MoeParams:
    @staticmethod
    def init(key, cfg: ArchConfig, dtype):
        d, E, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
        ks = jax.random.split(key, 5)
        p = {
            "router": dense_init(ks[0], (d, E), F32),  # router kept in f32
            "w_in": dense_init(ks[1], (E, d, ff), dtype, in_axis=1),
            "w_gate": dense_init(ks[2], (E, d, ff), dtype, in_axis=1),
            "w_out": dense_init(ks[3], (E, ff, d), dtype, in_axis=1),
        }
        if cfg.moe_num_shared:
            sh_ff = ff * cfg.moe_num_shared
            kss = jax.random.split(ks[4], 3)
            p["shared"] = {
                "w_in": dense_init(kss[0], (d, sh_ff), dtype),
                "w_gate": dense_init(kss[1], (d, sh_ff), dtype),
                "w_out": dense_init(kss[2], (sh_ff, d), dtype),
            }
        return p


def moe_block(p, x, cfg: ArchConfig, *, capacity_factor: float = 0.0,
              group_size: int = 2048):
    """Top-k routed experts + always-on shared experts, GShard-style
    GROUPED capacity dispatch.

    The classic (T, E, C) one-hot dispatch is quadratic in the token
    count (C ~ T*K/E): at 1M tokens the dispatch tensor alone would be
    terabytes.  Grouping tokens into independent dispatch groups of G
    tokens (GShard/Switch on TPU) bounds every intermediate to
    (n_groups, G, E, Cg) with Cg ~ G*K/E, and the group axis shards
    over ('pod','data') with zero cross-group communication before the
    expert all-to-all that GSPMD inserts around the expert einsum.

    x: (B, S, d).  Returns (out, aux_loss).
    """
    B, S, d = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    T = B * S
    G = min(group_size, T)
    nG = T // G
    assert T % G == 0, (T, G)
    xt = x.reshape(nG, G, d)
    xt = sh.constrain(xt, "batch", None, None)

    logits = (xt.astype(F32) @ p["router"])                 # (nG, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                # (nG, G, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    C = int(math.ceil(G * K / E * capacity_factor))
    C = min(max(C, 4), G)
    onehot = jax.nn.one_hot(idx, E, dtype=F32)              # (nG, G, K, E)
    # position of each (token, k) within its expert queue (group-local)
    flat = onehot.reshape(nG, G * K, E)
    ranks = (jnp.cumsum(flat, axis=1) - flat).reshape(nG, G, K, E)
    keep = (ranks < C) * onehot
    pos = jnp.einsum("gtke,gtke->gtk", ranks, onehot).astype(jnp.int32)

    if cfg.moe_dispatch == "gather":
        # §Perf B: scatter/gather dispatch — zero matmul FLOPs, O(T*K*d)
        # bytes.  The einsum path moves T*E*C*d MACs PER EINSUM, which at
        # 64 experts rivals the expert FFN compute itself (useful-flops
        # ratio 0.09 on moonshot); segment_sum/take replace it entirely.
        kept = jnp.einsum("gtke->gtk", keep) > 0            # (nG, G, K)
        slot = (idx * C + pos).astype(jnp.int32)            # (nG, G, K)
        slot = jnp.where(kept, slot, E * C)                 # drop bucket

        def disp_group(sl, xg):                             # (G,K), (G,d)
            upd = jnp.repeat(xg, K, axis=0)                 # (G*K, d)
            return jax.ops.segment_sum(upd, sl.reshape(-1),
                                       num_segments=E * C + 1)
        xe = jax.vmap(disp_group)(slot, xt)[:, :-1]         # (nG, E*C, d)
        xe = xe.reshape(nG, E, C, d)
        xe = sh.constrain(xe, "batch", None, None, None)
        h = sh.constrain(jnp.einsum("gecd,edf->gecf", xe, p["w_in"]),
                         "batch", None, None, "model")
        g = _act(cfg.act)(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        ye = jnp.einsum("gecf,efd->gecd", h * g, p["w_out"])
        ye_flat = ye.reshape(nG, E * C, d)
        back = jnp.take_along_axis(
            ye_flat, jnp.minimum(slot, E * C - 1).reshape(nG, G * K, 1),
            axis=1).reshape(nG, G, K, d)
        w = (gate_vals * kept).astype(back.dtype)           # (nG, G, K)
        out = jnp.einsum("gtk,gtkd->gtd", w, back)
    else:
        posoh = jax.nn.one_hot(pos, C, dtype=x.dtype)       # (nG, G, K, C)
        disp = jnp.einsum("gtke,gtkc->gtec", keep.astype(x.dtype), posoh)
        comb = jnp.einsum("gtec,gtk,gtke->gtec",
                          disp.astype(F32), gate_vals, keep).astype(x.dtype)

        xe = jnp.einsum("gtec,gtd->gecd", disp, xt)         # (nG, E, C, d)
        xe = sh.constrain(xe, "batch", None, None, None)
        h = sh.constrain(jnp.einsum("gecd,edf->gecf", xe, p["w_in"]),
                         "batch", None, None, "model")
        g = _act(cfg.act)(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
        ye = jnp.einsum("gecf,efd->gecd", h * g, p["w_out"])
        out = jnp.einsum("gtec,gecd->gtd", comb, ye)

    if cfg.moe_num_shared:
        sp = p["shared"]
        hs = (xt @ sp["w_in"]) * _act(cfg.act)(xt @ sp["w_gate"])
        out = out + (hs @ sp["w_out"])

    # load-balance aux loss (Switch-style)
    density = jnp.mean(onehot.sum(2), axis=(0, 1))          # routed frac / e
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * router_prob) * E
    return out.reshape(B, S, d), aux
