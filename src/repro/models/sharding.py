"""Logical sharding constraints usable from inside model code.

`constrain(x, *logical)` applies `with_sharding_constraint` when a mesh
context is active (train/serve/dry-run under `with mesh:`) and is a no-op
otherwise (CPU unit tests).  Logical names:

    batch -> ("pod","data") when the mesh has a pod axis, else ("data",)
    model -> "model"   (TP axis: heads / ff / vocab / channels)
    None  -> unsharded axis
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def current_mesh():
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain(x: jax.Array, *logical):
    mesh = current_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    spec = []
    for ax in logical:
        if ax == "batch":
            spec.append(("pod", "data") if "pod" in names else "data")
        elif ax == "model":
            spec.append("model" if "model" in names else None)
        else:
            spec.append(None)
    # never shard the batch axis finer than its size (e.g. long_500k B=1)
    dp = spec[0]
    if dp is not None and logical and logical[0] == "batch":
        dp_size = 1
        for a in (dp if isinstance(dp, tuple) else (dp,)):
            dp_size *= mesh.shape[a]
        if x.shape[0] % dp_size != 0:
            spec[0] = None
    return jax.lax.with_sharding_constraint(x, P(*spec))
