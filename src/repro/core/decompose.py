"""Signed gadget decomposition (the paper's Decomposer Unit, §IV-E).

Decomposes a torus element v (uint64) into `level` signed digits in
[-B/2, B/2), B = 2^base_log, such that

    v  ~=  sum_l  digit_l * g_l,      g_l = 2^(64 - (l+1)*base_log)

with the closest-representative rounding the hardware's "initial scaling
unit" performs.  Digit index l=0 is the MOST significant level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U64 = jnp.uint64
I64 = jnp.int64


def decompose(v: jax.Array, base_log: int, level: int) -> jax.Array:
    """uint64 (...,) -> int64 (..., level) signed digits, MSB level first."""
    assert v.dtype == U64
    B = 1 << base_log
    total = base_log * level
    shift = 64 - total
    # Round-to-nearest keep of the top `total` bits ("initial scaling unit").
    if shift > 0:
        u = (v + (U64(1) << U64(shift - 1))) >> U64(shift)
    else:
        u = v
    # LSB-first signed digit extraction with carry ("digit extraction unit").
    digits = []
    carry = jnp.zeros_like(u, dtype=I64)
    for _ in range(level):
        raw = (u & U64(B - 1)).astype(I64) + carry
        u = u >> U64(base_log)
        hi = raw >= (B // 2)
        digit = jnp.where(hi, raw - B, raw)
        carry = hi.astype(I64)
        digits.append(digit)
    # final carry folds into bits beyond the kept window; dropped by design
    digits.reverse()  # MSB level first
    return jnp.stack(digits, axis=-1)


def recompose(digits: jax.Array, base_log: int, level: int) -> jax.Array:
    """Inverse of `decompose` up to the rounding error (for tests)."""
    out = jnp.zeros(digits.shape[:-1], dtype=U64)
    for l in range(level):
        g = U64(1) << U64(64 - (l + 1) * base_log)
        out = out + digits[..., l].astype(U64) * g
    return out
