"""GLWE ciphertexts: the LUT carriers of programmable bootstrapping.

Layout: (..., k+1, N) uint64 = [A_1 .. A_k, B]; each row a polynomial in
Z_q[X]/(X^N+1).
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import torus, fft
from repro.core.params import TFHEParams

U64 = jnp.uint64


def keygen(key: jax.Array, k: int, N: int) -> jax.Array:
    """Binary GLWE secret key: (k, N) uint64 in {0,1}."""
    return jax.random.bernoulli(key, 0.5, (k, N)).astype(U64)


def flatten_key(glwe_key: jax.Array) -> jax.Array:
    """The 'big' LWE key sample-extract produces ciphertexts under."""
    return glwe_key.reshape(-1)


def encrypt(key: jax.Array, sk: jax.Array, msg_poly: jax.Array, std: float) -> jax.Array:
    """Encrypt torus polynomial(s) (..., N) -> (..., k+1, N)."""
    k, N = sk.shape
    shape = msg_poly.shape[:-1]
    ka, ke = jax.random.split(key)
    a = torus.random_torus(ka, shape + (k, N))
    e = torus.gaussian_noise(ke, shape + (N,), std)
    # b = sum_i a_i * s_i + m + e  (negacyclic products)
    prod = fft.inverse_torus(
        (fft.forward(a) * fft.forward(sk)).sum(axis=-2)
    )
    b = prod + msg_poly + e
    return jnp.concatenate([a, b[..., None, :]], axis=-2)


def decrypt_phase(sk: jax.Array, ct: jax.Array) -> jax.Array:
    a, b = ct[..., :-1, :], ct[..., -1, :]
    prod = fft.inverse_torus((fft.forward(a) * fft.forward(sk)).sum(axis=-2))
    return b - prod


def trivial(msg_poly: jax.Array, k: int) -> jax.Array:
    """Noiseless GLWE (A=0, B=m): how LUT accumulators start life."""
    z = jnp.zeros(msg_poly.shape[:-1] + (k, msg_poly.shape[-1]), dtype=U64)
    return jnp.concatenate([z, msg_poly[..., None, :].astype(U64)], axis=-2)


def rotate(ct: jax.Array, r: jax.Array, N: int) -> jax.Array:
    """Multiply every polynomial by the monomial X^r, r in [0, 2N).

    Negacyclic: X^N = -1.  Works on any (..., N) trailing-axis layout and
    traced r (per the blind-rotation loop).
    """
    r = jnp.asarray(r, dtype=jnp.uint32).astype(jnp.int64)
    j = jnp.arange(N, dtype=jnp.int64)
    src = (j - r) % (2 * N)              # exponent index in [0, 2N)
    neg = src >= N                        # second copy carries a minus sign
    idx = jnp.where(neg, src - N, src)
    vals = jnp.take(ct, idx, axis=-1)
    return jnp.where(neg, -vals, vals)


def sample_extract(ct: jax.Array) -> jax.Array:
    """Extract the constant coefficient as an LWE ciphertext (paper step D).

    (..., k+1, N) -> (..., k*N+1) under the flattened GLWE key.
    """
    *lead, kp1, N = ct.shape
    a_polys, b_poly = ct[..., :-1, :], ct[..., -1, :]
    # a'_{i*N + j} = A_i[0] if j == 0 else -A_i[N - j]
    rev = -a_polys[..., :, ::-1]                         # -A_i[N-1-j']
    a = jnp.concatenate(
        [a_polys[..., :, :1], rev[..., :, : N - 1]], axis=-1
    )  # [A_i[0], -A_i[N-1], ..., -A_i[1]]
    a = a.reshape(*lead, (kp1 - 1) * N)
    return jnp.concatenate([a, b_poly[..., :1]], axis=-1)


def make_lut_poly(table: jax.Array, params: TFHEParams) -> jax.Array:
    """Encode a plaintext LUT f: [0, 2^width) -> [0, 2^width) as the test
    polynomial V (torus coefficients), pre-rotated by half a slot so the
    rounding window is centred (standard Concrete construction).

    table: (2^width,) integer outputs.
    """
    N, width = params.N, params.width
    reps = N // (1 << width)
    vals = torus.encode(jnp.asarray(table, dtype=U64), params.delta)
    v = jnp.repeat(vals, reps)                            # (N,)
    # multiply by X^{-reps/2}: rotate by 2N - reps//2
    v = rotate(v, jnp.asarray(2 * N - reps // 2), N)
    return v


def make_lut_polys(tables: jax.Array, params: TFHEParams) -> jax.Array:
    """Batched `make_lut_poly`: (B, 2^width) integer tables -> (B, N)."""
    return jax.vmap(lambda t: make_lut_poly(t, params))(
        jnp.asarray(tables, dtype=U64))


# Process-wide test-polynomial cache, one entry per UNIQUE table row per
# parameter set.  A PBS round's (B, 2^width) table stack is almost always
# a tile of 2-3 distinct rows (msg/carry/status tables), and in the
# serving runtime every concurrent request re-derives the same rows —
# encoding each distinct row once and gathering beats re-encoding whole
# stacks (the eager per-row encode at N >= 2048 costs more than the PBS
# dispatch it feeds).  Bounded FIFO: table rows arrive from CLIENT
# programs, so an adversarial stream of all-distinct tables must not pin
# unbounded server memory (each row is an (N,) uint64, ~16KB at N=2048).
# Lookups/eviction are lock-guarded (serving workers are concurrent); the
# expensive encode itself runs outside the lock, so a race at worst
# re-encodes a row.
_ROW_POLY_CACHE: dict = {}
_ROW_POLY_CACHE_MAX = 4096
_ROW_POLY_LOCK = threading.Lock()
# observability: unique-row hits/misses per lookup plus evictions, so
# tests (and serving dashboards) can assert cross-context reuse
_ROW_POLY_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def row_poly_cache_stats() -> dict:
    """Snapshot of the process-wide LUT-poly cache counters."""
    with _ROW_POLY_LOCK:
        return dict(_ROW_POLY_STATS)


def clear_row_poly_cache() -> None:
    """Drop every cached row and reset the counters (test isolation)."""
    with _ROW_POLY_LOCK:
        _ROW_POLY_CACHE.clear()
        _ROW_POLY_STATS.update(hits=0, misses=0, evictions=0)


def _cache_put(key, poly) -> None:
    with _ROW_POLY_LOCK:
        while len(_ROW_POLY_CACHE) >= _ROW_POLY_CACHE_MAX:
            _ROW_POLY_CACHE.pop(next(iter(_ROW_POLY_CACHE)), None)
            _ROW_POLY_STATS["evictions"] += 1
        _ROW_POLY_CACHE[key] = poly


def make_lut_polys_cached(tables, params: TFHEParams) -> jax.Array:
    """`make_lut_polys` through the process-wide per-row cache: only rows
    never seen under `params` are encoded; the stack is gathered from
    cached (N,) polynomials.  Safe under concurrent callers (a race at
    worst re-encodes a row)."""
    tables = np.ascontiguousarray(np.asarray(tables, dtype=np.uint64))
    row_keys = [r.tobytes() for r in tables]
    order: dict = {}
    for i, k in enumerate(row_keys):
        if k not in order:
            order[k] = i
    # snapshot hits locally (under the lock) so concurrent eviction can't
    # race the gather below; counters are per UNIQUE row per lookup
    with _ROW_POLY_LOCK:
        local = {k: _ROW_POLY_CACHE[(params, k)] for k in order
                 if (params, k) in _ROW_POLY_CACHE}
        _ROW_POLY_STATS["hits"] += len(local)
        _ROW_POLY_STATS["misses"] += len(order) - len(local)
    missing = [k for k in order if k not in local]
    if missing:
        polys = make_lut_polys(
            np.stack([tables[order[k]] for k in missing]), params)
        for j, k in enumerate(missing):
            local[k] = polys[j]
            _cache_put((params, k), polys[j])
    uniq = jnp.stack([local[k] for k in order])
    slot = {k: j for j, k in enumerate(order)}
    return uniq[jnp.asarray([slot[k] for k in row_keys])]
