"""Boolean TFHE — the paper's comparison BASELINE (Fig. 2a / Fig. 5 top).

Bits are encoded as ±1/8 on the torus; every gate is one linear
combination followed by a sign-extracting programmable bootstrap (the
"gate bootstrapping" that makes Boolean TFHE ~1000x slower per useful
operation than multi-bit linear ops — Observation 1).

Gates (lin -> sign PBS), with T = 2^64:
    AND : a + b - 1/8        OR  : a + b + 1/8
    NAND: 1/8 - a - b        XOR : 2a + 2b + 1/4
    NOT : -a  (no bootstrap)
Full adder: s = a^b^cin (2 XOR-PBS), cout = MAJ(a,b,cin) = sign(a+b+cin)
(1 PBS) => 3 bootstraps per bit vs the paper's 5-gate count; both are
reported by benchmarks/fig5_addition.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import batch as batch_mod, glwe, lwe
from repro.core.params import TFHEParams
from repro.core.pbs import TFHEContext

U64 = jnp.uint64
EIGHTH = U64(1) << U64(61)       # 1/8 of the torus
QUARTER = U64(1) << U64(62)


def encode_bit(b) -> jax.Array:
    """bit -> ±1/8 torus."""
    b = jnp.asarray(b, U64)
    return jnp.where(b > 0, EIGHTH, (-jnp.asarray(EIGHTH, jnp.int64)).astype(U64))


@dataclasses.dataclass
class BooleanContext:
    """Gate-bootstrapping layer over a TFHEContext's key material."""
    ctx: TFHEContext

    @property
    def params(self) -> TFHEParams:
        return self.ctx.params

    # -- client ----------------------------------------------------------
    def encrypt(self, key: jax.Array, bits) -> jax.Array:
        m = encode_bit(jnp.asarray(bits, U64))
        return lwe.encrypt(key, self.ctx.big_sk, m, self.params.glwe_std)

    def decrypt(self, ct: jax.Array) -> jax.Array:
        ph = lwe.decrypt_phase(self.ctx.big_sk, ct)
        return (ph < (U64(1) << U64(63))).astype(jnp.int32)  # sign(phase)>0

    # -- the sign bootstrap ------------------------------------------------
    def _sign_pbs(self, cts: jax.Array) -> jax.Array:
        """(B, big_n+1) -> sign-refreshed ±1/8 ciphertexts (one PBS each)."""
        p = self.params
        small = batch_mod.keyswitch_batch(cts, self.ctx.ksk, p)
        ms = lwe.mod_switch(small, p.log2_N + 1)
        poly = jnp.full((p.N,), EIGHTH, U64)      # constant +1/8 test poly
        luts = glwe.trivial(jnp.broadcast_to(poly, (cts.shape[0], p.N)), p.k)
        acc = batch_mod.blind_rotate_batch(luts, ms, self.ctx.bsk_f, p)
        return glwe.sample_extract(acc)

    # -- gates (batched over leading axis) ----------------------------------
    def _const(self, c: jax.Array, like: jax.Array) -> jax.Array:
        z = jnp.zeros_like(like)
        return z.at[..., -1].set(c)

    def nand(self, a, b):
        lin = self._const(EIGHTH, a) - a - b
        return self._sign_pbs(lin)

    def and_(self, a, b):
        lin = a + b - self._const(EIGHTH, a)
        return self._sign_pbs(lin)

    def or_(self, a, b):
        lin = a + b + self._const(EIGHTH, a)
        return self._sign_pbs(lin)

    def xor(self, a, b):
        lin = (a + b) * U64(2) + self._const(QUARTER, a)
        return self._sign_pbs(lin)

    def maj(self, a, b, c):
        """Majority(a, b, c) — the carry of a full adder in ONE PBS."""
        return self._sign_pbs(a + b + c)

    def not_(self, a):
        return (-a.astype(jnp.int64)).astype(U64)

    # -- ripple-carry adder (Fig. 5 top) -------------------------------------
    def add_ripple(self, a_bits: jax.Array, b_bits: jax.Array):
        """Add two little-endian encrypted bit vectors (n, big_n+1).

        Returns (n+1, big_n+1) sum bits.  3 bootstraps per bit position
        (2 XOR + 1 MAJ)."""
        n = a_bits.shape[0]
        carry = None
        out = []
        for i in range(n):
            axb = self.xor(a_bits[i:i + 1], b_bits[i:i + 1])
            if carry is None:
                out.append(axb)
                carry = self.and_(a_bits[i:i + 1], b_bits[i:i + 1])
            else:
                out.append(self.xor(axb, carry))
                carry = self.maj(a_bits[i:i + 1], b_bits[i:i + 1], carry)
        out.append(carry)
        return jnp.concatenate(out, axis=0)

    @property
    def bootstraps_per_add_bit(self) -> int:
        return 3
