"""Radix wide-integer arithmetic on the batched engine (the paper's
"multi-bit TFHE unlocks integer workloads" claim, §I Obs. 1-2).

A W-bit integer is a little-endian vector of D = W / msg_bits DIGITS;
each digit is an ordinary multi-bit LWE ciphertext whose 2^width
plaintext space is split into `msg_bits` of message and
`width - msg_bits` of carry headroom (the Concrete/TFHE-rs radix
representation).  Linear digit work (adds, negation, plaintext shifts)
is LPU-only; every nonlinear step — carry extraction, partial products,
comparisons, sign masking — is ONE batched PBS dispatched through
`TaurusEngine.lut_batch`, so a carry-propagation round over all D digits
streams the BSK once for the whole digit vector instead of D times
(round-robin key reuse, paper §III-B / Fig. 13).

Carry propagation strategies:
  ripple     D rounds of batched (msg, carry) extraction; works for any
             width >= 2.
  prefix     Hillis-Steele scan over generate/propagate statuses:
             2 + ceil(log2(D)) batched rounds; needs width >= 4 because
             the status combine is a bivariate LUT over two 2-bit
             statuses.
  lookahead  two-level carry-lookahead for narrow windows (width < 4):
             the status is kept as TWO single-bit ciphertexts (generate,
             propagate) and each Hillis-Steele level splits into two
             batched rounds of univariate LUTs over bit SUMS, so the
             base-2 path drops its D-round ripple for
             2*ceil(log2(D)) + 2 rounds.
All run every round as a single `lut_batch` call of >= D ciphertexts.
"""
from __future__ import annotations

import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glwe, lwe, torus
from repro.core.engine import TaurusEngine
from repro.core.params import TFHEParams
from repro.core.pbs import TFHEContext

U64 = jnp.uint64


# ---------------------------------------------------------------------------
# digit layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RadixSpec:
    """Digit layout of a W-bit integer under one TFHEParams message space."""
    params: TFHEParams
    bits: int                 # integer width W (8 / 16 / 32 ...)
    msg_bits: int             # message bits per digit

    @classmethod
    def create(cls, params: TFHEParams, bits: int,
               msg_bits: int | None = None) -> "RadixSpec":
        m = msg_bits if msg_bits is not None else max(1, params.width // 2)
        spec = cls(params, bits, m)
        spec.validate()
        return spec

    def validate(self) -> None:
        assert self.msg_bits >= 1
        # carry space must cover at least the message space: a digit can
        # then absorb base-1 worth of carries, and bivariate LUTs
        # (a*base + b) fit the plaintext window.
        assert 2 * self.msg_bits <= self.params.width, (
            f"need width >= 2*msg_bits for carries+bivariate LUTs "
            f"(width={self.params.width}, msg_bits={self.msg_bits})")
        assert self.bits % self.msg_bits == 0, (
            "integer width must be a whole number of digits")

    @property
    def base(self) -> int:
        return 1 << self.msg_bits

    @property
    def n_digits(self) -> int:
        return self.bits // self.msg_bits

    @property
    def modulus(self) -> int:
        return 1 << self.bits

    # -- plaintext encode/decode -------------------------------------------
    def to_digits(self, value: int) -> np.ndarray:
        v = int(value) % self.modulus
        return np.array(
            [(v >> (i * self.msg_bits)) & (self.base - 1)
             for i in range(self.n_digits)], dtype=np.uint64)

    def from_digits(self, digits) -> int:
        """Weighted recombination mod 2^bits.  Tolerates un-propagated
        carries (digit values >= base) — the weighted sum still lands on
        the represented integer."""
        v = 0
        for i, d in enumerate(np.asarray(digits, dtype=np.uint64).tolist()):
            v += int(d) << (i * self.msg_bits)
        return v % self.modulus


@dataclasses.dataclass
class RadixCiphertext:
    """Encrypted wide integer: (D, k*N+1) big-key LWE digit ciphertexts,
    little-endian along axis 0."""
    spec: RadixSpec
    digits: jax.Array


# ---------------------------------------------------------------------------
# LUT tables (all indexed by the full 2^width plaintext window)
# ---------------------------------------------------------------------------

def _tbl(width: int, fn) -> np.ndarray:
    n = 1 << width
    return np.array([fn(v) % n for v in range(n)], dtype=np.uint64)


@functools.lru_cache(maxsize=None)
def msg_table(width: int, msg_bits: int) -> np.ndarray:
    return _tbl(width, lambda v: v & ((1 << msg_bits) - 1))


@functools.lru_cache(maxsize=None)
def carry_table(width: int, msg_bits: int) -> np.ndarray:
    return _tbl(width, lambda v: v >> msg_bits)


@functools.lru_cache(maxsize=None)
def sigma_table(width: int, msg_bits: int) -> np.ndarray:
    """Carry status of a digit sum s <= 2*base-1:
    2 = generate (s >= base), 1 = propagate (s == base-1), 0 = neither."""
    base = 1 << msg_bits
    return _tbl(width, lambda s: 2 if s >= base else (1 if s == base - 1 else 0))


@functools.lru_cache(maxsize=None)
def combine_table(width: int, to_carry: bool) -> np.ndarray:
    """Status monoid hi o lo (hi = more significant): hi unless hi is
    propagate, then lo.  Input is the radix-4 pack hi*4 + lo.  With
    to_carry the resolved status is mapped straight to the carry bit
    (generate -> 1), folding the carry readout into the final scan round."""
    def f(c):
        hi, lo = (c >> 2) & 3, c & 3
        r = hi if hi != 1 else lo
        return (1 if r == 2 else 0) if to_carry else r
    return _tbl(width, f)


@functools.lru_cache(maxsize=None)
def status_carry_table(width: int) -> np.ndarray:
    """sigma -> carry bit, for scan lanes whose prefix is already final."""
    return _tbl(width, lambda s: 1 if (s & 3) == 2 else 0)


@functools.lru_cache(maxsize=None)
def status_id_table(width: int) -> np.ndarray:
    """sigma -> sigma: lanes below the scan distance ride along in the
    round's batch (keeps every carry round at >= D ciphertexts)."""
    return _tbl(width, lambda s: s & 3)


@functools.lru_cache(maxsize=None)
def generate_table(width: int, msg_bits: int) -> np.ndarray:
    """Digit sum s -> generate bit [s >= base] (lookahead status)."""
    base = 1 << msg_bits
    return _tbl(width, lambda s: 1 if s >= base else 0)


@functools.lru_cache(maxsize=None)
def propagate_bit_table(width: int, msg_bits: int) -> np.ndarray:
    """Digit sum s -> propagate bit [s == base - 1] (lookahead status)."""
    base = 1 << msg_bits
    return _tbl(width, lambda s: 1 if s == base - 1 else 0)


@functools.lru_cache(maxsize=None)
def bit_and_table(width: int) -> np.ndarray:
    """Sum of two bits -> their AND ([x + y >= 2]); the bivariate bit op
    as a univariate LUT over an LPU add (fits any width >= 2 window)."""
    return _tbl(width, lambda v: 1 if v >= 2 else 0)


@functools.lru_cache(maxsize=None)
def bit_or_table(width: int) -> np.ndarray:
    """Sum of two bits -> their OR ([x + y >= 1]).  On a single bit this
    is the identity, so it doubles as the noise-refresh pass-through for
    scan lanes whose prefix is already final."""
    return _tbl(width, lambda v: 1 if v >= 1 else 0)


@functools.lru_cache(maxsize=None)
def pp_table(width: int, msg_bits: int, hi: bool) -> np.ndarray:
    """Partial product of two digits packed as a*base + b."""
    base = 1 << msg_bits
    def f(c):
        a, b = c >> msg_bits, c & (base - 1)
        p = a * b
        return p >> msg_bits if hi else p & (base - 1)
    return _tbl(width, f)


@functools.lru_cache(maxsize=None)
def cmp_digit_table(width: int, msg_bits: int) -> np.ndarray:
    """Digit comparison a*base + b -> {0: a==b, 1: a<b, 2: a>b}."""
    base = 1 << msg_bits
    def f(c):
        a, b = c >> msg_bits, c & (base - 1)
        return 0 if a == b else (1 if a < b else 2)
    return _tbl(width, f)


@functools.lru_cache(maxsize=None)
def cmp_combine_table(width: int) -> np.ndarray:
    """Lexicographic verdict hi*4 + lo -> hi unless digits tied."""
    def f(c):
        hi, lo = (c >> 2) & 3, c & 3
        return hi if hi != 0 else lo
    return _tbl(width, f)


@functools.lru_cache(maxsize=None)
def sign_table(width: int, msg_bits: int) -> np.ndarray:
    """Top digit -> two's-complement sign bit (its own MSB)."""
    base = 1 << msg_bits
    return _tbl(width, lambda d: 1 if (d & (base - 1)) >= base // 2 else 0)


@functools.lru_cache(maxsize=None)
def mask_table(width: int, msg_bits: int) -> np.ndarray:
    """sign*base + digit -> digit if sign == 0 else 0 (ReLU masking)."""
    base = 1 << msg_bits
    return _tbl(width, lambda c: 0 if c >= base else c)


def _pad_batch(b: int) -> int:
    """Quantize PBS batch sizes so the jitted pbs_batch compiles for a
    small, reusable set of shapes: a floor of 16, then 32, then
    multiples of 32.  Small rounds (a lone sign PBS, a compare-tree
    tail) thus dispatch up to 16 bootstraps for a handful of logical
    ones — on this engine a recompile (~seconds) costs far more than
    the padded blind rotations (~ms), so fewer shapes wins."""
    if b <= 32:
        return 1 << max(4, (b - 1).bit_length())
    return -(-b // 32) * 32


# ---------------------------------------------------------------------------
# client + server API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IntegerContext:
    """Encrypt/compute/decrypt wide integers over a TFHEContext's keys,
    dispatching every nonlinear round through `TaurusEngine.lut_batch`."""
    ctx: TFHEContext
    engine: TaurusEngine
    pad_batches: bool = True
    # optional repro.obs.Telemetry; every nonlinear round publishes
    # integer.* series into its registry when set
    telemetry: object = None
    stats: dict = dataclasses.field(default_factory=lambda: {
        "pbs": 0, "lut_batches": 0, "batch_sizes": [], "dispatch_sizes": []})
    _poly_cache: dict = dataclasses.field(default_factory=dict, repr=False)
    # stats counters are read-modify-write; the serving fan-out runs
    # several vector threads through ONE context, so guard them
    _stats_lock: object = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    @classmethod
    def create(cls, ctx: TFHEContext, engine: TaurusEngine | None = None,
               **kw) -> "IntegerContext":
        return cls(ctx, engine or TaurusEngine.from_context(ctx), **kw)

    @property
    def params(self) -> TFHEParams:
        return self.ctx.params

    def spec(self, bits: int, msg_bits: int | None = None) -> RadixSpec:
        return RadixSpec.create(self.params, bits, msg_bits)

    def reset_stats(self) -> None:
        with self._stats_lock:
            self.stats.update(pbs=0, lut_batches=0, batch_sizes=[],
                              dispatch_sizes=[])

    # -- client side --------------------------------------------------------
    def encrypt(self, key: jax.Array, value: int, bits: int,
                msg_bits: int | None = None) -> RadixCiphertext:
        spec = self.spec(bits, msg_bits)
        digs = jnp.asarray(spec.to_digits(value))
        cts = jax.vmap(lambda k, m: self.ctx.encrypt(k, m))(
            jax.random.split(key, spec.n_digits), digs)
        return RadixCiphertext(spec, cts)

    def decrypt_digits(self, rct: RadixCiphertext) -> np.ndarray:
        return np.asarray(jax.vmap(self.ctx.decrypt)(rct.digits))

    def decrypt(self, rct: RadixCiphertext) -> int:
        return rct.spec.from_digits(self.decrypt_digits(rct))

    def digit_noise(self, rct: RadixCiphertext, value: int) -> np.ndarray:
        """Signed per-digit residual noise (torus units) against the digits
        of the expected plaintext `value` — valid on carry-propagated
        ciphertexts, whose digits are all below base."""
        expect = jnp.asarray(rct.spec.to_digits(value))
        return np.asarray(jax.vmap(self.ctx.decrypt_noise)(rct.digits, expect))

    # -- the one nonlinear primitive ----------------------------------------
    def _lut(self, cts: jax.Array, tables: np.ndarray) -> jax.Array:
        """One PBS batch: per-ciphertext integer tables -> refreshed cts.

        Pads the batch to a quantized size (repeating real ciphertexts)
        so repeated rounds reuse one compiled pbs_batch shape."""
        b = int(cts.shape[0])
        tables = np.ascontiguousarray(np.asarray(tables, dtype=np.uint64))
        dispatch = cts
        dtables = tables
        if self.pad_batches:
            p = _pad_batch(b)
            if p > b:
                reps = -(-p // b)
                dispatch = jnp.tile(cts, (reps, 1))[:p]
                dtables = np.tile(tables, (reps, 1))[:p]
        out = self.engine.lut_batch(dispatch, self._polys(dtables))
        with self._stats_lock:
            self.stats["lut_batches"] += 1
            self.stats["pbs"] += b
            self.stats["batch_sizes"].append(b)
            self.stats["dispatch_sizes"].append(int(dispatch.shape[0]))
        tel = self.telemetry
        if tel is not None:
            tel.counter("integer.lut_batches").inc()
            tel.counter("integer.pbs").inc(b)
            tel.counter("integer.pbs_dispatched").inc(int(dispatch.shape[0]))
            tel.histogram("integer.batch_rows").observe(b)
        return out[:b]

    def _polys(self, tables: np.ndarray) -> jax.Array:
        # stack-level cache on top of the process-wide per-row cache:
        # repeated rounds reuse the same few stacks, and concurrent
        # serving contexts share the row encodes
        key = tables.tobytes()
        if key not in self._poly_cache:
            self._poly_cache[key] = glwe.make_lut_polys_cached(
                tables, self.params)
        return self._poly_cache[key]

    def _trivial_digits(self, spec: RadixSpec, value: int) -> jax.Array:
        m = torus.encode(jnp.full((spec.n_digits,), value, dtype=U64),
                         self.params.delta)
        return lwe.trivial(m, self.params.big_n)

    # -- carry propagation ---------------------------------------------------
    def _extract_round(self, digits: jax.Array, spec: RadixSpec) -> jax.Array:
        """One batched (msg, carry) extraction + shifted re-add: the ripple
        round.  Batch size 2D, one key-stream for the whole vector."""
        d = spec.n_digits
        w, m = self.params.width, spec.msg_bits
        batch = jnp.concatenate([digits, digits], axis=0)
        tables = np.concatenate([np.tile(msg_table(w, m), (d, 1)),
                                 np.tile(carry_table(w, m), (d, 1))])
        out = self._lut(batch, tables)
        msg, carry = out[:d], out[d:]
        return msg.at[1:].add(carry[:-1])

    def _propagate_ripple(self, digits: jax.Array, spec: RadixSpec,
                          rounds: int) -> jax.Array:
        for _ in range(rounds):
            digits = self._extract_round(digits, spec)
        return digits

    def _propagate_prefix(self, digits: jax.Array, spec: RadixSpec) -> jax.Array:
        """Hillis-Steele carry scan.  Preconditions: width >= 4, every
        digit value <= 2*base - 1 and already including its incoming
        additions (no external carry-in)."""
        d = spec.n_digits
        w, m = self.params.width, spec.msg_bits
        # round 1: messages + generate/propagate statuses, one 2D batch
        batch = jnp.concatenate([digits, digits], axis=0)
        tables = np.concatenate([np.tile(msg_table(w, m), (d, 1)),
                                 np.tile(sigma_table(w, m), (d, 1))])
        out = self._lut(batch, tables)
        msg, sig = out[:d], out[d:]
        # scan rounds: log2(D) bivariate status combines.  Every round
        # dispatches all D lanes — lanes below the scan distance pass
        # through a univariate status table — and the last round's LUTs
        # map the resolved status straight to the carry bit.
        dists = []
        dd = 1
        while dd < d:
            dists.append(dd)
            dd *= 2
        carries = None
        for i, dd in enumerate(dists):
            last = i == len(dists) - 1
            comb = lwe.add(lwe.scalar_mul(sig[dd:], 4), sig[:-dd])
            batch = jnp.concatenate([sig[:dd], comb], axis=0)
            lo_tbl = status_carry_table(w) if last else status_id_table(w)
            tables = np.concatenate(
                [np.tile(lo_tbl, (dd, 1)),
                 np.tile(combine_table(w, to_carry=last), (d - dd, 1))])
            out = self._lut(batch, tables)
            if last:
                carries = out
            else:
                sig = out
        # final: add carries and fold digit sums (<= base) back below base.
        # msg_table is the identity below base, so digit 0 rides along and
        # the round stays a full-width D batch.
        summed = msg.at[1:].add(carries[:-1])
        return self._lut(summed, np.tile(msg_table(w, m), (d, 1)))

    def _propagate_lookahead(self, digits: jax.Array, spec: RadixSpec) -> jax.Array:
        """Two-level carry-lookahead for narrow plaintext windows.

        The packed Hillis-Steele scan (`_propagate_prefix`) needs a 4-bit
        window for its radix-4 status pairs.  Below that, the
        (generate, propagate) status lives in TWO single-bit ciphertexts
        and each scan level becomes two batched rounds — the monoid
        combine (g, p) o (g', p') = (g | (p & g'), p & p') decomposed
        into its two levels of bit logic, each an AND/OR evaluated as a
        univariate LUT over an LPU bit sum:

          round A:  t_i  = p_i AND g_{i-dd}     ([p + g >= 2])
                    p_i <- p_i AND p_{i-dd}
          round B:  g_i <- g_i OR t_i           ([g + t >= 1])

        1 + 2*ceil(log2(D)) + 1 batched rounds total, vs D ripple
        rounds.  Preconditions: D > 1 and every digit value
        <= 2*base - 2 (same as the prefix scan)."""
        d = spec.n_digits
        w, m = self.params.width, spec.msg_bits
        # round 1: messages + both status bits, one 3D batch
        batch = jnp.concatenate([digits, digits, digits], axis=0)
        tables = np.concatenate([np.tile(msg_table(w, m), (d, 1)),
                                 np.tile(generate_table(w, m), (d, 1)),
                                 np.tile(propagate_bit_table(w, m), (d, 1))])
        out = self._lut(batch, tables)
        msg, g, p = out[:d], out[d:2 * d], out[2 * d:]
        dd = 1
        while dd < d:
            k = d - dd
            # round A: lookahead terms + propagate combine for lanes >= dd;
            # lanes below the scan distance refresh p through the bit
            # identity (OR) so the round stays >= D ciphertexts
            batch = jnp.concatenate([lwe.add(p[dd:], g[:-dd]),
                                     lwe.add(p[dd:], p[:-dd]),
                                     p[:dd]], axis=0)
            tables = np.concatenate([np.tile(bit_and_table(w), (2 * k, 1)),
                                     np.tile(bit_or_table(w), (dd, 1))])
            out = self._lut(batch, tables)
            t = out[:k]
            p = jnp.concatenate([out[2 * k:], out[k:2 * k]], axis=0)
            # round B: fold the lookahead term into g (lanes < dd final)
            batch = jnp.concatenate([g[:dd], lwe.add(g[dd:], t)], axis=0)
            g = self._lut(batch, np.tile(bit_or_table(w), (d, 1)))
            dd *= 2
        # g[i] is now the carry OUT of digit i; stitch and fold below base
        summed = msg.at[1:].add(g[:-1])
        return self._lut(summed, np.tile(msg_table(w, m), (d, 1)))

    @staticmethod
    def lookahead_rounds(n_digits: int) -> int:
        """Batched-PBS rounds of the two-level lookahead strategy."""
        return 2 + 2 * max(0, (n_digits - 1).bit_length())

    def propagate(self, rct: RadixCiphertext, max_val: int | None = None,
                  strategy: str = "auto") -> RadixCiphertext:
        """Carry-propagate so every digit lands in [0, base).

        max_val bounds the current per-digit plaintext value (defaults to
        the whole 2^width window); values above 2*base-2 are first folded
        down by batched extraction rounds.  The 2*base-2 ceiling keeps
        every intermediate carry in {0, 1} — the prefix statuses cannot
        express a carry of 2 (which v = 2*base-1 plus an incoming carry
        would produce)."""
        spec = rct.spec
        base, w = spec.base, self.params.width
        digits = rct.digits
        if max_val is None:
            max_val = (1 << w) - 1
        # pre-reduction: each round maps v -> (v mod base) + (v' >> msg)
        while max_val > 2 * base - 2:
            max_val = (base - 1) + (max_val >> spec.msg_bits)
            digits = self._extract_round(digits, spec)
        if strategy == "auto":
            if w >= 4 and spec.n_digits > 1:
                strategy = "prefix"
            elif (spec.n_digits > 1
                  and self.lookahead_rounds(spec.n_digits) < spec.n_digits):
                strategy = "lookahead"       # narrow window, long chains
            else:
                strategy = "ripple"
        if strategy == "prefix":
            # the radix-4 status pack needs a 4-bit window, and a single
            # digit has no carries to scan — explicit misuse would decrypt
            # wrong, not just slow
            assert w >= 4 and spec.n_digits > 1, (
                "prefix carry scan needs width >= 4 and more than one digit")
            digits = self._propagate_prefix(digits, spec)
        elif strategy == "lookahead":
            assert spec.n_digits > 1, (
                "lookahead carry scan needs more than one digit")
            digits = self._propagate_lookahead(digits, spec)
        else:
            digits = self._propagate_ripple(digits, spec, spec.n_digits)
        return RadixCiphertext(spec, digits)

    # -- arithmetic -----------------------------------------------------------
    def add(self, a: RadixCiphertext, b: RadixCiphertext) -> RadixCiphertext:
        assert a.spec == b.spec
        s = lwe.add(a.digits, b.digits)
        return self.propagate(RadixCiphertext(a.spec, s),
                              max_val=2 * a.spec.base - 2)

    def sub(self, a: RadixCiphertext, b: RadixCiphertext) -> RadixCiphertext:
        """a - b mod 2^bits, via base-complement: a + ~b + 1."""
        assert a.spec == b.spec
        spec = a.spec
        neg = lwe.sub(self._trivial_digits(spec, spec.base - 1), b.digits)
        s = lwe.add(a.digits, neg)
        s = s.at[0, -1].add(U64(self.params.delta))        # the +1 at the LSB
        # max_val describes digits that can RECEIVE a carry (<= 2*base-2);
        # only digit 0 holds the extra +1, and it has no incoming carry,
        # so its 2*base-1 ceiling still yields a single outgoing carry.
        return self.propagate(RadixCiphertext(spec, s),
                              max_val=2 * spec.base - 2)

    def _pp_batch(self, comb: jax.Array, spec: RadixSpec):
        """Dispatch packed digit pairs (a*base + b) through BOTH partial-
        product halves in one batch; returns (lo, hi) digit vectors."""
        t = int(comb.shape[0])
        w, m = self.params.width, spec.msg_bits
        batch = jnp.concatenate([comb, comb], axis=0)
        tables = np.concatenate([np.tile(pp_table(w, m, hi=False), (t, 1)),
                                 np.tile(pp_table(w, m, hi=True), (t, 1))])
        out = self._lut(batch, tables)
        return out[:t], out[t:]

    def mul_digit(self, a: RadixCiphertext, digit_ct: jax.Array) -> RadixCiphertext:
        """Multiply by ONE encrypted digit (< base): a row of the schoolbook
        product.  Both partial-product halves run as a single 2D batch."""
        spec = a.spec
        base = spec.base
        comb = lwe.add(lwe.scalar_mul(a.digits, base),
                       jnp.broadcast_to(digit_ct, a.digits.shape))
        lo, hi = self._pp_batch(comb, spec)
        s = lo.at[1:].add(hi[:-1])
        return self.propagate(RadixCiphertext(spec, s),
                              max_val=2 * base - 3)

    def mul(self, a: RadixCiphertext, b: RadixCiphertext) -> RadixCiphertext:
        """Schoolbook product mod 2^bits.  All D*(D+1) partial-product LUTs
        fire as ONE batch; column sums then compress through batched
        carry-save rounds sized to the carry headroom."""
        assert a.spec == b.spec
        spec = a.spec
        d, base = spec.n_digits, spec.base
        w, m = self.params.width, spec.msg_bits
        window = (1 << w) - 1

        pairs = [(i, j) for i in range(d) for j in range(d - i)]
        ii = np.array([i for i, _ in pairs])
        jj = np.array([j for _, j in pairs])
        comb = lwe.add(lwe.scalar_mul(a.digits[ii], base), b.digits[jj])
        lo, hi = self._pp_batch(comb, spec)

        # columns of (ciphertext, max plaintext value) terms
        cols: list = [[] for _ in range(d)]
        for k, (i, j) in enumerate(pairs):
            cols[i + j].append((lo[k], base - 1))
            if i + j + 1 < d:
                cols[i + j + 1].append((hi[k], max(base - 2, 0)))
        # carry-save compression: per round, greedily group terms whose
        # plaintext sum fits the 2^width window, then extract (msg, carry)
        # for every group in one batch.
        guard = 0
        while any(len(c) > 1 for c in cols):
            guard += 1
            assert guard <= 8 * d, "carry-save reduction failed to converge"
            groups = []          # (col, [cts], group_max)
            for ci in range(d):
                col = cols[ci]
                if len(col) < 2:
                    continue
                # smallest-first: any two terms fit (2*(base-1) <= window)
                col.sort(key=lambda tm: tm[1])
                taken, mx = [], 0
                while col and mx + col[0][1] <= window:
                    ct, v = col.pop(0)
                    taken.append(ct)
                    mx += v
                groups.append((ci, taken, mx))
            batch = jnp.stack([sum_cts(g[1]) for g in groups] * 2)
            n = len(groups)
            tables = np.concatenate([np.tile(msg_table(w, m), (n, 1)),
                                     np.tile(carry_table(w, m), (n, 1))])
            ext = self._lut(batch, tables)
            for gi, (ci, _, mx) in enumerate(groups):
                cols[ci].append((ext[gi], base - 1))
                if ci + 1 < d:
                    cols[ci + 1].append((ext[n + gi], mx >> m))
        digits = jnp.stack([c[0][0] for c in cols])
        res_max = max(v for c in cols for _, v in c)
        # with width == 2*msg_bits every surviving term is already < base
        # (carries bound by window >> msg_bits): the product is reduced and
        # a final propagation would only burn PBS rounds
        if res_max < base:
            return RadixCiphertext(spec, digits)
        return self.propagate(RadixCiphertext(spec, digits), max_val=res_max)

    def linear_compress(self, xs: jax.Array, W,
                        spec: RadixSpec) -> tuple[jax.Array, int]:
        """Integer-weight linear layer over a batch of radix vectors,
        reduced to ONE un-propagated digit vector per output column.

        xs: (V_in, D, k*N+1) carry-propagated digit vectors (every digit
        below base); W: integer (V_in, V_out) matrix.  Returns
        (digits, max_val): a (V_out, D, k*N+1) array where digits[j]
        represents sum_i W[i, j] * x_i mod 2^bits with every digit's
        plaintext value <= max_val — `propagate(..., max_val=max_val)`
        per output vector finishes the reduction.

        Negative weights lower through the base complement
        (-w*x = |w|*(~x) + |w|, ~x digitwise base-1-d), with the +|w|
        constants collected into one trivial digit-vector term per
        column.  Positive/complement terms then carry-save compress like
        `mul`'s column reduction: each round greedily merges the terms
        whose summed per-digit ceiling fits the 2^width window (one
        group per column), and ALL groups extract (msg, carry) in a
        single `lut_batch` — the serving scheduler fuses these rounds
        across concurrent requests like any other radix round."""
        W = np.asarray(W, np.int64)
        v_in, v_out = W.shape
        d, base, m = spec.n_digits, spec.base, spec.msg_bits
        w_bits = self.params.width
        window = (1 << w_bits) - 1
        assert int(xs.shape[0]) == v_in and int(xs.shape[1]) == d, (
            f"linear_compress: xs {xs.shape} vs W {W.shape} x {d} digits")
        # any two compressed terms (ceiling (base-1) + window>>m each) must
        # merge within the window or the reduction stalls: msg_bits == 1
        # (a 2-bit window) cannot host a linear layer
        assert 2 * ((base - 1) + (window >> m)) <= window, (
            f"radix_linear needs carry headroom to merge compressed terms "
            f"(msg_bits={m}, width={w_bits}; use msg_bits >= 2)")

        terms: list = []                 # per column: [(digit_vec, max)]
        for j in range(v_out):
            col: list = []
            negsum = 0
            for i in range(v_in):
                w = int(W[i, j])
                if w == 0:
                    continue
                if w > 0:
                    ct = xs[i] if w == 1 else lwe.scalar_mul(xs[i], w)
                    col.append((ct, w * (base - 1)))
                else:
                    comp = lwe.sub(self._trivial_digits(spec, base - 1),
                                   xs[i])
                    if w < -1:
                        comp = lwe.scalar_mul(comp, -w)
                    col.append((comp, (-w) * (base - 1)))
                    negsum += -w
            if negsum:
                digs = torus.encode(jnp.asarray(spec.to_digits(negsum)),
                                    self.params.delta)
                col.append((lwe.trivial(digs, self.params.big_n), base - 1))
            if not col:
                col.append((self._trivial_digits(spec, 0), 0))
            for _, mx in col:
                assert mx <= window, (
                    f"weight magnitude overflows the digit window "
                    f"(per-digit ceiling {mx} > {window})")
            terms.append(col)

        guard = 0
        max_rounds = 8 * (d + max(len(c) for c in terms)) + 8
        while any(len(c) > 1 for c in terms):
            guard += 1
            assert guard <= max_rounds, "carry-save linear failed to converge"
            groups = []                  # (col, summed ct, group max)
            for j in range(v_out):
                col = terms[j]
                if len(col) < 2:
                    continue
                col.sort(key=lambda tm: tm[1])
                taken, mx = [], 0
                while col and mx + col[0][1] <= window:
                    ct, v = col.pop(0)
                    taken.append(ct)
                    mx += v
                if len(taken) < 2:
                    # no pair fits the window: solo-extract the LARGEST
                    # term instead — its ceiling strictly shrinks (it
                    # must exceed base here, or a pair would have fit),
                    # whereas re-extracting a small term spins forever
                    col.extend(zip(taken, [mx] * len(taken)))
                    col.sort(key=lambda tm: tm[1])
                    ct, mx = col.pop()
                    taken = [ct]
                groups.append((j, sum_cts(taken), mx))
            gn = len(groups)
            gcts = jnp.concatenate([g[1] for g in groups], axis=0)
            batch = jnp.concatenate([gcts, gcts], axis=0)
            tables = np.concatenate(
                [np.tile(msg_table(w_bits, m), (gn * d, 1)),
                 np.tile(carry_table(w_bits, m), (gn * d, 1))])
            out = self._lut(batch, tables)
            msgs = out[:gn * d].reshape(gn, d, -1)
            carries = out[gn * d:].reshape(gn, d, -1)
            for gi, (j, _, mx) in enumerate(groups):
                new = msgs[gi].at[1:].add(carries[gi][:-1])
                terms[j].append((new, (base - 1) + (mx >> m)))

        digits = jnp.stack([c[0][0] for c in terms])
        max_val = max(c[0][1] for c in terms)
        return digits, max_val

    # -- predicates -----------------------------------------------------------
    def compare(self, a: RadixCiphertext, b: RadixCiphertext) -> jax.Array:
        """Encrypted three-way compare: one ciphertext holding
        0 (a == b), 1 (a < b) or 2 (a > b).  Per-digit verdicts in one
        batch, then a log-depth lexicographic tree reduce."""
        assert a.spec == b.spec
        spec = a.spec
        w, m = self.params.width, spec.msg_bits
        assert w >= 4, "compare needs width >= 4 (bivariate verdict combine)"
        comb = lwe.add(lwe.scalar_mul(a.digits, spec.base), b.digits)
        cur = self._lut(comb, np.tile(cmp_digit_table(w, m),
                                      (spec.n_digits, 1)))
        while cur.shape[0] > 1:
            n = int(cur.shape[0])
            lo, hi = cur[0:n - 1:2], cur[1:n:2]
            comb = lwe.add(lwe.scalar_mul(hi, 4), lo)
            out = self._lut(comb, np.tile(cmp_combine_table(w),
                                          (comb.shape[0], 1)))
            if n % 2:
                out = jnp.concatenate([out, cur[n - 1:]], axis=0)
            cur = out
        return cur[0]

    def relu_clamp(self, a: RadixCiphertext) -> RadixCiphertext:
        """max(a, 0) for a interpreted as a two's-complement signed
        integer: one sign PBS on the top digit, then one batched masking
        round over all digits."""
        spec = a.spec
        w, m = self.params.width, spec.msg_bits
        sign = self._lut(a.digits[-1:], sign_table(w, m)[None])[0]
        comb = lwe.add(a.digits,
                       jnp.broadcast_to(lwe.scalar_mul(sign, spec.base),
                                        a.digits.shape))
        out = self._lut(comb, np.tile(mask_table(w, m), (spec.n_digits, 1)))
        return RadixCiphertext(spec, out)


def sum_cts(cts: list) -> jax.Array:
    """Linear sum of LWE ciphertexts (LPU work, no PBS)."""
    acc = cts[0]
    for c in cts[1:]:
        acc = lwe.add(acc, c)
    return acc
