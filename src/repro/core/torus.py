"""Torus arithmetic on q = 2^64 (uint64 wraparound).

A torus element t in [0,1) is stored as round(t * 2^64) mod 2^64.
All additions/multiplications below are exact mod-2^64 wraparound ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U64 = jnp.uint64
I64 = jnp.int64


def to_signed(x: jax.Array) -> jax.Array:
    """Reinterpret uint64 as two's-complement int64 (no value change mod q)."""
    return x.astype(I64)


def to_unsigned(x: jax.Array) -> jax.Array:
    return x.astype(U64)


def encode(msg: jax.Array, delta: int) -> jax.Array:
    """Integer message -> torus: m * delta mod q."""
    return (msg.astype(U64) * U64(delta)).astype(U64)


def decode(t: jax.Array, delta: int, modulus: int) -> jax.Array:
    """Torus -> integer message: round(t / delta) mod message-modulus."""
    half = U64(delta >> 1)
    return ((t + half) // U64(delta)).astype(U64) % U64(modulus)


def random_torus(key: jax.Array, shape) -> jax.Array:
    return jax.random.bits(key, shape, dtype=U64)


def gaussian_noise(key: jax.Array, shape, std: float) -> jax.Array:
    """Gaussian noise with std given in torus units, wrapped to uint64."""
    e = jax.random.normal(key, shape, dtype=jnp.float64) * (std * 2.0**64)
    # Round-to-nearest then wrap mod 2^64. f64 -> i64 saturates at +-2^63,
    # which is fine: std*2^64 << 2^63 for any sane parameter set.
    return jnp.round(e).astype(I64).astype(U64)


def float_to_torus(x: jax.Array) -> jax.Array:
    """Round a float64 array (arbitrary magnitude) to uint64 mod 2^64.

    Split into hi/lo parts while still in float space (both splits are
    EXACT f64 ops), then wrap in integer space — wrapping in f64 would
    destroy low bits near 2^64 (ulp there is 2^11).  Valid for |x| < 2^95.
    """
    hi = jnp.round(x / 2.0**32)
    lo = x - hi * 2.0**32                 # exact; in [-2^31, 2^31]
    return (
        (hi.astype(I64) << I64(32)) + jnp.round(lo).astype(I64)
    ).astype(U64)
