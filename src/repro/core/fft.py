"""Negacyclic polynomial multiplication via the double-real ("twisted") FFT.

The paper (§IV-C) processes a degree-2^16 polynomial with a 2^15-point
complex FFT ("double-real FFT").  This module is the mathematical core of
that trick, in pure JAX:

    forward :  N real coeffs  ->  N/2 complex values
               u_j = a_j + i * a_{j+N/2}
               v_j = u_j * exp(i*pi*j/N)            (the "twist")
               A   = FFT_{N/2}(v)
    pointwise multiply in the transform domain == negacyclic convolution
    inverse :  untwist + split real/imag.

`repro.kernels.fourstep_fft` implements the FFT itself as the paper's
heterogeneous 256x128 factorization (MXU matmuls); this module is the
complex128 reference path — the kernel oracle AND what
`TaurusEngine(kernel_backend="reference")` (the default) runs.  With
`kernel_backend="pallas"` the engine's PBS hot path runs the Pallas
kernel instead, with f64 planes (`repro.kernels.fused_pbs`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import torus


@functools.lru_cache(maxsize=32)
def _twist(N: int):
    import numpy as np

    j = np.arange(N // 2)
    return jnp.asarray(np.exp(1j * np.pi * j / N), dtype=jnp.complex128)


def forward(poly: jax.Array) -> jax.Array:
    """Real (...,(N,)) -> complex (...,(N/2,)) negacyclic transform.

    Accepts float64 or (u)int coefficient arrays; integers are taken as
    SIGNED representatives (int64 view for torus values).
    """
    N = poly.shape[-1]
    if jnp.issubdtype(poly.dtype, jnp.unsignedinteger):
        poly = torus.to_signed(poly)
    poly = poly.astype(jnp.float64)
    u = poly[..., : N // 2] + 1j * poly[..., N // 2:]
    return jnp.fft.fft(u * _twist(N), axis=-1)


def inverse(spec: jax.Array) -> jax.Array:
    """Complex (...,(N/2,)) -> float64 (...,(N,)) coefficients."""
    N = spec.shape[-1] * 2
    u = jnp.fft.ifft(spec, axis=-1) * jnp.conj(_twist(N))
    return jnp.concatenate([jnp.real(u), jnp.imag(u)], axis=-1)


def inverse_torus(spec: jax.Array) -> jax.Array:
    """Inverse transform folded back onto the torus (uint64 mod 2^64)."""
    return torus.float_to_torus(inverse(spec))


def negacyclic_mul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact-ish negacyclic product of two integer polys, mod 2^64.

    `a` is expected to hold SMALL integers (e.g. gadget-decomposed digits),
    `b` arbitrary torus values; this keeps the f64 roundoff below the
    scheme noise (the paper's 48-bit fixed-point argument, Obs. 4).
    """
    return inverse_torus(forward(a) * forward(b))
