"""Noise-budget analysis (the paper's Fig. 6 parameter interplay).

Variance propagation through the TFHE pipeline, Concrete-style:

    fresh LWE            var = lwe_std^2          (torus units^2)
    x + y                var_x + var_y
    c * x                c^2 * var
    key-switch           var + big_n * ks_level * E[digit^2] * lwe_std^2
                             + big_n * decomposition rounding term
    PBS output           n * (k+1) * pbs_level * N * B^2/12 * glwe_std^2
                             + n * (1 + k*N) / (4 * (2N)^2)   (mod-switch)

`failure_prob` is the Gaussian tail of the phase noise crossing half a
message slot (delta/2) — the paper keeps p_err < 2^-40.  These formulas
drive parameter validation tests and document WHY wider widths force the
larger (n, N) the paper's hardware must then cope with (Obs. in §III-B).
"""
from __future__ import annotations

import math

from repro.core.params import TFHEParams


def fresh_var(p: TFHEParams) -> float:
    return p.glwe_std ** 2


def keyswitch_var(p: TFHEParams, var_in: float) -> float:
    B = 2.0 ** p.ks_base_log
    digit2 = B * B / 12.0
    key_term = p.big_n * p.ks_level * digit2 * (p.lwe_std ** 2)
    # rounding of dropped levels: uniform in +-2^(64 - l*blog - 1)
    drop = 2.0 ** -(p.ks_base_log * p.ks_level)
    round_term = p.big_n * (drop ** 2) / 48.0
    return var_in + key_term + round_term


def modswitch_var(p: TFHEParams, var_in: float) -> float:
    twoN = 2.0 * p.N
    return var_in + (1.0 + p.n * 0.5) / (12.0 * twoN * twoN)


def pbs_out_var(p: TFHEParams) -> float:
    """Output noise of blind rotation (independent of input noise)."""
    B = 2.0 ** p.pbs_base_log
    digit2 = B * B / 12.0
    ext = p.n * (p.k + 1) * p.pbs_level * p.N * digit2 * (p.glwe_std ** 2)
    drop = 2.0 ** -(p.pbs_base_log * p.pbs_level)
    round_term = p.n * (p.k + 1) * p.N * (drop ** 2) / 48.0
    return ext + round_term


def pre_rotation_std(p: TFHEParams, var_in: float) -> float:
    """Phase noise entering the blind rotation (after KS + MS)."""
    return math.sqrt(modswitch_var(p, keyswitch_var(p, var_in)))


def failure_prob(p: TFHEParams, var_in: float | None = None) -> float:
    """P[decode error]: phase noise exceeding half a message slot at the
    blind-rotation input (the step that actually rounds to a LUT slot)."""
    if var_in is None:
        var_in = pbs_out_var(p)       # steady state: output of previous PBS
    std = pre_rotation_std(p, var_in)
    half_slot = 2.0 ** -(p.width + p.padding_bits + 1)
    z = half_slot / max(std, 1e-300)
    # log-domain Gaussian tail: erfc(z/sqrt(2)) ~ exp(-z^2/2)
    return math.erfc(z / math.sqrt(2.0))


def log2_failure_prob(p: TFHEParams, width: int | None = None) -> float:
    w = p.width if width is None else width
    z = (2.0 ** -(w + p.padding_bits + 1)) / \
        max(pre_rotation_std(p, pbs_out_var(p)), 1e-300)
    # log2 erfc(z/sqrt2) ~ -z^2/(2 ln2) for large z
    return -(z * z) / (2.0 * math.log(2.0))


def radix_width(p: TFHEParams) -> int:
    """Per-PBS message width when a width-w program runs in radix
    (msg+carry) chunks — Concrete's strategy for small N (the paper's
    footnotes 3/4).  The LARGE-N sets (Table II's 32768/65536) carry the
    full width in one LUT up to 9 bits; at 10 bits the modulus-switch
    noise floor (~(n/2)/(12*(2N)^2)) forces the multi-LUT / bit-extraction
    evaluation of the paper's reference [10] (Chillotti et al., larger-
    precision PBS), i.e. radix chunks again."""
    if p.N >= 16384 and p.width <= 9:
        return p.width
    return (p.width + 1) // 2 + 1
