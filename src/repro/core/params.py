"""TFHE parameter sets.

Terminology follows the paper (§II):
  n       LWE dimension of the *small* key (blind-rotation loop length)
  N       GLWE polynomial degree (power of two; paper scales to 2^16)
  k       GLWE dimension (paper: k=1 for wide multi-bit TFHE, Obs. 3)
  width   message bits per ciphertext (paper: up to 10)
  pbs_*   gadget decomposition of the external product (base 2^pbs_base_log,
          depth pbs_level)
  ks_*    gadget decomposition of key-switching
  *_std   noise standard deviations, in torus units (fraction of q)

The *big* LWE dimension (output of sample-extract, input of key-switch in
the paper's key-switching-first order) is always k*N.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class TFHEParams:
    name: str
    n: int
    N: int
    k: int
    width: int
    pbs_base_log: int
    pbs_level: int
    ks_base_log: int
    ks_level: int
    lwe_std: float
    glwe_std: float
    padding_bits: int = 1  # one carry/padding bit, Concrete-style

    @property
    def big_n(self) -> int:
        return self.k * self.N

    @property
    def q_bits(self) -> int:
        return 64

    @property
    def log2_N(self) -> int:
        return int(math.log2(self.N))

    @property
    def delta(self) -> int:
        """Scaling factor of the message encoding (one padding bit)."""
        return 1 << (self.q_bits - self.width - self.padding_bits)

    @property
    def plaintext_modulus(self) -> int:
        return 1 << self.width

    def validate(self) -> None:
        assert self.N & (self.N - 1) == 0, "N must be a power of two"
        assert self.pbs_base_log * self.pbs_level <= self.q_bits
        assert self.ks_base_log * self.ks_level <= self.q_bits
        assert self.width + self.padding_bits <= self.log2_N, (
            "LUT needs >=1 coefficient per message slot"
        )


# --- Unit-test parameter sets -----------------------------------------------
# Correctness-oriented: small n/N keep CPU tests fast; noise is set low so
# the decryption-failure probability is negligible. NOT cryptographically
# secure (security needs n ~ 700+, see PAPER_PARAMS); correctness and
# dataflow are identical.
TEST_PARAMS = TFHEParams(
    name="test-2bit",
    n=64, N=512, k=1, width=2,
    pbs_base_log=12, pbs_level=2,
    ks_base_log=4, ks_level=5,
    lwe_std=2.0 ** -45, glwe_std=2.0 ** -45,
)

TEST_PARAMS_4BIT = TFHEParams(
    name="test-4bit",
    n=96, N=2048, k=1, width=4,
    pbs_base_log=14, pbs_level=2,
    ks_base_log=5, ks_level=5,
    lwe_std=2.0 ** -48, glwe_std=2.0 ** -48,
)

TEST_PARAMS_6BIT = TFHEParams(
    name="test-6bit",
    n=128, N=4096, k=1, width=6,
    pbs_base_log=16, pbs_level=2,
    ks_base_log=6, ks_level=4,
    lwe_std=2.0 ** -50, glwe_std=2.0 ** -50,
)

TEST_PARAMS_K2 = TFHEParams(
    name="test-2bit-k2",
    n=48, N=256, k=2, width=2,
    pbs_base_log=12, pbs_level=2,
    ks_base_log=4, ks_level=5,
    lwe_std=2.0 ** -45, glwe_std=2.0 ** -45,
)

# --- Paper parameter sets (Table II) -----------------------------------------
# n, (N, k), width exactly as reported; decomposition/noise follow the
# Concrete optimizer's choices for 128-bit security at p_err < 2^-40.
# These drive the cost model and dry-run style benchmarks (a full blind
# rotation at N=65536 is run through the batched engine, not unit tests).
def _paper(name, n, N, k, width):
    # Representative Concrete-style decomposition for 64-bit torus at these
    # scales (base/level grow with width; values match TFHE-rs defaults for
    # the corresponding precision tier).
    if width <= 4:
        pbs = (23, 1); ks = (3, 5)
    elif width <= 6:
        pbs = (22, 1); ks = (3, 6)
    elif width <= 8:
        pbs = (15, 2); ks = (4, 6)
    else:
        pbs = (11, 3); ks = (4, 7)
    return TFHEParams(
        name=name, n=n, N=N, k=k, width=width,
        pbs_base_log=pbs[0], pbs_level=pbs[1],
        ks_base_log=ks[0], ks_level=ks[1],
        # Fig. 6 security line (128-bit): log2(sigma) ~ -0.0255 * n
        lwe_std=2.0 ** (-0.0255 * n), glwe_std=2.0 ** -51,
    )


PAPER_PARAMS = {
    # Table II: workload -> n, (N, k), width
    "cnn20":       _paper("cnn20",       737,  2048,  1, 6),
    "cnn50":       _paper("cnn50",       828,  4096,  1, 6),
    "decision_tree": _paper("decision_tree", 1070, 65536, 1, 9),
    "gpt2":        _paper("gpt2",        1003, 32768, 1, 6),
    "gpt2_12head": _paper("gpt2_12head", 1009, 32768, 1, 6),
    "knn":         _paper("knn",         1058, 65536, 1, 9),
    "xgboost":     _paper("xgboost",     1025, 32768, 1, 8),
    # the paper's 10-bit headline capability
    "max10bit":    _paper("max10bit",    1100, 65536, 1, 10),
}

for _p in list(PAPER_PARAMS.values()) + [TEST_PARAMS, TEST_PARAMS_4BIT, TEST_PARAMS_K2]:
    _p.validate()
