"""Programmable bootstrapping, key-switching-FIRST order (paper §II-B).

Pipeline (paper Fig. 3):  A key-switch -> B mod-switch -> C blind rotation
-> D sample extract.  Ciphertexts between PBS ops live under the BIG key
(dimension k*N); key-switch brings them down to the small key (dimension
n) right before blind rotation.  This order is what enables the
compiler's KS-dedup (Observation 6).

`TFHEContext` bundles keygen + client ops; `pbs()` is the server op.
The batched variant lives in `repro.core.batch` (reference) and
`repro.kernels.fused_pbs` (Pallas engine room) — `TaurusEngine`
selects between them via `kernel_backend`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import torus, fft, glwe, ggsw, lwe
from repro.core.params import TFHEParams

U64 = jnp.uint64


def blind_rotate(lut_glwe: jax.Array, lwe_ct_mod: jax.Array,
                 bsk_f: jax.Array, params: TFHEParams) -> jax.Array:
    """Blind rotation (paper step C).

    lut_glwe: (k+1, N) trivial/encrypted GLWE holding the LUT.
    lwe_ct_mod: (n+1,) uint64 values already mod-switched into [0, 2N).
    bsk_f: (n, k+1, level, k+1, N/2) fourier BSK.
    """
    N = params.N
    a, b = lwe_ct_mod[:-1], lwe_ct_mod[-1]
    acc = glwe.rotate(lut_glwe, (2 * N - b) % (2 * N), N)   # X^{-b} * V

    def step(acc, inp):
        a_i, bsk_i = inp
        rotated = glwe.rotate(acc, a_i, N)                  # X^{a_i} * acc
        return ggsw.cmux_fourier(
            bsk_i, acc, rotated, params.pbs_base_log, params.pbs_level
        ), None

    acc, _ = jax.lax.scan(step, acc, (a, bsk_f))
    return acc


@functools.partial(jax.jit, static_argnames=("params",))
def pbs(big_ct: jax.Array, lut_poly: jax.Array, bsk_f: jax.Array,
        ksk: jax.Array, params: TFHEParams) -> jax.Array:
    """One full PBS: (k*N+1,) LWE + (N,) LUT poly -> (k*N+1,) LWE.

    Output has the LUT applied and noise refreshed.
    """
    # A: key-switch big -> small
    small = lwe.keyswitch(big_ct, ksk, params.ks_base_log, params.ks_level)
    # B: mod-switch to Z_2N
    ms = lwe.mod_switch(small, params.log2_N + 1)
    # C: blind rotation
    acc = blind_rotate(glwe.trivial(lut_poly, params.k), ms, bsk_f, params)
    # D: sample extract back to the big key
    return glwe.sample_extract(acc)


@dataclasses.dataclass
class TFHEContext:
    """Client-side key material + encode/encrypt helpers (Fig. 1 client)."""
    params: TFHEParams
    lwe_sk: jax.Array      # small key (n,)
    glwe_sk: jax.Array     # (k, N)
    big_sk: jax.Array      # flattened GLWE key (k*N,)
    bsk_f: jax.Array       # fourier bootstrapping key (server/eval key)
    ksk: jax.Array         # key-switching key big->small (server/eval key)

    @classmethod
    def create(cls, key: jax.Array, params: TFHEParams) -> "TFHEContext":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        lwe_sk = lwe.keygen(k1, params.n)
        glwe_sk = glwe.keygen(k2, params.k, params.N)
        big_sk = glwe.flatten_key(glwe_sk)
        bsk = ggsw.bsk_gen(k3, lwe_sk, glwe_sk, params)
        bsk_f = ggsw.bsk_to_fourier(bsk)
        ksk = lwe.ksk_gen(k4, big_sk, lwe_sk,
                          params.ks_base_log, params.ks_level, params.lwe_std)
        return cls(params, lwe_sk, glwe_sk, big_sk, bsk_f, ksk)

    # -- client ops ------------------------------------------------------
    def encrypt(self, key: jax.Array, msg) -> jax.Array:
        """Encrypt integer message(s) under the BIG key (PBS-ready)."""
        m = torus.encode(jnp.asarray(msg, dtype=U64), self.params.delta)
        return lwe.encrypt(key, self.big_sk, m, self.params.glwe_std)

    def decrypt(self, ct: jax.Array) -> jax.Array:
        ph = lwe.decrypt_phase(self.big_sk, ct)
        return torus.decode(ph, self.params.delta, self.params.plaintext_modulus)

    def decrypt_noise(self, ct: jax.Array, msg) -> jax.Array:
        """Signed residual noise (torus units) for noise-budget tests."""
        ph = lwe.decrypt_phase(self.big_sk, ct)
        expect = torus.encode(jnp.asarray(msg, dtype=U64), self.params.delta)
        return torus.to_signed(ph - expect).astype(jnp.float64) / 2.0**64

    # -- server op ---------------------------------------------------------
    def lut(self, ct: jax.Array, table) -> jax.Array:
        poly = glwe.make_lut_poly(jnp.asarray(table, dtype=U64), self.params)
        return pbs(ct, poly, self.bsk_f, self.ksk, self.params)
