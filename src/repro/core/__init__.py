"""Core multi-bit TFHE scheme in JAX (the paper's subject).

The torus modulus is q = 2^64, so every ciphertext tensor is uint64 and
x64 must be enabled.  We enable it here, at ``repro.core`` import time —
the LM-framework side (`repro.models`, `repro.launch`) never imports this
package and is dtype-explicit, so enabling x64 is safe process-wide.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.params import TFHEParams, TEST_PARAMS, TEST_PARAMS_4BIT, PAPER_PARAMS  # noqa: E402,F401
from repro.core import torus, fft, decompose, lwe, glwe, ggsw, pbs  # noqa: E402,F401
from repro.core import noise, boolean  # noqa: E402,F401
