"""Batched PBS — the paper's round-robin BSK reuse (§III-B), TPU-native.

Taurus's BRU round-robins 12 ciphertexts through one wide FFT pipeline so
each BSK chunk streamed from HBM is consumed by ALL in-flight ciphertexts.
On TPU the same insight is a BATCH dimension: one blind-rotation iteration
loads bsk_f[i] once and applies it to the whole ciphertext batch via a
single einsum (MXU-shaped, transform-domain).  Arithmetic intensity on the
BSK stream scales linearly with the batch size, exactly the paper's Fig. 13
bandwidth argument.

All functions here are the BATCHED versions of `repro.core.pbs`; the
unbatched path (used as the Morphling-XPU comparison baseline in
benchmarks) simply sets B=1 per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import decompose as dec, fft, ggsw, glwe, lwe
from repro.core.params import TFHEParams

U64 = jnp.uint64


def rotate_batch(cts: jax.Array, rs: jax.Array, N: int) -> jax.Array:
    """Monomial-rotate a batch: cts (B, k+1, N), rs (B,) in [0, 2N)."""
    return jax.vmap(lambda c, r: glwe.rotate(c, r, N))(cts, rs)


def external_product_batch(ggsw_f: jax.Array, glwe_cts: jax.Array,
                           base_log: int, level: int) -> jax.Array:
    """One GGSW (fourier) applied to a BATCH of GLWEs — the key-reuse MAC.

    ggsw_f: (k+1, level, k+1, N/2) complex — loaded ONCE.
    glwe_cts: (B, k+1, N) uint64.
    """
    digits = dec.decompose(glwe_cts, base_log, level)   # (B, k+1, N, level)
    digits = jnp.moveaxis(digits, -1, -2)               # (B, k+1, level, N)
    dig_f = fft.forward(digits)                         # (B, k+1, level, N/2)
    out_f = jnp.einsum("bulf,ulcf->bcf", dig_f, ggsw_f)
    return fft.inverse_torus(out_f)


def blind_rotate_batch(lut_glwes: jax.Array, ms_cts: jax.Array,
                       bsk_f: jax.Array, params: TFHEParams) -> jax.Array:
    """Batched blind rotation.

    lut_glwes: (B, k+1, N); ms_cts: (B, n+1) mod-switched to [0, 2N);
    bsk_f: (n, k+1, level, k+1, N/2) — scanned once, shared across batch.
    """
    N = params.N
    a, b = ms_cts[:, :-1], ms_cts[:, -1]
    acc = rotate_batch(lut_glwes, (2 * N - b) % (2 * N), N)

    def step(acc, inp):
        a_i, bsk_i = inp                                # a_i: (B,)
        rotated = rotate_batch(acc, a_i, N)
        diff = rotated - acc
        acc = acc + external_product_batch(
            bsk_i, diff, params.pbs_base_log, params.pbs_level
        )
        return acc, None

    acc, _ = jax.lax.scan(step, acc, (a.T, bsk_f))
    return acc


def keyswitch_batch(big_cts: jax.Array, ksk: jax.Array,
                    params: TFHEParams) -> jax.Array:
    """(B, k*N+1) -> (B, n+1); a single wraparound int matmul (LPU)."""
    return lwe.keyswitch(big_cts, ksk, params.ks_base_log, params.ks_level)


@functools.partial(jax.jit, static_argnames=("params",))
def pbs_batch(big_cts: jax.Array, lut_polys: jax.Array, bsk_f: jax.Array,
              ksk: jax.Array, params: TFHEParams) -> jax.Array:
    """Batch of full PBS ops: (B, k*N+1) + (B, N) LUTs -> (B, k*N+1)."""
    small = keyswitch_batch(big_cts, ksk, params)
    ms = lwe.mod_switch(small, params.log2_N + 1)
    luts = glwe.trivial(lut_polys, params.k)
    acc = blind_rotate_batch(luts, ms, bsk_f, params)
    return glwe.sample_extract(acc)


@functools.partial(jax.jit, static_argnames=("params",))
def keyswitch_batch_jit(big_cts: jax.Array, ksk: jax.Array,
                        params: TFHEParams) -> jax.Array:
    """Standalone jitted keyswitch stage — the first half of `pbs_batch`,
    split out so the serving scheduler can key-switch a batch of UNIQUE
    ciphertexts once and fan the small-key results out to every
    (ciphertext, table) row that shares them (KS-level partial dedup)."""
    return keyswitch_batch(big_cts, ksk, params)


@functools.partial(jax.jit, static_argnames=("params",))
def pbs_batch_small(small_cts: jax.Array, lut_polys: jax.Array,
                    bsk_f: jax.Array, params: TFHEParams) -> jax.Array:
    """PBS minus the keyswitch: (B, n+1) small-key cts + (B, N) LUTs ->
    (B, k*N+1).  Composing `keyswitch_batch_jit` then this function is
    arithmetically identical to `pbs_batch` — both run the same
    mod-switch / blind-rotate / sample-extract stages on the same
    small-key ciphertexts."""
    ms = lwe.mod_switch(small_cts, params.log2_N + 1)
    luts = glwe.trivial(lut_polys, params.k)
    acc = blind_rotate_batch(luts, ms, bsk_f, params)
    return glwe.sample_extract(acc)


@functools.partial(jax.jit, static_argnames=("params",))
def pbs_unbatched_loop(big_cts: jax.Array, lut_polys: jax.Array,
                       bsk_f: jax.Array, ksk: jax.Array,
                       params: TFHEParams) -> jax.Array:
    """XPU-style baseline: process ciphertexts one at a time (no BSK
    reuse across ciphertexts).  Same math, B× the BSK traffic — used by
    the Table IV comparison benchmark."""
    from repro.core import pbs as pbs_mod

    def one(ct, lut):
        small = lwe.keyswitch(ct, ksk, params.ks_base_log, params.ks_level)
        ms = lwe.mod_switch(small, params.log2_N + 1)
        acc = pbs_mod.blind_rotate(glwe.trivial(lut, params.k), ms, bsk_f, params)
        return glwe.sample_extract(acc)

    return jax.lax.map(lambda args: one(*args), (big_cts, lut_polys))
