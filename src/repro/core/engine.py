"""TaurusEngine: the paper's 4-cluster accelerator as a mesh of devices.

Mapping (paper -> here):
  compute cluster            -> one mesh device on the `data` axis
  12 round-robin cts/cluster -> `batch_per_device` (default 12)
  48-ct scheduling batch     -> engine.batch_size = 12 * n_devices
  global BSK/KSK buffer +NoC -> keys replicated across the mesh
  full synchronization       -> one SPMD program per PBS batch (Obs. 5)

The engine is the execution backend for `repro.compiler` schedules and
the unit benchmarks in `benchmarks/`.

Kernel backends: `kernel_backend="reference"` (default) runs the jax
reference PBS in `repro.core.batch`; `"pallas"` runs the fused Pallas
engine room (`repro.kernels.fused_pbs`) — same KS-first pipeline, but
the FFT / external-product / keyswitch stages execute as Pallas kernels
against a `FusedPbsPack` of resident transform-domain key operands
(built lazily on first `lut_batch`, reused across every round — the
paper's key-reuse strategy).  Both backends are decrypt-identical; the
keyswitch stage is bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import batch as batch_mod, glwe, lwe, torus
from repro.core.params import TFHEParams

U64 = jnp.uint64


class ConfigError(ValueError):
    """An unsupported engine/runtime configuration, rejected at
    construction time (not at first `lut_batch`).

    Supported (kernel_backend, mesh) combinations:

      reference + mesh=None   single-device jax reference PBS
      reference + mesh        SPMD `pbs_batch` sharded over the data axis
      pallas    + mesh=None   fused Pallas engine room, per-device

    pallas + mesh is NOT supported: the fused kernels run per-device.
    The sharded `ServeRuntime` routes around this — a multi-device shard
    requesting the pallas backend gets a single-device engine instead of
    raising here (see `repro.serve.shard.build_shards`)."""


def validate_lut_tables(cts: jax.Array, tables, params: TFHEParams):
    """Normalize/validate per-ciphertext integer LUT tables against a
    batch: broadcast a single (2^width,) table across the batch, reject
    any other count mismatch (it used to slip through as a silent shape
    mismatch inside the jitted PBS).  Shared by `TaurusEngine` and the
    serving `FusedEngineProxy` so their validation cannot drift."""
    tables = jnp.asarray(tables, dtype=U64)
    mod = params.plaintext_modulus
    if tables.ndim == 1:
        tables = jnp.broadcast_to(tables, (cts.shape[0],) + tables.shape)
    if tables.ndim != 2 or tables.shape[-1] != mod:
        raise ValueError(
            f"lut_batch_tables: tables must be (B, {mod}) or ({mod},), "
            f"got {tuple(tables.shape)}")
    if tables.shape[0] != cts.shape[0]:
        raise ValueError(
            f"lut_batch_tables: {cts.shape[0]} ciphertexts but "
            f"{tables.shape[0]} tables — pass one table per ciphertext "
            f"or a single shared table")
    return tables


@dataclasses.dataclass
class TaurusEngine:
    params: TFHEParams
    bsk_f: jax.Array
    ksk: jax.Array
    mesh: Optional[Mesh] = None
    data_axis: str = "data"
    batch_per_device: int = 12  # paper's round-robin depth (Fig. 13b)
    # optional repro.obs.Telemetry; None keeps the hot path untouched.
    # Set explicitly (engine.telemetry = tel) — the serve layer does NOT
    # auto-attach, so a shared engine never pollutes baseline waves.
    telemetry: Optional[object] = None
    # "reference" = jax PBS in repro.core.batch; "pallas" = fused kernel
    # path in repro.kernels.fused_pbs (interpret mode on CPU).
    kernel_backend: str = "reference"

    def __post_init__(self):
        if self.kernel_backend not in ("reference", "pallas"):
            raise ValueError(
                f"kernel_backend must be 'reference' or 'pallas', "
                f"got {self.kernel_backend!r}")
        if self.kernel_backend == "pallas" and self.mesh is not None:
            raise ConfigError(
                "kernel_backend='pallas' + mesh is not a supported engine "
                "configuration — the fused kernels run per-device. "
                "Supported combinations: reference + mesh=None, "
                "reference + mesh, pallas + mesh=None. Use the reference "
                "backend for multi-cluster meshes, or drop the mesh for "
                "the pallas engine room (the sharded ServeRuntime does "
                "the latter automatically).")

    # -- derived -----------------------------------------------------------
    @property
    def key_bytes(self) -> tuple:
        """(bsk_bytes, ksk_bytes) of the evaluation keys as streamed per
        PBS round — the quantity the bandwidth ledger accounts."""
        kb = getattr(self, "_key_bytes", None)
        if kb is None:
            kb = self._key_bytes = (
                int(self.bsk_f.size) * self.bsk_f.dtype.itemsize,
                int(self.ksk.size) * self.ksk.dtype.itemsize)
        return kb

    @property
    def fused_pack(self):
        """The resident `FusedPbsPack` for the pallas backend, built on
        first use and cached — every later `lut_batch` round reuses the
        same transform-domain key arrays (the paper's key reuse)."""
        pack = getattr(self, "_fused_pack", None)
        if pack is None:
            from repro.kernels.fused_pbs import FusedPbsPack
            pack = self._fused_pack = FusedPbsPack.build(
                self.bsk_f, self.ksk, self.params)
        return pack

    @property
    def n_clusters(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.data_axis]

    @property
    def supports_ks_split(self) -> bool:
        """Whether `keyswitch` + `lut_batch_small` may replace a
        `lut_batch` (the serving scheduler's KS-level partial dedup).
        Single-device engines only: the mesh path runs one SPMD program
        per full PBS round and has no sharded half-round entry."""
        return self.mesh is None

    @property
    def batch_size(self) -> int:
        return self.batch_per_device * self.n_clusters

    # -- linear ops (LPU; no bootstrapping, Fig. 2b step 4) -----------------
    def add(self, a, b):
        return lwe.add(a, b)

    def sub(self, a, b):
        return lwe.sub(a, b)

    def scalar_mul(self, a, c):
        return lwe.scalar_mul(a, c)

    def add_plain(self, a, msg):
        return lwe.add_plain(a, torus.encode(jnp.asarray(msg, dtype=U64), self.params.delta))

    def trivial(self, msg) -> jax.Array:
        m = torus.encode(jnp.asarray(msg, dtype=U64), self.params.delta)
        return lwe.trivial(m, self.params.big_n)

    # -- PBS (BRU + LPU pipeline) -------------------------------------------
    def lut_batch(self, cts: jax.Array, lut_polys: jax.Array) -> jax.Array:
        """Apply per-ciphertext LUTs with noise refresh.

        cts: (B, k*N+1); lut_polys: (B, N) torus polys
        (`glwe.make_lut_poly` encodes integer tables).
        Pads B up to a multiple of the cluster count.
        """
        B = cts.shape[0]
        if lut_polys.shape[0] != B:
            raise ValueError(
                f"lut_batch: {B} ciphertexts but {lut_polys.shape[0]} LUT "
                f"polynomials — counts must match per batch row")
        shards = self.n_clusters
        pad = (-B) % shards
        if pad:
            cts = jnp.concatenate([cts, cts[:pad]], axis=0)
            lut_polys = jnp.concatenate([lut_polys, lut_polys[:pad]], axis=0)
        tel = self.telemetry
        span = (tel.span("lut_batch", cat="engine", rows=B, padded=pad)
                if tel is not None else None)
        if span is not None:
            span.__enter__()
        try:
            if self.mesh is None:
                if self.kernel_backend == "pallas":
                    out = self.fused_pack.pbs_batch(cts, lut_polys)
                else:
                    out = batch_mod.pbs_batch(cts, lut_polys, self.bsk_f, self.ksk, self.params)
            else:
                data_sh = NamedSharding(self.mesh, P(self.data_axis))
                repl = NamedSharding(self.mesh, P())
                fn = jax.jit(
                    batch_mod.pbs_batch,
                    static_argnames=("params",),
                    in_shardings=(data_sh, data_sh, repl, repl),
                    out_shardings=data_sh,
                )
                out = fn(cts, lut_polys, self.bsk_f, self.ksk, self.params)
        finally:
            if span is not None:
                span.__exit__(None, None, None)
        if tel is not None:
            tel.counter(f"engine.lut_batches_{self.kernel_backend}").inc()
            tel.counter("engine.lut_batches").inc()
            tel.counter("engine.pbs_rows").inc(B + pad)
            tel.counter("engine.pbs_rows_padded").inc(pad)
            tel.histogram("engine.lut_batch_rows").observe(B)
        return out[:B]

    # -- the split PBS entries (KS-level partial dedup, ISSUE 10) -----------
    def keyswitch(self, big_cts: jax.Array) -> jax.Array:
        """The keyswitch stage alone: (B, k*N+1) big-key cts ->
        (B, n+1) small-key cts.  Bit-identical to the first stage of
        `lut_batch` on both backends (the pallas limb kernel is exact
        mod 2^64), so key-switching each UNIQUE ciphertext once and
        fanning the result out across its tables is decrypt-identical
        to key-switching every row."""
        if not self.supports_ks_split:
            raise ConfigError(
                "keyswitch/lut_batch_small need a single-device engine "
                "(supports_ks_split) — the mesh path dispatches full PBS "
                "rounds only")
        if self.kernel_backend == "pallas":
            return self.fused_pack.keyswitch(big_cts)
        return batch_mod.keyswitch_batch_jit(big_cts, self.ksk, self.params)

    def lut_batch_small(self, small_cts: jax.Array,
                        lut_polys: jax.Array) -> jax.Array:
        """`lut_batch` minus the keyswitch: (B, n+1) small-key cts +
        (B, N) LUT polys -> (B, k*N+1) refreshed big-key cts.
        `keyswitch` then `lut_batch_small` computes exactly what
        `lut_batch` computes."""
        if not self.supports_ks_split:
            raise ConfigError(
                "lut_batch_small needs a single-device engine "
                "(supports_ks_split) — the mesh path dispatches full PBS "
                "rounds only")
        B = small_cts.shape[0]
        if lut_polys.shape[0] != B:
            raise ValueError(
                f"lut_batch_small: {B} ciphertexts but "
                f"{lut_polys.shape[0]} LUT polynomials — counts must "
                f"match per batch row")
        tel = self.telemetry
        span = (tel.span("lut_batch_small", cat="engine", rows=B)
                if tel is not None else None)
        if span is not None:
            span.__enter__()
        try:
            if self.kernel_backend == "pallas":
                out = self.fused_pack.pbs_from_small(small_cts, lut_polys)
            else:
                out = batch_mod.pbs_batch_small(small_cts, lut_polys,
                                                self.bsk_f, self.params)
        finally:
            if span is not None:
                span.__exit__(None, None, None)
        if tel is not None:
            tel.counter(f"engine.lut_batches_{self.kernel_backend}").inc()
            tel.counter("engine.lut_batches").inc()
            tel.counter("engine.pbs_rows").inc(B)
            tel.histogram("engine.lut_batch_rows").observe(B)
        return out

    def lut_batch_tables(self, cts: jax.Array, tables) -> jax.Array:
        """lut_batch from per-ciphertext INTEGER tables (B, 2^width):
        encodes each row as a test polynomial, then one batched PBS.

        A single 1-D table (2^width,) broadcasts across the whole batch;
        any other count mismatch raises (see `validate_lut_tables`)."""
        tables = validate_lut_tables(cts, tables, self.params)
        return self.lut_batch(cts,
                              glwe.make_lut_polys_cached(tables, self.params))

    def lut_batch_xpu(self, cts: jax.Array, lut_polys: jax.Array) -> jax.Array:
        """Morphling-XPU-style baseline: no cross-ciphertext BSK reuse."""
        return batch_mod.pbs_unbatched_loop(
            cts, lut_polys, self.bsk_f, self.ksk, self.params
        )

    @classmethod
    def from_context(cls, ctx, mesh: Optional[Mesh] = None, **kw) -> "TaurusEngine":
        return cls(ctx.params, ctx.bsk_f, ctx.ksk, mesh=mesh, **kw)
