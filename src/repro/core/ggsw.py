"""GGSW ciphertexts, the bootstrapping key, and the external product.

A GGSW ciphertext of a bit s is a ((k+1)*level, k+1, N) stack of GLWE
rows:  row (u, l) = GLWE_sk(0) + s * g_l * e_u   (Z + s*G).

The external product  GGSW ⊡ GLWE -> GLWE  (paper Fig. 4b) is a
vector-matrix product over polynomials in the transform domain.  Its
Pallas incarnation, `repro.kernels.external_product`, runs inside the
engine's fused PBS path (`TaurusEngine(kernel_backend="pallas")` ->
`repro.kernels.fused_pbs`) against a resident plane-layout BSK built
once from `bsk_to_fourier`'s output; this module is the reference path
and the differential-test oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fft, glwe, decompose as dec
from repro.core.params import TFHEParams

U64 = jnp.uint64


def encrypt_bit(key: jax.Array, sk: jax.Array, bit: jax.Array,
                base_log: int, level: int, std: float) -> jax.Array:
    """GGSW of a single bit: (k+1, level, k+1, N) uint64."""
    k, N = sk.shape
    rows_msg = jnp.zeros(((k + 1) * level, N), dtype=U64)
    z = glwe.encrypt(key, sk, rows_msg, std)            # ((k+1)*level, k+1, N)
    z = z.reshape(k + 1, level, k + 1, N)
    g = (U64(1) << (U64(64) - U64(base_log) * jnp.arange(1, level + 1, dtype=U64)))
    add = bit.astype(U64)[..., None] * g                # (level,)
    # row (u, l): component u gets + s*g_l at constant coefficient? NO —
    # the gadget adds s*g_l to the WHOLE u-th polynomial's... only the
    # constant monomial when s is a scalar bit: s interpreted as the
    # constant polynomial s.
    upd = z[jnp.arange(k + 1), :, jnp.arange(k + 1), 0] + add[None, :]
    z = z.at[jnp.arange(k + 1), :, jnp.arange(k + 1), 0].set(upd)
    return z


def bsk_gen(key: jax.Array, lwe_sk: jax.Array, glwe_sk: jax.Array,
            params: TFHEParams) -> jax.Array:
    """Bootstrapping key: n GGSW ciphertexts of the small-LWE key bits.

    Returns (n, k+1, level, k+1, N) uint64.
    """
    n = lwe_sk.shape[0]
    keys = jax.random.split(key, n)
    f = lambda kk, bit: encrypt_bit(
        kk, glwe_sk, bit, params.pbs_base_log, params.pbs_level, params.glwe_std
    )
    return jax.vmap(f)(keys, lwe_sk)


def bsk_to_fourier(bsk: jax.Array) -> jax.Array:
    """Pre-transform the BSK once (complex128 (n, k+1, level, k+1, N/2)).

    This is the stream the paper's BRU reads from HBM; in the batched
    engine it is the reused operand (key-reuse strategy, §III-B).
    """
    return fft.forward(bsk)


def external_product_fourier(ggsw_f: jax.Array, glwe_ct: jax.Array,
                             base_log: int, level: int) -> jax.Array:
    """GGSW (fourier, (k+1, level, k+1, N/2)) ⊡ GLWE ((k+1, N)) -> GLWE.

    Batched over leading axes of `glwe_ct`.
    """
    digits = dec.decompose(glwe_ct, base_log, level)     # (..., k+1, N, level)
    digits = jnp.moveaxis(digits, -1, -2)                # (..., k+1, level, N)
    dig_f = fft.forward(digits)                          # (..., k+1, level, N/2)
    out_f = jnp.einsum("...ulf,ulcf->...cf", dig_f, ggsw_f)
    return fft.inverse_torus(out_f)


def cmux_fourier(ggsw_f: jax.Array, ct0: jax.Array, ct1: jax.Array,
                 base_log: int, level: int) -> jax.Array:
    """CMux: returns ct0 if the GGSW bit is 0 else ct1."""
    return ct0 + external_product_fourier(ggsw_f, ct1 - ct0, base_log, level)
