"""LWE ciphertexts and the LPU-side operations (paper §IV-A).

Ciphertext layout: (..., n+1) uint64 = [a_0 .. a_{n-1}, b].
All functions are batched over leading axes.

Key-switching here is the paper's most expensive LPU op.  The Pallas
uint32-limb version in `repro.kernels.keyswitch` is wired into the PBS
hot path via `TaurusEngine(kernel_backend="pallas")`
(`repro.kernels.fused_pbs.keyswitch_fused`) and is BIT-IDENTICAL to
`keyswitch` below — the limb MAC is exact mod 2^64, pinned by
`tests/test_kernels.py`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import torus, decompose as dec

U64 = jnp.uint64


# --- keys & encryption (client side; the server never holds these) ----------

def keygen(key: jax.Array, n: int) -> jax.Array:
    """Binary LWE secret key, shape (n,) uint64 in {0,1}."""
    return jax.random.bernoulli(key, 0.5, (n,)).astype(U64)


def encrypt(key: jax.Array, sk: jax.Array, msg_torus: jax.Array, std: float) -> jax.Array:
    """Encrypt torus element(s).  msg_torus: (...,) uint64 -> (..., n+1)."""
    n = sk.shape[0]
    shape = msg_torus.shape
    ka, ke = jax.random.split(key)
    a = torus.random_torus(ka, shape + (n,))
    e = torus.gaussian_noise(ke, shape, std)
    b = (a * sk).sum(axis=-1, dtype=U64) + msg_torus + e
    return jnp.concatenate([a, b[..., None]], axis=-1)


def decrypt_phase(sk: jax.Array, ct: jax.Array) -> jax.Array:
    """Return the noisy phase b - <a, s>  (caller rounds/decodes)."""
    a, b = ct[..., :-1], ct[..., -1]
    return b - (a * sk).sum(axis=-1, dtype=U64)


def trivial(msg_torus: jax.Array, n: int) -> jax.Array:
    """Noiseless 'trivial' ciphertext (a=0, b=m) — public constant."""
    z = jnp.zeros(msg_torus.shape + (n,), dtype=U64)
    return jnp.concatenate([z, msg_torus[..., None].astype(U64)], axis=-1)


# --- linear homomorphic ops (LPU VecAdd / VecMult) ---------------------------

def add(ct0: jax.Array, ct1: jax.Array) -> jax.Array:
    return ct0 + ct1  # uint64 wraparound == torus addition


def sub(ct0: jax.Array, ct1: jax.Array) -> jax.Array:
    return ct0 - ct1


def scalar_mul(ct: jax.Array, c) -> jax.Array:
    """Multiply by a plaintext (small) integer."""
    return ct * jnp.asarray(c, dtype=jnp.int64).astype(U64)


def add_plain(ct: jax.Array, msg_torus) -> jax.Array:
    return ct.at[..., -1].add(jnp.asarray(msg_torus, dtype=U64))


# --- modulus switching (paper step B) ----------------------------------------

def mod_switch(ct: jax.Array, log2_2N: int) -> jax.Array:
    """Scale torus values from q=2^64 to Z_{2N}; returns uint64 in [0, 2N)."""
    shift = 64 - log2_2N
    rounded = (ct >> U64(shift - 1)) + U64(1)
    return (rounded >> U64(1)) & U64((1 << log2_2N) - 1)


# --- key switching (paper step A; KS-first order) -----------------------------

def ksk_gen(key: jax.Array, sk_from: jax.Array, sk_to: jax.Array,
            base_log: int, level: int, std: float) -> jax.Array:
    """Key-switching key: (n_from, level, n_to+1) uint64.

    KSK[i, l] = LWE_{sk_to}( sk_from[i] * g_l ),  g_l = 2^(64-(l+1)*base_log)
    """
    n_from = sk_from.shape[0]
    g = (U64(1) << (U64(64) - U64(base_log) * jnp.arange(1, level + 1, dtype=U64)))
    msgs = sk_from[:, None] * g[None, :]           # (n_from, level)
    return encrypt(key, sk_to, msgs, std)


def keyswitch(ct: jax.Array, ksk: jax.Array, base_log: int, level: int) -> jax.Array:
    """Switch (..., n_from+1) under sk_from to (..., n_to+1) under sk_to."""
    n_from = ksk.shape[0]
    n_to = ksk.shape[-1] - 1
    a, b = ct[..., :-1], ct[..., -1]
    digits = dec.decompose(a, base_log, level)      # (..., n_from, level) int64
    # out = (0, b) - sum_{i,l} digit * KSK[i,l]
    acc = jnp.einsum(
        "...il,ilj->...j",
        digits.astype(U64), ksk,
    ).astype(U64)  # wraparound dot; digit cast is two's-complement-correct
    out = -acc
    out = out.at[..., -1].add(b)
    return out
