"""The two scenario runners.

`run_scenario`       paces a Scenario's arrivals onto the WALL clock and
                     drives a real `ServeRuntime` — every request is a
                     real compiled graph over real big-key ciphertexts,
                     so its report carries measured serving latencies.

`simulate_scenario`  replays the SAME Scenario in virtual time: a
                     discrete-event loop over a K-slot service model
                     with a seeded per-request service-time draw.  No
                     crypto, no threads, no wall clock — two runs of the
                     same (scenario, max_inflight) produce reports that
                     are identical field for field, which is the
                     regression contract `tests/test_sim.py` and the
                     benchmark's determinism check pin.

Both feed the same metric names (`serve.request_latency_s`,
`serve.queue_wait_s`, `serve.admitted/completed/abandoned`) into a
`repro.obs.Telemetry`, snapshot it at phase boundaries, and hand the
`Snapshot.diff` windows plus client outcome tallies to `slo.evaluate` —
the SLO layer cannot tell the runners apart.

Client life cycle (both runners): a request that completes within its
deadline is DONE; completes late is TIMEOUT (the server had started it,
`RequestHandle.abandon()` refused); still queued at its deadline is
ABANDONED (`abandon()` removed it); rejected or errored is FAILED.  A
`drain=False` scenario ends with `ServeRuntime.close(drain=False)` —
the fail-fast shutdown — and everything still queued goes ABANDONED via
`RuntimeClosedError`.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
import threading
import time
from collections import deque
from typing import Optional

import jax

from repro.core.integer import IntegerContext
from repro.obs import Telemetry
from repro.serve.runtime import (AdmissionError, RequestAbandonedError,
                                 RuntimeClosedError, ServeRuntime,
                                 SubmitValidationError)
from repro.sim import clients as C
from repro.sim.arrivals import arrival_plan, seeded_rng
from repro.sim.slo import LATENCY_HIST, QUEUE_WAIT_HIST, evaluate


@dataclasses.dataclass
class SimRequest:
    """One planned request: the draw (workload + plaintext values) plus
    its life-cycle record."""
    req_id: int
    client_id: str
    workload: object
    values: list
    record: C.ClientRequest
    enc: Optional[list] = None          # real runner: pre-encrypted inputs
    started: bool = False               # virtual runner: service began


@dataclasses.dataclass
class ScenarioRun:
    """A runner's output: the SLO report plus the raw per-request
    records (state-machine audit trail)."""
    report: dict
    records: list


def default_service_model(workload, rng: random.Random) -> float:
    """Virtual service time: the workload's mean prior with ±30%
    uniform jitter from the request's own seeded stream."""
    return workload.mean_service_s * (0.7 + 0.6 * rng.random())


# --------------------------------------------------------------------------
# shared plan/draw helpers (same seeds ⇒ same traffic in both runners)
# --------------------------------------------------------------------------

def _open_loop_requests(scenario) -> list:
    plan = arrival_plan(scenario.arrival, scenario.population,
                        scenario.duration_s, scenario.seed)
    rng = seeded_rng("draw", scenario.seed)
    reqs = []
    for i, (t, cidx) in enumerate(plan):
        w = scenario.mix.sample(rng)
        reqs.append(SimRequest(
            i, f"client-{cidx}", w, w.sample_values(rng),
            C.ClientRequest(f"client-{cidx}", w.name, t,
                            t + scenario.deadline_s)))
    return reqs


def _client_rng(scenario, cidx: int) -> random.Random:
    return seeded_rng("client", scenario.seed, cidx)


def _draw_closed(scenario, cidx: int, rng: random.Random, t: float,
                 req_id: int) -> SimRequest:
    w = scenario.mix.sample(rng)
    return SimRequest(req_id, f"client-{cidx}", w, w.sample_values(rng),
                      C.ClientRequest(f"client-{cidx}", w.name, t,
                                      t + scenario.deadline_s))


def _phase_windows(scenario, snaps: list, records: list) -> tuple:
    """Diff the boundary snapshots into per-phase windows and attribute
    each terminal record to the phase its finish time falls in (the
    last phase absorbs post-cutoff drain spillover)."""
    phase_list = scenario.phase_list()
    ends = [end for _, end in phase_list]

    def phase_idx(t: float) -> int:
        for i, end in enumerate(ends):
            if t <= end:
                return i
        return len(ends) - 1

    recs = [r.record for r in records]
    by_phase: list = [[] for _ in ends]
    for rec in recs:
        if rec.finish_s is not None:
            by_phase[phase_idx(rec.finish_s)].append(rec)
    windows = []
    for i, (phase, _) in enumerate(phase_list):
        delta = snaps[i + 1].diff(snaps[i])
        windows.append((phase.name, phase.duration_s, delta,
                        C.outcome_counts(by_phase[i])))
    overall_delta = snaps[-1].diff(snaps[0])
    return windows, overall_delta, C.outcome_counts(recs)


# --------------------------------------------------------------------------
# deterministic virtual-time runner
# --------------------------------------------------------------------------

def simulate_scenario(scenario, *, max_inflight: int = 4,
                      service_model=default_service_model) -> ScenarioRun:
    """Discrete-event replay on the virtual clock.

    Service is a K-slot pool (K = max_inflight, mirroring the runtime's
    worker count) over a FIFO queue; each request's service time comes
    from `service_model(workload, rng)` with an rng seeded by
    (scenario.seed, request id) — so the full report is a pure function
    of (scenario, max_inflight, service_model)."""
    tel = Telemetry()
    h_lat = tel.histogram(LATENCY_HIST)
    h_qw = tel.histogram(QUEUE_WAIT_HIST)
    c_adm = tel.counter("serve.admitted")
    c_done = tel.counter("serve.completed")
    c_aband = tel.counter("serve.abandoned")

    events: list = []
    seq = itertools.count()
    req_ids = itertools.count()
    records: list = []
    queue: deque = deque()
    free = max_inflight
    closed = False
    clrngs = {i: _client_rng(scenario, i)
              for i in range(scenario.population)}

    def push(t: float, kind: str, payload) -> None:
        heapq.heappush(events, (t, next(seq), kind, payload))

    if scenario.arrival.open_loop:
        for r in _open_loop_requests(scenario):
            push(r.record.arrival_s, "arrive", r)
    else:
        for cidx in range(scenario.population):
            t0 = scenario.arrival.first_arrival(cidx, clrngs[cidx])
            push(t0, "arrive", _draw_closed(scenario, cidx, clrngs[cidx],
                                            t0, next(req_ids)))

    def start(r: SimRequest, t: float) -> None:
        nonlocal free
        free -= 1
        r.started = True
        h_qw.observe(t - r.record.arrival_s)
        rng = seeded_rng("svc", scenario.seed, r.req_id)
        push(t + service_model(r.workload, rng), "finish", r)

    def next_closed(r: SimRequest, t: float) -> None:
        """Closed loop: after a terminal state, think, then rearrive."""
        if scenario.arrival.open_loop or closed:
            return
        cidx = int(r.client_id.split("-")[-1])
        t_next = t + scenario.arrival.think(clrngs[cidx])
        if t_next < scenario.duration_s:
            push(t_next, "arrive",
                 _draw_closed(scenario, cidx, clrngs[cidx], t_next,
                              next(req_ids)))

    snaps = [tel.snapshot()]
    ends = [end for _, end in scenario.phase_list()]
    interior = ends[:-1]
    bidx = 0

    while events:
        t, _, kind, r = heapq.heappop(events)
        while bidx < len(interior) and t > interior[bidx]:
            snaps.append(tel.snapshot())
            bidx += 1
        if not closed and not scenario.drain and t > scenario.duration_s:
            # fail-fast close at the cutoff: queued work is dropped with
            # RuntimeClosedError; in-service requests run to completion
            closed = True
            while queue:
                q = queue.popleft()
                c_aband.inc()
                q.record.transition(C.ABANDONED, scenario.duration_s)
        if kind == "arrive":
            records.append(r)
            r.record.transition(C.SUBMIT)
            if closed:
                r.record.transition(C.ABANDONED, t)
                c_aband.inc()
                continue
            c_adm.inc()
            r.record.transition(C.WAITING)
            push(r.record.deadline_s, "deadline", r)
            if free > 0:
                start(r, t)
            else:
                queue.append(r)
        elif kind == "deadline":
            if r.record.state == C.WAITING and not r.started:
                queue.remove(r)
                c_aband.inc()
                r.record.transition(C.ABANDONED, t)
                next_closed(r, t)
        elif kind == "finish":
            free += 1
            h_lat.observe(t - r.record.arrival_s)
            c_done.inc()
            late = t > r.record.deadline_s
            r.record.transition(C.TIMEOUT if late else C.DONE, t)
            next_closed(r, t)
            while free > 0 and queue:
                start(queue.popleft(), t)

    while bidx <= len(interior):             # remaining boundaries + final
        snaps.append(tel.snapshot())
        bidx += 1

    windows, overall_delta, overall = _phase_windows(scenario, snaps,
                                                     records)
    report = evaluate(scenario, windows, overall_delta, overall,
                      runner="virtual")
    report["max_inflight"] = max_inflight
    return ScenarioRun(report, records)


# --------------------------------------------------------------------------
# real wall-clock runner
# --------------------------------------------------------------------------

class _RealDriver:
    """Shared machinery of the open-/closed-loop wall-clock drivers."""

    def __init__(self, scenario, ctx, engine, *, max_inflight: int,
                 time_scale: float, validate: bool, fused: bool,
                 shards: int = 1, elastic=None):
        self.scenario = scenario
        self.time_scale = time_scale
        self.validate = validate
        self.tel = Telemetry()
        self.rt = ServeRuntime(ctx, engine, max_inflight=max_inflight,
                               fused=fused, shards=shards, elastic=elastic,
                               telemetry=self.tel)
        self.ic = IntegerContext.create(ctx, self.rt.engine)
        self.records: list = []
        self._rec_lock = threading.Lock()
        self.t0: float = 0.0

    # virtual <-> wall clock
    def vnow(self) -> float:
        return (time.perf_counter() - self.t0) / self.time_scale

    def sleep_until(self, t_virtual: float) -> None:
        delay = self.t0 + t_virtual * self.time_scale - time.perf_counter()
        if delay > 0:
            time.sleep(delay)

    def encrypt(self, r: SimRequest) -> None:
        key = jax.random.key(self.scenario.seed * 100003 + r.req_id)
        r.enc = r.workload.encrypt(self.ic, key, r.values)

    def submit(self, r: SimRequest):
        """SUBMIT transition + runtime admission; returns the handle or
        None after recording a terminal submit-path outcome."""
        r.record.transition(C.SUBMIT)
        with self._rec_lock:
            self.records.append(r)
        graph, _, _ = r.workload.build()
        try:
            h = self.rt.submit(graph, r.enc, client_id=r.client_id)
        except RuntimeClosedError:
            r.record.transition(C.ABANDONED, self.vnow())
            return None
        except (AdmissionError, SubmitValidationError):
            r.record.transition(C.FAILED, self.vnow())
            return None
        r.record.transition(C.WAITING)
        return h

    def await_outcome(self, r: SimRequest, handle) -> None:
        """The client side of one in-flight request: wait to the
        deadline, abandon if still queued, otherwise ride it out."""
        wall_deadline = self.t0 + r.record.deadline_s * self.time_scale
        outcome = None
        try:
            handle.wait(timeout=max(0.0,
                                    wall_deadline - time.perf_counter()))
            outcome = C.DONE
        except TimeoutError:
            if handle.abandon():
                outcome = C.ABANDONED
            else:
                try:                         # already executing: ride out
                    handle.wait()
                    outcome = C.TIMEOUT
                except (RequestAbandonedError, RuntimeClosedError):
                    outcome = C.ABANDONED
                except Exception:            # noqa: BLE001 — server error
                    outcome = C.FAILED
        except (RequestAbandonedError, RuntimeClosedError):
            outcome = C.ABANDONED
        except Exception:                    # noqa: BLE001 — server error
            outcome = C.FAILED
        if outcome == C.DONE and self.validate \
                and r.workload.oracle is not None:
            got = r.workload.decrypt(self.ic, handle.outputs())
            r.record.ok_payload = (got == r.workload.oracle(r.values))
        r.record.transition(outcome, self.vnow())


def run_scenario(scenario, ctx, engine=None, *, max_inflight: int = 4,
                 time_scale: float = 1.0, validate: bool = False,
                 fused: bool = True, shards: int = 1,
                 elastic=None) -> ScenarioRun:
    """Drive the scenario against a real `ServeRuntime` on the wall
    clock (virtual seconds × `time_scale`).  Open-loop traffic is drawn
    and pre-encrypted before the clock starts, so the measured window
    contains serving work only; closed-loop clients encrypt inline (the
    client's own think-time work).  validate=True decrypts every DONE
    request and checks it against the workload's integer oracle.
    `shards`/`elastic` thread straight to `ServeRuntime` — the scenario
    plays against a sharded router exactly as production traffic would
    (`max_inflight` then bounds each shard, not the whole runtime)."""
    d = _RealDriver(scenario, ctx, engine, max_inflight=max_inflight,
                    time_scale=time_scale, validate=validate, fused=fused,
                    shards=shards, elastic=elastic)
    try:
        return _run_real(d, scenario)
    finally:
        d.rt.close()


def _run_real(d: _RealDriver, scenario) -> ScenarioRun:
    waiters: list = []
    interior = [end for _, end in scenario.phase_list()][:-1]
    snaps: list = []

    def spawn_waiter(r: SimRequest, handle) -> None:
        t = threading.Thread(target=d.await_outcome, args=(r, handle),
                             daemon=True)
        t.start()
        waiters.append(t)

    if scenario.arrival.open_loop:
        reqs = _open_loop_requests(scenario)
        for r in reqs:
            d.encrypt(r)
        client_threads: list = []
    else:
        reqs = []

        def client_loop(cidx: int) -> None:
            rng = _client_rng(scenario, cidx)
            t_next = scenario.arrival.first_arrival(cidx, rng)
            n = 0
            while t_next < scenario.duration_s:
                d.sleep_until(t_next)
                r = _draw_closed(scenario, cidx, rng, d.vnow(),
                                 cidx * 1_000_000 + n)
                n += 1
                d.encrypt(r)
                va = d.vnow()
                r.record.arrival_s = va
                r.record.deadline_s = va + scenario.deadline_s
                h = d.submit(r)
                if h is not None:
                    d.await_outcome(r, h)    # one outstanding per client
                t_next = d.vnow() + scenario.arrival.think(rng)

        client_threads = [threading.Thread(target=client_loop, args=(i,),
                                           daemon=True)
                          for i in range(scenario.population)]

    d.t0 = time.perf_counter()
    snaps.append(d.rt.metrics())
    bidx = 0

    def snap_boundaries_until(t_virtual: float) -> None:
        nonlocal bidx
        while bidx < len(interior) and interior[bidx] <= t_virtual:
            d.sleep_until(interior[bidx])
            snaps.append(d.rt.metrics())
            bidx += 1

    if scenario.arrival.open_loop:
        for r in reqs:
            snap_boundaries_until(r.record.arrival_s)
            d.sleep_until(r.record.arrival_s)
            h = d.submit(r)
            if h is not None:
                spawn_waiter(r, h)
    else:
        for t in client_threads:
            t.start()

    snap_boundaries_until(scenario.duration_s)
    d.sleep_until(scenario.duration_s)

    if scenario.drain:
        d.rt.drain()
    else:
        d.rt.close(drain=False)              # fail-fast: queued ⇒ closed
    for t in client_threads:
        t.join()
    for t in waiters:
        t.join()
    snaps.append(d.rt.metrics())

    windows, overall_delta, overall = _phase_windows(scenario, snaps,
                                                     d.records)
    report = evaluate(scenario, windows, overall_delta, overall,
                      runner="real")
    report["max_inflight"] = d.rt.max_inflight
    report["shards"] = d.rt.n_shards
    report["time_scale"] = d.time_scale
    return ScenarioRun(report, d.records)
