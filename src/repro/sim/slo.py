"""SLO targets + the evaluator over metric-snapshot deltas.

`evaluate` is runner-agnostic: it consumes, per phase, a
`Snapshot.diff` delta (exact interval quantiles of
`serve.request_latency_s` / `serve.queue_wait_s`) and the client-side
outcome tally (`clients.outcome_counts`), and emits a JSON-able report:

  * measured columns per phase — p50_s, p99_s, queue_wait_p99_s,
    abandon_rate (1 − done/attempts: timeouts, abandons and failures
    all count against the operator), goodput_rps (deadline-met
    completions per virtual second);
  * one check per configured target, and a phase / scenario verdict.

Latency targets on a phase with zero completed requests pass vacuously
(value None) — the abandon-rate and goodput checks are the ones that
catch a runtime serving nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.sim import clients

LATENCY_HIST = "serve.request_latency_s"
QUEUE_WAIT_HIST = "serve.queue_wait_s"


@dataclasses.dataclass(frozen=True)
class SLOTargets:
    """Operator promises; None disables a check.  Latency/abandon are
    upper bounds, goodput a lower bound."""
    p50_s: Optional[float] = None
    p99_s: Optional[float] = None
    queue_wait_p99_s: Optional[float] = None
    abandon_rate: Optional[float] = None
    goodput_rps: Optional[float] = None


def _hist_q(delta, name: str, q: str) -> Optional[float]:
    h = delta.get("histograms", {}).get(name)
    return None if h is None else h.get(q)


def measures(delta, outcomes: dict, duration_s: float) -> dict:
    """The measured SLO columns for one window."""
    attempts = outcomes.get("attempts", 0)
    done = outcomes.get(clients.DONE, 0)
    rate = 0.0 if attempts == 0 else 1.0 - done / attempts
    return {
        "requests": attempts,
        "done": done,
        "timeout": outcomes.get(clients.TIMEOUT, 0),
        "abandoned": outcomes.get(clients.ABANDONED, 0),
        "failed": outcomes.get(clients.FAILED, 0),
        "p50_s": _hist_q(delta, LATENCY_HIST, "p50"),
        "p99_s": _hist_q(delta, LATENCY_HIST, "p99"),
        "queue_wait_p99_s": _hist_q(delta, QUEUE_WAIT_HIST, "p99"),
        "abandon_rate": round(rate, 6),
        "goodput_rps": round(done / duration_s, 6) if duration_s > 0
        else 0.0,
    }


def _checks(slo: SLOTargets, m: dict) -> list:
    out = []

    def check(metric, limit, value, kind):
        if limit is None:
            return
        if value is None:                     # no samples: vacuous pass
            ok = True
        elif kind == "max":
            ok = value <= limit
        else:
            ok = value >= limit
        out.append({"metric": metric, "kind": kind, "limit": limit,
                    "value": value, "ok": ok})

    check("p50_s", slo.p50_s, m["p50_s"], "max")
    check("p99_s", slo.p99_s, m["p99_s"], "max")
    check("queue_wait_p99_s", slo.queue_wait_p99_s,
          m["queue_wait_p99_s"], "max")
    check("abandon_rate", slo.abandon_rate, m["abandon_rate"], "max")
    check("goodput_rps", slo.goodput_rps, m["goodput_rps"], "min")
    return out


def evaluate(scenario, phase_windows: list, overall_delta,
             overall_outcomes: dict, runner: str) -> dict:
    """Build the scenario report.

    phase_windows: [(phase_name, duration_s, delta_snapshot, outcomes)]
    in order; overall_* cover the whole run (including post-cutoff
    drain), so the headline columns never lose spillover completions.
    """
    phases = []
    for name, dur, delta, outcomes in phase_windows:
        m = measures(delta, outcomes, dur)
        checks = _checks(scenario.slo, m)
        phases.append({"phase": name, "duration_s": dur, **m,
                       "checks": checks,
                       "ok": all(c["ok"] for c in checks)})
    overall = measures(overall_delta, overall_outcomes,
                       scenario.duration_s)
    overall_checks = _checks(scenario.slo, overall)
    ok = all(p["ok"] for p in phases) and all(
        c["ok"] for c in overall_checks)
    return {
        "scenario": scenario.name,
        "runner": runner,
        "seed": scenario.seed,
        "duration_s": scenario.duration_s,
        "population": scenario.population,
        "deadline_s": scenario.deadline_s,
        "ok": ok,
        "expect_ok": scenario.expect_ok,
        "as_expected": ok == scenario.expect_ok,
        "overall": {**overall, "checks": overall_checks},
        "phases": phases,
    }
