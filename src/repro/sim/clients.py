"""Client-population state machine for the traffic simulator.

Every request a simulated client makes walks one life cycle::

    IDLE ──▶ SUBMIT ──▶ WAITING ──▶ DONE        completed within deadline
                │           ├─────▶ TIMEOUT     completed, but past its
                │           │                   deadline (server had
                │           │                   already started it, so
                │           │                   `abandon()` returned False)
                │           └─────▶ ABANDONED   client walked away while
                │                               the request was queued
                │                               (deadline expiry, runtime
                │                               shutdown) — no result
                └─────────────────▶ FAILED      rejected at submit or the
                                                server errored

Transitions are validated (`ClientRequest.transition` raises on an edge
not in `_EDGES`), which is what the state-machine coverage tests pin.
Both runners — the real wall-clock driver and the deterministic
virtual-time simulator — produce these records, so SLO evaluation is
runner-agnostic: abandon rate and goodput come from outcome counts,
latency quantiles from the metrics snapshots.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# life-cycle states
IDLE = "IDLE"
SUBMIT = "SUBMIT"
WAITING = "WAITING"
DONE = "DONE"
TIMEOUT = "TIMEOUT"
ABANDONED = "ABANDONED"
FAILED = "FAILED"

TERMINAL = frozenset({DONE, TIMEOUT, ABANDONED, FAILED})

_EDGES = {
    IDLE: frozenset({SUBMIT}),
    SUBMIT: frozenset({WAITING, FAILED, ABANDONED}),
    WAITING: frozenset({DONE, TIMEOUT, ABANDONED, FAILED}),
}


@dataclasses.dataclass
class ClientRequest:
    """One request's life-cycle record on the VIRTUAL clock.

    `arrival_s` is when the client decided to submit; `deadline_s` the
    absolute virtual time after which the client no longer wants the
    answer; `finish_s` when it reached a terminal state.  `ok_payload`
    is the decrypted-result check (None when validation was skipped)."""
    client_id: str
    workload: str
    arrival_s: float
    deadline_s: float
    state: str = IDLE
    finish_s: Optional[float] = None
    ok_payload: Optional[bool] = None

    def transition(self, new_state: str, at_s: Optional[float] = None):
        allowed = _EDGES.get(self.state, frozenset())
        if new_state not in allowed:
            raise ValueError(
                f"invalid client transition {self.state} -> {new_state} "
                f"(allowed: {sorted(allowed) or 'none — terminal state'})")
        self.state = new_state
        if new_state in TERMINAL:
            self.finish_s = at_s
        return self

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s


def outcome_counts(requests: list) -> dict:
    """Terminal-state tally for a batch of ClientRequests.  `attempts`
    counts every request that reached a terminal state; non-terminal
    records (still in flight when the scenario was cut off) are ignored
    — the runners drain before tallying, so normally there are none."""
    counts = {DONE: 0, TIMEOUT: 0, ABANDONED: 0, FAILED: 0}
    for r in requests:
        if r.state in counts:
            counts[r.state] += 1
    counts["attempts"] = sum(counts[s] for s in TERMINAL)
    return counts
