"""Seeded arrival processes on a virtual clock.

Every process is a declarative spec; `schedule(duration_s, seed)` expands
an OPEN-LOOP spec into the sorted list of virtual arrival times — a pure
function of (spec, duration, seed), so the same scenario always replays
the same traffic (the simulator's determinism contract pins this in
`tests/test_sim.py`).

  Poisson     constant-rate open loop: exponential inter-arrival gaps.
  MMPP        Markov-modulated Poisson: the rate steps through declared
              (rate, duration) segments — bursts and ramps — cycling
              until the scenario ends.
  ClosedLoop  think-time pacing: each client submits, waits for its
              result (or abandons at its deadline), thinks, repeats.
              No global pre-schedule exists — arrivals depend on service
              times — so the runners drive it per client; `think(rng)`
              samples the gap.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random


def seeded_rng(*parts) -> random.Random:
    """A `random.Random` seeded from a stable digest of `parts`.

    `random.Random(tuple)` seeds via `hash()`, which Python randomizes
    per process for strings — reports would silently differ across
    processes.  Hashing the repr through sha256 keeps every stream a
    pure function of its labels, which the determinism contract needs."""
    digest = hashlib.sha256(repr(parts).encode()).digest()
    return random.Random(int.from_bytes(digest[:8], "big"))


@dataclasses.dataclass(frozen=True)
class Poisson:
    """Open-loop Poisson arrivals at `rate` requests per virtual second."""
    rate: float

    open_loop = True

    def schedule(self, duration_s: float, seed: int) -> list:
        rng = seeded_rng("poisson", seed, self.rate)
        out, t = [], 0.0
        while True:
            t += rng.expovariate(self.rate)
            if t >= duration_s:
                return out
            out.append(t)


@dataclasses.dataclass(frozen=True)
class MMPP:
    """Markov-modulated Poisson process: rate steps through `segments`
    — a tuple of (rate_rps, duration_s) — cycling until the scenario
    duration is exhausted.  A two-segment (calm, burst) spec is the
    classic bursty workload; a longer ladder is a ramp."""
    segments: tuple                    # ((rate, duration), ...)

    open_loop = True

    def schedule(self, duration_s: float, seed: int) -> list:
        rng = seeded_rng("mmpp", seed, self.segments)
        out, t, seg = [], 0.0, 0
        seg_end = self.segments[0][1]
        while t < duration_s:
            rate = self.segments[seg % len(self.segments)][0]
            gap = rng.expovariate(rate) if rate > 0 else float("inf")
            if t + gap >= seg_end:
                # no arrival before the segment flips: jump to the next
                # rate segment and resample from there
                t = seg_end
                seg += 1
                seg_end += self.segments[seg % len(self.segments)][1]
                continue
            t += gap
            if t >= duration_s:
                break
            out.append(t)
        return out


@dataclasses.dataclass(frozen=True)
class ClosedLoop:
    """Closed-loop think-time pacing: each client owns one outstanding
    request at a time and waits `think` seconds between them.
    `initial_stagger` spreads the population's first submissions so the
    opening instant is not a synchronized thundering herd."""
    think_s: float
    initial_stagger_s: float = 0.5

    open_loop = False

    def think(self, rng: random.Random) -> float:
        return rng.expovariate(1.0 / self.think_s) if self.think_s > 0 \
            else 0.0

    def first_arrival(self, client_idx: int, rng: random.Random) -> float:
        return rng.uniform(0.0, self.initial_stagger_s) \
            if self.initial_stagger_s > 0 else 0.0


def arrival_plan(process, population: int, duration_s: float,
                 seed: int) -> list:
    """Expand an OPEN-LOOP process into [(virtual_time, client_idx)],
    clients assigned round-robin so every simulated tenant participates.
    Closed-loop processes have no global plan (arrivals depend on
    completions) — the runners pace those per client."""
    assert process.open_loop, "closed-loop arrivals are paced per client"
    times = process.schedule(duration_s, seed)
    return [(t, i % population) for i, t in enumerate(times)]
