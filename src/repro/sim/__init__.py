"""repro.sim — stochastic traffic simulation + SLO harness for the
FHE serving stack (PR 8).

Capacity questions ("what does p99 do when a burst doubles the arrival
rate?", "how many tenants before clients start abandoning?") need
repeatable traffic, not ad-hoc scripts.  This package provides:

  arrivals    seeded arrival processes on a virtual clock — Poisson,
              bursty/ramp MMPP, closed-loop think-time.
  clients     the client-population state machine (IDLE → SUBMIT →
              WAITING → DONE / TIMEOUT / ABANDONED / FAILED) with
              validated transitions and per-request deadlines.
  workloads   weighted mixes over the existing program builders: radix
              arithmetic, const-op analytics (zero PBS), radix_linear
              queries, the GPT-2 block.
  scenario    declarative `Scenario` (population, phases, arrival
              process, workload mix, SLO targets) + `standard_suite`.
  slo         `SLOTargets` and the runner-agnostic evaluator over
              `Snapshot.diff` metric windows.
  runner      `run_scenario` (real ciphertexts on a real `ServeRuntime`,
              wall clock) and `simulate_scenario` (deterministic
              discrete-event replay in virtual time — same scenario,
              same seed ⇒ identical report, field for field).

Example::

    from repro.sim import (Poisson, Scenario, SLOTargets, WorkloadMix,
                           simulate_scenario)
    mix = WorkloadMix.of({"radix_add": 1.0}, bits=8, msg_bits=2)
    sc = Scenario("steady", Poisson(2.0), mix, duration_s=30.0,
                  deadline_s=6.0, slo=SLOTargets(p99_s=5.0))
    run = simulate_scenario(sc, max_inflight=4)
    assert run.report["ok"]

`benchmarks/sim_slo.py` runs `standard_suite` end-to-end on real
ciphertexts and writes the SLO report to `benchmarks/BENCH_sim.json`.
"""
from repro.sim.arrivals import (ClosedLoop, MMPP, Poisson, arrival_plan,
                                seeded_rng)
from repro.sim.clients import (ABANDONED, DONE, FAILED, IDLE, SUBMIT,
                               TIMEOUT, WAITING, ClientRequest,
                               outcome_counts)
from repro.sim.runner import (ScenarioRun, SimRequest,
                              default_service_model, run_scenario,
                              simulate_scenario)
from repro.sim.scenario import Phase, Scenario, standard_suite
from repro.sim.slo import SLOTargets, evaluate, measures
from repro.sim.workloads import REGISTRY, Workload, WorkloadMix

__all__ = [
    "ABANDONED", "DONE", "FAILED", "IDLE", "SUBMIT", "TIMEOUT", "WAITING",
    "ClientRequest", "ClosedLoop", "MMPP", "Phase", "Poisson", "REGISTRY",
    "Scenario", "ScenarioRun", "SimRequest", "SLOTargets", "Workload",
    "WorkloadMix", "arrival_plan", "default_service_model", "evaluate",
    "measures", "outcome_counts", "run_scenario", "seeded_rng",
    "simulate_scenario", "standard_suite",
]
