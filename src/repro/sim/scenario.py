"""Declarative traffic scenarios + the standard SLO suite.

A `Scenario` is pure data: who arrives (arrival process over a client
population), what they run (a `WorkloadMix`), how patient they are
(`deadline_s`), for how long (phases on the virtual clock), and what
the operator promised (`SLOTargets`).  Both runners consume the same
object — `run_scenario` paces it onto a real `ServeRuntime` on the wall
clock, `simulate_scenario` replays it deterministically in virtual time
— and `slo.evaluate` turns either run's per-phase metric deltas into
the pass/fail report.
"""
from __future__ import annotations

import dataclasses

from repro.sim.arrivals import ClosedLoop, MMPP, Poisson
from repro.sim.slo import SLOTargets
from repro.sim.workloads import WorkloadMix


@dataclasses.dataclass(frozen=True)
class Phase:
    """One evaluation window: metrics snapshots are taken at phase
    boundaries and diffed, so each phase gets its own SLO verdict."""
    name: str
    duration_s: float


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    arrival: object                     # Poisson | MMPP | ClosedLoop
    mix: WorkloadMix
    duration_s: float
    population: int = 4
    deadline_s: float = 10.0            # per-request patience (relative)
    slo: SLOTargets = dataclasses.field(default_factory=SLOTargets)
    seed: int = 0
    phases: tuple = ()                  # default: one phase, full duration
    drain: bool = True                  # False → close(drain=False) at cut
    expect_ok: bool = True              # documented verdict (overload=False)

    def __post_init__(self):
        if self.phases:
            total = sum(p.duration_s for p in self.phases)
            if abs(total - self.duration_s) > 1e-9:
                raise ValueError(
                    f"scenario {self.name!r}: phase durations sum to "
                    f"{total}, duration_s is {self.duration_s}")

    def phase_list(self) -> list:
        """[(phase, absolute end time)] covering the full duration."""
        phases = self.phases or (Phase("all", self.duration_s),)
        out, t = [], 0.0
        for p in phases:
            t += p.duration_s
            out.append((p, t))
        return out


def standard_suite(capacity_rps: float = 1.0, *, bits: int = 8,
                   msg_bits: int = 2, duration_s: float = 18.0,
                   deadline_s: float = 12.0, seed: int = 7) -> list:
    """The four-scenario SLO suite `benchmarks/sim_slo.py` runs.

    `capacity_rps` anchors arrival rates to the serving capacity of the
    machine under test (measure one request, divide max_inflight by its
    latency).  Scenarios:

      steady        Poisson at 60% capacity — the SLO-meeting baseline.
      burst         MMPP calm → 2.2x-capacity burst → recovery, one SLO
                    verdict per phase (the burst phase eats the queue).
      overload      Poisson at 3x capacity with tight deadlines: clients
                    abandon queued work, and the scenario ends with
                    `close(drain=False)` — the fail-fast shutdown path.
                    Documented as expect_ok=False: its report SHOULD
                    show the SLO breach.
      mixed_tenant  six tenants interleaving cheap const-op analytics
                    (zero PBS) with PBS-heavy radix arithmetic and
                    linear queries on one runtime.
      closed_loop   think-time pacing: population-bound concurrency,
                    the classic interactive-tenant shape.
    """
    kw = dict(bits=bits, msg_bits=msg_bits)
    arith = WorkloadMix.of({"radix_add": 2.0, "radix_mul": 1.0}, **kw)
    mixed = WorkloadMix.of({"analytics_const": 3.0, "radix_add": 2.0,
                            "radix_mul": 1.0, "analytics_linear": 1.0},
                           **kw)
    cap = capacity_rps
    lenient = SLOTargets(p99_s=deadline_s, queue_wait_p99_s=deadline_s,
                         abandon_rate=0.05, goodput_rps=0.25 * cap)
    third = duration_s / 3.0
    return [
        Scenario("steady", Poisson(0.6 * cap), arith, duration_s,
                 deadline_s=deadline_s, slo=lenient, seed=seed),
        Scenario("burst",
                 MMPP(((0.3 * cap, third), (2.2 * cap, third),
                       (0.3 * cap, third))),
                 arith, duration_s, deadline_s=deadline_s,
                 # the burst phase is SUPPOSED to spike latency (clients
                 # ride out their deadline while the queue drains), so
                 # the latency bound gets 2x headroom — the collapse
                 # detector here is the abandon rate
                 slo=SLOTargets(p99_s=2.0 * deadline_s,
                                abandon_rate=0.25),
                 seed=seed + 1,
                 phases=(Phase("calm", third), Phase("burst", third),
                         Phase("recover", third))),
        Scenario("overload", Poisson(3.0 * cap), arith, duration_s,
                 deadline_s=0.4 * deadline_s,
                 slo=SLOTargets(abandon_rate=0.05,
                                goodput_rps=0.5 * cap),
                 seed=seed + 2, drain=False, expect_ok=False),
        Scenario("mixed_tenant", Poisson(1.0 * cap), mixed, duration_s,
                 population=6, deadline_s=deadline_s,
                 slo=SLOTargets(p99_s=1.5 * deadline_s,
                                abandon_rate=0.10),
                 seed=seed + 3),
        Scenario("closed_loop", ClosedLoop(think_s=1.0 / max(cap, 1e-9)),
                 arith, duration_s, population=3, deadline_s=deadline_s,
                 slo=SLOTargets(p99_s=deadline_s, abandon_rate=0.05),
                 seed=seed + 4),
    ]
