"""Workload mix registry for the traffic simulator.

Each `Workload` wraps one of the existing `repro.serve.programs` /
`repro.fhe_ml.lower` builders into the uniform shape the runners need:
a (lazily traced, cached) graph + IntSpec lists, a seeded plaintext
sampler, an integer oracle for end-to-end validation, and a mean
service-time prior the deterministic virtual runner's service model
starts from.

A `WorkloadMix` is a weighted distribution over workloads —
`mix.sample(rng)` draws the workload for each arriving request, so a
mixed-tenant scenario interleaves cheap const-op analytics with
PBS-heavy radix arithmetic on one runtime.

Registry (all parameterized by radix width / digit size)::

    radix_add         D-digit encrypted add        (carry-propagation PBS)
    radix_mul         D-digit encrypted multiply   (PBS-heaviest int op)
    radix_relu        two's-complement ReLU        (sign-LUT PBS)
    analytics_const   k*x + c with plaintext k, c  (LPU-only — zero PBS)
    analytics_linear  radix_linear matmul analytics query
    gpt2_block        reduced single-head encrypted transformer block
"""
from __future__ import annotations

import random
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.session import trace_program
from repro.api.tracing import IntSpec


class Workload:
    """One program template: `build()` traces (once) to
    (graph, in_specs, out_specs); `sample_values(rng)` draws the flat
    list of plaintext ints a request encrypts; `oracle(values)` is the
    expected decrypted output (None ⇒ skip validation)."""

    def __init__(self, name: str, builder: Callable,
                 sample: Callable, oracle: Optional[Callable] = None,
                 mean_service_s: float = 1.0):
        self.name = name
        self._builder = builder
        self._sample = sample
        self.oracle = oracle
        self.mean_service_s = mean_service_s
        self._built = None

    def build(self):
        """(graph, in_specs, out_specs) — traced on first call, cached."""
        if self._built is None:
            self._built = self._builder()
        return self._built

    def sample_values(self, rng: random.Random) -> list:
        return self._sample(rng)

    def encrypt(self, ic, key: jax.Array, values: list) -> list:
        """Encrypt the flat value list per the graph's input specs (a
        shape-(V,) spec consumes V ints, concatenated on the digit
        axis exactly as the interpreter expects)."""
        _, in_specs, _ = self.build()
        enc, vals = [], iter(values)
        for spec in in_specs:
            n = int(np.prod(spec.shape)) if spec.shape else 1
            digs = []
            for _ in range(n):
                key, sub = jax.random.split(key)
                digs.append(ic.encrypt(sub, int(next(vals)) % spec.modulus,
                                       spec.bits, spec.msg_bits).digits)
            enc.append(jnp.concatenate(digs, axis=0) if n > 1 else digs[0])
        return enc

    def decrypt(self, ic, outputs: list) -> list:
        """Flat list of output ints (client side)."""
        from repro.serve.programs import decrypt_radix_output
        _, _, out_specs = self.build()
        res = []
        for spec, arr in zip(out_specs, outputs):
            res.extend(decrypt_radix_output(ic, arr, spec.bits,
                                            spec.msg_bits))
        return res

    def __repr__(self):
        return f"Workload({self.name!r})"


# --------------------------------------------------------------------------
# builders
# --------------------------------------------------------------------------

def _uniform(n: int, bits: int):
    mod = 1 << bits
    return lambda rng: [rng.randrange(mod) for _ in range(n)]


def _binop(name: str, fn, oracle_fn, bits: int, msg_bits: int,
           mean_service_s: float) -> Workload:
    spec = IntSpec(bits, msg_bits)
    mod = 1 << bits

    def builder():
        prog = trace_program(fn, (spec, spec))
        return prog.graph, prog.in_specs, prog.out_specs

    return Workload(name, builder, _uniform(2, bits),
                    lambda v: [oracle_fn(v[0], v[1]) % mod],
                    mean_service_s)


def radix_add(bits: int = 8, msg_bits: int = 2) -> Workload:
    return _binop("radix_add", lambda a, b: a + b, lambda x, y: x + y,
                  bits, msg_bits, mean_service_s=0.6)


def radix_mul(bits: int = 8, msg_bits: int = 2) -> Workload:
    return _binop("radix_mul", lambda a, b: a * b, lambda x, y: x * y,
                  bits, msg_bits, mean_service_s=1.6)


def radix_relu(bits: int = 8, msg_bits: int = 2) -> Workload:
    spec = IntSpec(bits, msg_bits)
    mod = 1 << bits

    def builder():
        prog = trace_program(lambda a: a.relu(), (spec,))
        return prog.graph, prog.in_specs, prog.out_specs

    return Workload("radix_relu", builder, _uniform(1, bits),
                    lambda v: [0 if v[0] >= mod // 2 else v[0]],
                    mean_service_s=0.8)


def analytics_const(bits: int = 8, msg_bits: int = 2) -> Workload:
    """k*x + c with plaintext constants — pure-LPU traffic (PR 8
    satellite: zero PBS rounds), the cheap high-rate tenant in a mixed
    scenario.  Constants are picked to stay inside the carry window at
    the given digit size, so no renormalization PBS sneaks in."""
    k, c = (3, 41) if msg_bits >= 2 else (2, 1)
    spec = IntSpec(bits, msg_bits)
    mod = 1 << bits

    def builder():
        prog = trace_program(lambda x: x * k + c, (spec,))
        return prog.graph, prog.in_specs, prog.out_specs

    return Workload("analytics_const", builder, _uniform(1, bits),
                    lambda v: [(k * v[0] + c) % mod],
                    mean_service_s=0.02)


def analytics_linear(bits: int = 8, msg_bits: int = 2,
                     v: int = 2) -> Workload:
    """radix_linear analytics query: an encrypted length-`v` record
    against a plaintext aggregation matrix."""
    W = (np.arange(v * v).reshape(v, v) % 3 - 1).astype(np.int64)
    W[0, 0] = 2                      # keep the matrix non-degenerate
    spec = IntSpec(bits, msg_bits, shape=(v,))
    mod = 1 << bits

    def builder():
        prog = trace_program(lambda x: x.linear(W), (spec,))
        return prog.graph, prog.in_specs, prog.out_specs

    def oracle(vals):
        q = np.asarray(vals, np.int64)
        return [int(x) % mod for x in q @ W]

    return Workload("analytics_linear", builder, _uniform(v, bits), oracle,
                    mean_service_s=1.2)


def gpt2_block(bits: int = 16, msg_bits: int = 2, d: int = 2,
               seed: int = 0) -> Workload:
    """Encrypted-transformer traffic: the reduced single-head GPT-2
    block of `repro.fhe_ml.lower` (PBS-heaviest workload by far — use
    sparingly in scenario mixes)."""
    from repro.serve.programs import fhe_ml_block_program
    graph, meta = fhe_ml_block_program("gpt2", d, bits, msg_bits,
                                       seed=seed)
    mod = 1 << bits
    qmax = int(meta["input_qmax"])

    def oracle(vals):
        return [int(x) % mod for x in meta["int_fn"](vals)]

    return Workload(
        "gpt2_block",
        lambda: (graph, meta["in_specs"], meta["out_specs"]),
        lambda rng: [rng.randrange(qmax + 1) for _ in range(d)],
        oracle, mean_service_s=18.0)


REGISTRY = {
    "radix_add": radix_add,
    "radix_mul": radix_mul,
    "radix_relu": radix_relu,
    "analytics_const": analytics_const,
    "analytics_linear": analytics_linear,
    "gpt2_block": gpt2_block,
}


class WorkloadMix:
    """Weighted distribution over workloads.  Construct from instances
    (`WorkloadMix([(w, 3.0), ...])`) or names via `WorkloadMix.of`
    (`WorkloadMix.of({"radix_add": 3, "analytics_const": 1}, bits=8,
    msg_bits=2)`)."""

    def __init__(self, entries: list):
        if not entries:
            raise ValueError("empty workload mix")
        self.entries = [(w, float(wt)) for w, wt in entries]
        total = sum(wt for _, wt in self.entries)
        if total <= 0:
            raise ValueError("workload mix weights must sum > 0")
        self._total = total

    @classmethod
    def of(cls, weights: dict, **kw) -> "WorkloadMix":
        return cls([(REGISTRY[name](**kw), wt)
                    for name, wt in weights.items()])

    @property
    def workloads(self) -> list:
        return [w for w, _ in self.entries]

    def sample(self, rng: random.Random) -> Workload:
        u = rng.random() * self._total
        acc = 0.0
        for w, wt in self.entries:
            acc += wt
            if u < acc:
                return w
        return self.entries[-1][0]
