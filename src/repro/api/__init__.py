"""repro.api — one front door for eager, compiled, and served FHE.

This repo grew four divergent entry points (eager `IntegerContext` ops,
hand-built IR graphs, `fhe_ml.FheExecutor.run`, `serve.ServeRuntime
.submit`); this package unifies them behind a single traced program
contract, the API the rest of the roadmap (sharded scheduling,
encrypted-LLM traffic) is written against:

    from repro.api import Session, IntSpec

    sess = Session(ctx, backend="local")            # or "eager" / "serve"
    prog = sess.trace(lambda a, b: (a * b).relu(),
                      IntSpec(16), IntSpec(16))     # operators record IR
    enc  = sess.encrypt_inputs(key, [x, y], prog)
    vals = sess.decrypt_outputs(prog, sess.run(prog, enc))

  tracing   `EncryptedInt` / `EncryptedTensor`: Python operators
            (+, -, *, comparisons, relu) record `radix_*`/linear/`lut`
            nodes into a `repro.compiler.ir.Graph`.
  session   `Session.trace` -> `Program` (graph + encrypt/decrypt
            specs); encrypt/run/decrypt round trip.
  backends  `Backend.execute(program, enc_inputs) -> outputs`:
            `EagerBackend` (direct IntegerContext + KS/ACC-dedup PBS),
            `LocalBackend` (`serve.IrInterpreter`), `ServeBackend`
            (multi-tenant `ServeRuntime`, cross- AND intra-request
            round fusion).  Same program, identical plaintexts on all
            three.
"""
from repro.api.backends import (Backend, EagerBackend, LocalBackend,
                                ServeBackend, eval_linear_ct_op,
                                eval_radix_vector, make_backend)
from repro.api.session import Program, Session, trace_program
from repro.api.tracing import (EncryptedInt, EncryptedTensor, EncryptedValue,
                               IntSpec, RawSpec, TensorSpec)

__all__ = [
    "Backend", "EagerBackend", "EncryptedInt", "EncryptedTensor",
    "EncryptedValue", "IntSpec", "LocalBackend", "Program", "RawSpec",
    "ServeBackend", "Session", "TensorSpec", "eval_linear_ct_op",
    "eval_radix_vector", "make_backend", "trace_program",
]
