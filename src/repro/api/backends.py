"""Pluggable execution backends behind the `Session` front door.

One protocol — `Backend.execute(program, enc_inputs) -> outputs` — three
implementations:

  EagerBackend   direct execution for debugging: `lut` nodes run the
                 KS-first PBS pipeline with the paper's KS/ACC dedup
                 live (the former `fhe_ml.FheExecutor` engine room,
                 moved here; `FheExecutor.run` is now a shim), radix
                 nodes run straight through `IntegerContext`.
  LocalBackend   the serving execution contract in-process:
                 `repro.serve.IrInterpreter`, every bootstrap through
                 `engine.lut_batch`; `fused=True` wraps the engine in a
                 private `FusedLutScheduler` so one request's
                 multi-vector radix rounds fuse intra-request.
  ServeBackend   submits through the multi-tenant `ServeRuntime` and
                 wraps its `RequestHandle` — the same program joins
                 cross-request round fusion and online dedup.

`repro.serve` imports stay lazy (function-local): this module is
imported by `repro.fhe_ml.executor` and by `repro.serve` itself, and
the linear-op evaluator below is the single definition both executors
share.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glwe, lwe, torus
from repro.core import batch as batch_mod
from repro.core.integer import IntegerContext, RadixCiphertext
from repro.core.params import TFHEParams

U64 = jnp.uint64


@runtime_checkable
class Backend(Protocol):
    """The execution contract every backend implements."""
    name: str

    def execute(self, program, enc_inputs: list) -> list:
        """Run `program.graph` on encrypted inputs; returns the output
        ciphertext arrays in `program.graph.outputs` order."""
        ...


# ---------------------------------------------------------------------------
# shared node evaluators
# ---------------------------------------------------------------------------

def eval_linear_ct_op(n, vals: dict, p: TFHEParams):
    """Evaluate one PBS-free IR node on ciphertext tensors (LPU work:
    add/sub/addc/mulc/linear/reshape/concat).  Returns the result array,
    or None if `n` is not a linear op.  Shared by `EagerBackend` and
    `repro.serve.IrInterpreter` so their linear semantics cannot
    diverge."""
    delta = p.delta
    if n.op == "add":
        return lwe.add(vals[n.inputs[0]], vals[n.inputs[1]])
    if n.op == "sub":
        return lwe.sub(vals[n.inputs[0]], vals[n.inputs[1]])
    if n.op == "addc":
        c = torus.encode(jnp.asarray(
            np.asarray(n.attrs["const"], np.int64).reshape(-1)
            % (1 << p.width), dtype=U64), delta)
        x = vals[n.inputs[0]]
        c = jnp.broadcast_to(c, x.shape[:-1])
        return x.at[..., -1].add(c)
    if n.op == "mulc":
        c = np.asarray(n.attrs["const"], np.int64).reshape(-1)
        return vals[n.inputs[0]] * jnp.asarray(
            c, jnp.int64)[:, None].astype(U64)
    if n.op == "linear":
        W = jnp.asarray(np.asarray(n.attrs["W"], np.int64))
        x = vals[n.inputs[0]]                      # (in, big_n+1)
        y = jnp.einsum("io,id->od", W.astype(U64), x)
        if n.attrs.get("bias") is not None:
            b = torus.encode(jnp.asarray(
                np.asarray(n.attrs["bias"], np.int64).reshape(-1)
                % (1 << p.width), U64), delta)
            y = y.at[..., -1].add(b)
        return y
    if n.op == "radix_addc":
        # digitize the constant and add each digit onto the matching
        # digit ciphertext's body — LPU only, result left un-propagated
        # (its digit ceiling rides on the node's max_val attr)
        m, d = n.attrs["msg_bits"], n.attrs["n_digits"]
        c = int(n.attrs["const"]) % (1 << (m * d))
        digs = np.array([(c >> (i * m)) & ((1 << m) - 1) for i in range(d)],
                        dtype=np.uint64)
        x = vals[n.inputs[0]]                      # (V*d, big_n+1)
        enc = torus.encode(jnp.asarray(np.tile(digs, x.shape[0] // d)),
                           delta)
        return x.at[..., -1].add(enc)
    if n.op == "radix_mulc":
        return lwe.scalar_mul(vals[n.inputs[0]], int(n.attrs["const"]))
    if n.op in ("reshape", "concat"):
        return vals[n.inputs[0]]
    return None


def eval_radix_vector(ic: IntegerContext, op: str, spec, av: jax.Array,
                      bv: Optional[jax.Array],
                      max_val: Optional[int] = None) -> jax.Array:
    """One radix IR op on ONE digit vector through `IntegerContext`.
    Shared by `EagerBackend` and `repro.serve.IrInterpreter` — the
    radix execution semantics has exactly one definition.

    For `radix_linear`, `av` is one PRE-COMBINED output vector from
    `IntegerContext.linear_compress` and `max_val` its digit ceiling;
    this evaluator finishes the carry propagation (so the per-vector
    propagation rounds fan out / fuse exactly like the elementwise
    radix ops)."""
    ra = RadixCiphertext(spec, av)
    if op in ("radix_linear", "radix_norm"):
        return ic.propagate(ra, max_val=max_val).digits
    if op == "radix_add":
        return ic.add(ra, RadixCiphertext(spec, bv)).digits
    if op == "radix_sub":
        return ic.sub(ra, RadixCiphertext(spec, bv)).digits
    if op == "radix_mul":
        return ic.mul(ra, RadixCiphertext(spec, bv)).digits
    if op == "radix_relu":
        return ic.relu_clamp(ra).digits
    if op == "radix_cmp":
        return ic.compare(ra, RadixCiphertext(spec, bv))[None]
    raise ValueError(op)


# ---------------------------------------------------------------------------
# eager
# ---------------------------------------------------------------------------

class EagerBackend:
    """Direct execution for debugging: no queue, no round scheduler.

    `lut` nodes run the KS-first PBS pipeline with both paper dedups
    live (KS results cached per source tensor, one accumulator image per
    unique table); `radix_*` nodes dispatch per digit vector through a
    private `IntegerContext`.  Counts what it does in `stats`.
    """

    name = "eager"

    def __init__(self, ctx, engine=None, *, ks_dedup: bool = True,
                 acc_dedup: bool = True, pad_batches: bool = True,
                 telemetry=None):
        from repro.core.engine import TaurusEngine
        self.ctx = ctx
        self.params: TFHEParams = ctx.params
        self.ks_dedup = ks_dedup
        self.acc_dedup = acc_dedup
        self.telemetry = telemetry
        self.int_ctx = IntegerContext.create(
            ctx, engine or TaurusEngine.from_context(ctx),
            pad_batches=pad_batches, telemetry=telemetry)
        self.stats = {"pbs": 0, "keyswitch": 0, "lut_polys": 0}
        self._lut_cache: dict = {}

    # -- the KS-first PBS pipeline (per unique-table accumulator) -----------
    def _lut_poly(self, table: np.ndarray):
        key = table.tobytes() if self.acc_dedup else object()
        if key not in self._lut_cache:
            self._lut_cache[key] = glwe.make_lut_poly(
                jnp.asarray(table, U64), self.params)
            self.stats["lut_polys"] += 1
        return self._lut_cache[key]

    def _pbs(self, cts, table, small_cache_key, ks_cache):
        """PBS with the KS-first order so key-switch results are reusable."""
        p = self.params
        if self.ks_dedup and small_cache_key in ks_cache:
            small = ks_cache[small_cache_key]
        else:
            small = batch_mod.keyswitch_batch(cts, self.ctx.ksk, p)
            self.stats["keyswitch"] += int(cts.shape[0])
            ks_cache[small_cache_key] = small
        ms = lwe.mod_switch(small, p.log2_N + 1)
        poly = self._lut_poly(table)
        luts = glwe.trivial(jnp.broadcast_to(poly, (cts.shape[0], p.N)), p.k)
        acc = batch_mod.blind_rotate_batch(luts, ms, self.ctx.bsk_f, p)
        self.stats["pbs"] += int(cts.shape[0])
        return glwe.sample_extract(acc)

    def _radix(self, n, vals: dict) -> jax.Array:
        m, d = n.attrs["msg_bits"], n.attrs["n_digits"]
        spec = self.int_ctx.spec(m * d, m)
        width = self.params.big_n + 1
        a = vals[n.inputs[0]].reshape(-1, d, width)
        b, mv = None, None
        if n.op == "radix_linear":
            # LPU-combine + carry-save compress to one vector per output
            # column; the per-vector loop below finishes the propagation
            a, mv = self.int_ctx.linear_compress(a, n.attrs["W"], spec)
        elif n.op == "radix_norm":
            mv = n.attrs["max_val"]
        elif len(n.inputs) == 2:
            b = vals[n.inputs[1]].reshape(-1, d, width)
        outs = [eval_radix_vector(self.int_ctx, n.op, spec, a[v],
                                  None if b is None else b[v], max_val=mv)
                for v in range(a.shape[0])]
        return jnp.concatenate(outs, axis=0)

    # -- run -----------------------------------------------------------------
    def run(self, g, enc_inputs: list) -> dict:
        """Execute a Graph; returns {node_id: ciphertext array} for every
        node (the historical `FheExecutor.run` contract)."""
        from repro.compiler.ir import RADIX_OPS
        vals: dict = {}
        ks_cache: dict = {}
        it = iter(enc_inputs)
        for n in g.nodes:
            if n.op == "input":
                vals[n.id] = next(it)
                continue
            out = eval_linear_ct_op(n, vals, self.params)
            if out is not None:
                vals[n.id] = out
            elif n.op == "lut":
                vals[n.id] = self._pbs(vals[n.inputs[0]],
                                       np.asarray(n.attrs["table"]),
                                       n.inputs[0], ks_cache)
            elif n.op in RADIX_OPS:
                vals[n.id] = self._radix(n, vals)
            else:
                raise ValueError(n.op)
        return vals

    def execute(self, program, enc_inputs: list) -> list:
        vals = self.run(program.graph, enc_inputs)
        return [vals[o] for o in program.graph.outputs]


# ---------------------------------------------------------------------------
# local (serving interpreter in-process)
# ---------------------------------------------------------------------------

class LocalBackend:
    """The serving execution contract without the queue: a
    `repro.serve.IrInterpreter` over this process's engine.  With
    `fused=True` the engine is wrapped in a private `FusedLutScheduler`,
    so the per-vector rounds of one program's tensor-level radix nodes
    fuse into shared batches (intra-request fusion, no runtime needed).
    """

    name = "local"

    def __init__(self, ctx, engine=None, *, fused: bool = False,
                 telemetry=None):
        from repro.core.engine import TaurusEngine
        from repro.serve.interpreter import IrInterpreter
        from repro.serve.scheduler import FusedLutScheduler
        engine = engine or TaurusEngine.from_context(ctx)
        self.telemetry = telemetry
        self.scheduler = (FusedLutScheduler(telemetry=telemetry)
                          if fused else None)
        eng = self.scheduler.proxy(engine) if fused else engine
        self.interp = IrInterpreter(ctx, eng, telemetry=telemetry)

    def execute(self, program, enc_inputs: list) -> list:
        return self.interp.run_outputs(program.graph, enc_inputs)


# ---------------------------------------------------------------------------
# serve (multi-tenant runtime)
# ---------------------------------------------------------------------------

class ServeBackend:
    """Submits programs through a `ServeRuntime`: the session's traffic
    joins cross-request fused PBS rounds and online dedup, and a traced
    program's tensor-level radix nodes flatten into per-vector rounds
    that fuse intra-request (`IrInterpreter` vector fan-out).

    Runtime keywords thread straight through — `Session(ctx,
    backend="serve", shards=2, elastic=True, max_inflight=8)` builds a
    sharded runtime exactly like calling `ServeRuntime` directly (the
    `shards=` knob rides the same path `kernel_backend=` does)."""

    name = "serve"

    def __init__(self, ctx, engine=None, *, runtime=None,
                 client_id: str = "session", **runtime_kw):
        from repro.serve.runtime import ServeRuntime
        self._owns_runtime = runtime is None
        self.runtime = runtime if runtime is not None \
            else ServeRuntime(ctx, engine, **runtime_kw)
        # the runtime's Telemetry (passed via runtime_kw or its default):
        # `Session.telemetry` and `metrics()` read through this
        self.telemetry = self.runtime.telemetry
        self.client_id = client_id

    def metrics(self) -> dict:
        return self.runtime.metrics()

    @property
    def scheduler(self):
        return self.runtime.scheduler

    def submit(self, program, enc_inputs: list,
               client_id: Optional[str] = None):
        """Async path: returns the runtime's `RequestHandle`
        (`handle.outputs()` joins)."""
        return self.runtime.submit(program.graph, enc_inputs,
                                   client_id=client_id or self.client_id)

    def execute(self, program, enc_inputs: list) -> list:
        return self.submit(program, enc_inputs).outputs()

    def close(self) -> None:
        if self._owns_runtime:
            self.runtime.close()


_BACKENDS = {"eager": EagerBackend, "local": LocalBackend,
             "serve": ServeBackend}


def make_backend(name: str, ctx, engine=None, *, kernel_backend=None, **kw):
    """Construct a named backend ("eager" | "local" | "serve") over the
    given key material; extra keywords forward to the backend's
    constructor (e.g. `fused=True` for local, `max_inflight=8` or
    `shards=2` for serve).
    `kernel_backend="reference" | "pallas"` selects the engine
    room when no prebuilt engine is passed (see `repro.core.engine`).
    `Session` calls this for string backends; use it directly to share
    one backend across sessions::

        be = make_backend("serve", ctx, engine, max_inflight=4)
        sess = Session(ctx, engine, backend=be)
    """
    try:
        cls = _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r} "
                         f"(have {sorted(_BACKENDS)})") from None
    if kernel_backend is not None:
        if engine is not None:
            raise TypeError("pass kernel_backend OR a prebuilt engine, "
                            "not both")
        from repro.core.engine import TaurusEngine
        engine = TaurusEngine.from_context(ctx, kernel_backend=kernel_backend)
    return cls(ctx, engine, **kw)
