"""The `repro.api` front door: trace once, run anywhere.

A `Session` binds key material (a `TFHEContext`) to one pluggable
`Backend` and gives every FHE workload in this repo the same three-step
shape:

    sess = Session(ctx, backend="local")
    prog = sess.trace(lambda a, b: (a * b).relu(), IntSpec(16), IntSpec(16))
    enc  = sess.encrypt_inputs(key, [x, y], prog)
    out  = sess.run(prog, enc)
    vals = sess.decrypt_outputs(prog, out)

The traced `Program` is an ordinary `repro.compiler.ir.Graph` plus the
input/output specs needed to encrypt and decrypt — the single program
contract between the frontend and every executor.  Swapping
`backend="eager" | "local" | "serve"` changes WHERE the graph executes
(direct `IntegerContext`, the serving IR interpreter, or the
multi-tenant `ServeRuntime`), never WHAT it computes: decrypted outputs
are identical across the three (tested in `tests/test_api.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.api.tracing import (EncryptedInt, EncryptedTensor, EncryptedValue,
                               IntSpec, RawSpec, TensorSpec, make_input)
from repro.compiler.ir import Graph
from repro.core.integer import IntegerContext, RadixCiphertext


@dataclasses.dataclass
class Program:
    """A compiled program: the IR graph plus its encryption contract."""
    graph: Graph
    in_specs: list
    out_specs: list

    @classmethod
    def from_graph(cls, graph: Graph, in_specs: Optional[list] = None,
                   out_specs: Optional[list] = None) -> "Program":
        """Wrap a hand-built / lowered Graph (e.g. from `repro.fhe_ml`).
        Specs default to plain tensor/raw slots shaped like the graph's
        input and output nodes."""
        if in_specs is None:
            in_specs = [TensorSpec(tuple(n.shape)) for n in graph.nodes
                        if n.op == "input"]
        if out_specs is None:
            out_specs = [RawSpec(tuple(graph.nodes[o].shape))
                         for o in graph.outputs]
        return cls(graph, list(in_specs), list(out_specs))

    @property
    def n_inputs(self) -> int:
        return len(self.in_specs)


def trace_program(fn, in_specs, params=None) -> Program:
    """Trace `fn` over input specs into a `Program`.

    Session-free entry (used by `repro.serve.programs`): without
    `params`, IntSpecs must carry explicit msg_bits and boolean
    comparisons are unavailable (their verdict LUT needs the plaintext
    width).
    """
    width = params.width if params is not None else None
    g = Graph()
    specs = [s.resolve(params) if isinstance(s, IntSpec) and params is not None
             else s for s in in_specs]
    args = [make_input(g, s, width) for s in specs]
    out = fn(*args)
    outs = list(out) if isinstance(out, (tuple, list)) else [out]
    out_specs = []
    for o in outs:
        if not isinstance(o, (EncryptedInt, EncryptedTensor, EncryptedValue)):
            raise TypeError(f"traced fn returned {type(o).__name__}; "
                            "return traced encrypted values")
        out_specs.append(o.out_spec())
    g.outputs = [o.t.node.id for o in outs]
    return Program(g, specs, out_specs)


class Session:
    """One front door for eager, compiled, and served FHE execution.

    backend: "eager" | "local" | "serve", or any object implementing the
    `Backend` protocol (`execute(program, enc_inputs) -> outputs`).
    Extra keyword arguments are forwarded to the named backend's
    constructor (e.g. `max_inflight=8` for "serve", `fused=True` for
    "local").  The sharded serving knobs thread the same way:
    `Session(ctx, backend="serve", shards=2, elastic=True)` serves this
    session's traffic through a 2-shard `ServeRuntime` with elastic
    per-shard admission — `shards=1` stays decrypt-identical to the
    single-shard runtime on every backend.

    kernel_backend: "reference" | "pallas" — which PBS engine room the
    session's `TaurusEngine` runs (see `repro.core.engine`).  Only valid
    when no prebuilt engine is passed; eager, local, and serve backends
    all inherit it because they share the session engine.

    telemetry: an optional `repro.obs.Telemetry` threaded through the
    named backend's whole stack (runtime, scheduler, interpreter,
    integer context); `Session.metrics()` returns its snapshot and,
    when traced (`Telemetry(trace=True)`), `telemetry.write_chrome_trace`
    exports the request spans.

    Example (the repo-wide three-step shape; `sess(prog, key, *vals)`
    collapses encrypt -> run -> decrypt)::

        with Session(ctx, backend="serve") as sess:
            prog = sess.trace(lambda a, b: a + b, IntSpec(16), IntSpec(16))
            print(sess(prog, jax.random.key(0), 1234, 567))   # [1801]

    Hand-lowered graphs (e.g. the quantize-to-radix transformer blocks
    from `repro.fhe_ml.lower`) adopt through `compile`::

        g, meta = lower_gpt2_block_radix(2, bits=16, msg_bits=2)
        prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
    """

    def __init__(self, ctx, engine=None, backend="local", telemetry=None,
                 kernel_backend=None, **backend_kw):
        from repro.api.backends import make_backend
        from repro.core.engine import TaurusEngine
        self.ctx = ctx
        self.params = ctx.params
        if kernel_backend is not None:
            if engine is not None:
                raise TypeError("pass kernel_backend OR a prebuilt engine, "
                                "not both (set it on the engine instead)")
            engine = TaurusEngine.from_context(ctx,
                                               kernel_backend=kernel_backend)
        # client-side radix crypto (encrypt/decrypt only — backends own
        # their server-side contexts)
        self.int_ctx = IntegerContext.create(ctx, engine)
        self.engine = self.int_ctx.engine
        if isinstance(backend, str):
            if telemetry is not None:
                backend_kw["telemetry"] = telemetry
            backend = make_backend(backend, ctx, self.engine, **backend_kw)
        elif backend_kw or telemetry is not None:
            raise TypeError("backend_kw/telemetry only apply to named "
                            "backends (pass telemetry to the backend's own "
                            "constructor instead)")
        self.backend = backend
        self.telemetry = telemetry if telemetry is not None \
            else getattr(backend, "telemetry", None)

    def metrics(self) -> dict:
        """The backend's telemetry snapshot ({} for an un-instrumented
        backend object)."""
        return self.telemetry.snapshot() if self.telemetry is not None else {}

    # -- trace / compile -----------------------------------------------------
    def trace(self, fn, *in_specs) -> Program:
        """Trace `fn` over the given specs into a backend-portable
        Program.  IntSpec msg_bits defaults from this session's params."""
        return trace_program(fn, in_specs, self.params)

    def compile(self, graph: Graph, in_specs=None, out_specs=None) -> Program:
        """Adopt an existing IR graph (e.g. a `repro.fhe_ml` lowering)
        as a backend-portable Program.

        Without specs, inputs/outputs default to plain width-bit
        ciphertext-slot tensors shaped like the graph's input/output
        nodes (right for the narrow-LUT lowerings).  Radix graphs pass
        their IntSpec lists — the quantize-to-radix lowerings hand them
        over in meta::

            prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
        """
        return Program.from_graph(graph, in_specs, out_specs)

    # -- client-side crypto --------------------------------------------------
    def _encrypt_one(self, key: jax.Array, spec, value) -> jax.Array:
        if isinstance(spec, IntSpec):
            spec = spec.resolve(self.params)
            vals = np.asarray(value).reshape(-1)
            assert vals.size == spec.n_ints, (
                f"spec {spec} wants {spec.n_ints} integers, got {vals.size}")
            cts = []
            for sub, v in zip(jax.random.split(key, vals.size), vals):
                cts.append(self.int_ctx.encrypt(
                    sub, int(v), spec.bits, spec.msg_bits).digits)
            return jax.numpy.concatenate(cts, axis=0)     # (V*D, big_n+1)
        if isinstance(spec, (TensorSpec, RawSpec)):
            flat = np.asarray(value).reshape(-1)
            return self.ctx.encrypt(key, flat)
        raise TypeError(f"cannot encrypt for spec {spec!r}")

    def encrypt_inputs(self, key: jax.Array, values, program: Program) -> list:
        """Encrypt one plaintext per program input; returns the
        ciphertext arrays every backend consumes."""
        assert len(values) == program.n_inputs, (
            f"program takes {program.n_inputs} inputs, got {len(values)}")
        out = []
        for spec, v in zip(program.in_specs, values):
            key, sub = jax.random.split(key)
            out.append(self._encrypt_one(sub, spec, v))
        return out

    def _decrypt_one(self, spec, arr):
        if isinstance(spec, IntSpec):
            spec = spec.resolve(self.params)
            rspec = self.int_ctx.spec(spec.bits, spec.msg_bits)
            vecs = np.asarray(arr).reshape(-1, rspec.n_digits, arr.shape[-1])
            ints = [self.int_ctx.decrypt(RadixCiphertext(rspec, v))
                    for v in vecs]
            if spec.shape == ():
                return ints[0]
            return np.array(ints, dtype=np.int64).reshape(spec.shape)
        vals = np.asarray(jax.vmap(self.ctx.decrypt)(arr))
        return vals.reshape(spec.shape)

    def decrypt_outputs(self, program: Program, outputs) -> list:
        """Decrypt backend outputs back to Python ints / numpy arrays."""
        return [self._decrypt_one(s, a)
                for s, a in zip(program.out_specs, outputs)]

    # -- execution -----------------------------------------------------------
    def run(self, program: Program, enc_inputs: list) -> list:
        """Execute on this session's backend; returns the output
        ciphertext arrays in `program.graph.outputs` order."""
        return self.backend.execute(program, enc_inputs)

    def submit(self, program: Program, enc_inputs: list,
               client_id: Optional[str] = None):
        """Async submit (serve backend): returns the request handle,
        whose `output_futures` resolve PER OUTPUT (each with a
        completion timestamp) as the interpreter materializes them —
        `handle.outputs()` still joins the whole request.  client_id
        defaults to the backend's configured identity."""
        submit = getattr(self.backend, "submit", None)
        if submit is None:
            raise TypeError(
                f"backend {getattr(self.backend, 'name', self.backend)!r} "
                "is synchronous — use run(), or Session(backend='serve')")
        return submit(program, enc_inputs, client_id=client_id)

    def __call__(self, program: Program, key: jax.Array, *values) -> list:
        """Convenience: encrypt -> run -> decrypt in one call."""
        enc = self.encrypt_inputs(key, list(values), program)
        return self.decrypt_outputs(program, self.run(program, enc))

    def close(self) -> None:
        close = getattr(self.backend, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
