"""Tracing types of the `repro.api` front door.

`EncryptedInt` and `EncryptedTensor` are the values a traced function
manipulates: thin wrappers over `repro.compiler.ir.FheTensor` whose
Python operators record IR nodes instead of computing.  An
`EncryptedInt` is a radix wide integer — its last tensor axis is the
little-endian digit vector (`repro.core.integer`), and `+`, `-`, `*`,
comparisons and `relu()` record `radix_*` nodes.  An `EncryptedTensor`
is a tensor of plain width-bit ciphertext slots — the `repro.fhe_ml`
value kind — and records the linear/`lut` nodes `FheTensor` already
implements.

The specs (`IntSpec`, `TensorSpec`, `RawSpec`) describe program inputs
and outputs; `Session` uses them to encrypt arguments and decrypt
results, so one `Program` means the same plaintexts on every backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.compiler.ir import FheTensor, Graph


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclasses.dataclass(frozen=True)
class IntSpec:
    """A (tensor of) encrypted W-bit radix integer(s).

    shape is the LEADING shape — () for one integer, (V,) for a vector
    of V integers; the traced tensor gains a trailing digit axis of
    length `bits // msg_bits`.  msg_bits defaults per parameter set
    (half the plaintext window) when the spec reaches a `Session`.

    Example::

        prog = sess.trace(lambda a, b: a + b,
                          IntSpec(16), IntSpec(16))          # scalars
        prog = sess.trace(lambda v: v.linear(W).relu(),
                          IntSpec(32, shape=(8,)))           # a vector
    """
    bits: int
    msg_bits: Optional[int] = None
    shape: tuple = ()

    def resolve(self, params) -> "IntSpec":
        if self.msg_bits is not None:
            return self
        return dataclasses.replace(
            self, msg_bits=max(1, params.width // 2))

    @property
    def n_digits(self) -> int:
        assert self.msg_bits is not None, "unresolved IntSpec (no msg_bits)"
        return self.bits // self.msg_bits

    @property
    def n_ints(self) -> int:
        return _prod(self.shape)

    @property
    def tensor_shape(self) -> tuple:
        return tuple(self.shape) + (self.n_digits,)

    @property
    def modulus(self) -> int:
        return 1 << self.bits


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A tensor of ordinary width-bit ciphertext slots (the fhe_ml value
    kind: quantized activations, LUT inputs/outputs)."""
    shape: tuple

    @property
    def n_elements(self) -> int:
        return _prod(self.shape)


@dataclasses.dataclass(frozen=True)
class RawSpec:
    """An output of raw ciphertext slots decrypted elementwise — compare
    verdicts, comparison bits, anything not carrying radix layout."""
    shape: tuple

    @property
    def n_elements(self) -> int:
        return _prod(self.shape)


class EncryptedValue:
    """Raw ciphertext-slot handle (cmp verdicts / comparison bits): still
    traceable through elementwise `lut`."""

    def __init__(self, t: FheTensor):
        self.t = t

    @property
    def shape(self):
        return self.t.shape

    def lut(self, table, name: str = "") -> "EncryptedValue":
        return EncryptedValue(self.t.lut(np.asarray(table), name=name))

    def out_spec(self) -> RawSpec:
        return RawSpec(tuple(self.shape))


# cmp verdict encoding (repro.core.integer.cmp_digit_table):
#   0 = equal, 1 = less-than, 2 = greater-than
_VERDICT_BITS = {
    "lt": (1,), "gt": (2,), "eq": (0,),
    "le": (0, 1), "ge": (0, 2), "ne": (1, 2),
}


def _verdict_table(width: int, which: str) -> np.ndarray:
    hot = _VERDICT_BITS[which]
    return np.array([1 if v in hot else 0 for v in range(1 << width)],
                    dtype=np.uint64)


class EncryptedInt:
    """Traced radix wide integer: operators record `radix_*` IR nodes.

    `width` (the parameter set's plaintext window) is only needed by the
    boolean comparisons, whose verdict-to-bit LUT is a 2^width table;
    `Session.trace` always supplies it, the session-free
    `trace_program(..., params=None)` path leaves it unset.

    Plaintext-constant operands (`enc + 3`, `enc * 2`, `enc - 5`) record
    LPU-only `radix_addc`/`radix_mulc` nodes — no PBS round.  The result
    is left UN-PROPAGATED: `max_val` tracks its per-digit plaintext
    ceiling (decryption recombines exactly regardless), and a
    `radix_norm` node (one carry propagation) is auto-inserted only when
    an un-normalized value feeds a PBS op whose digit packing assumes
    values below base.
    """

    def __init__(self, t: FheTensor, spec: IntSpec,
                 width: Optional[int] = None,
                 max_val: Optional[int] = None):
        assert spec.msg_bits is not None, "IntSpec must be resolved"
        assert tuple(t.shape) == spec.tensor_shape, (t.shape, spec)
        self.t = t
        self.spec = spec
        self.width = width
        # per-digit plaintext ceiling; base-1 == carry-propagated
        self.max_val = ((1 << spec.msg_bits) - 1
                        if max_val is None else int(max_val))

    @property
    def shape(self):
        return self.spec.shape

    @property
    def _window(self) -> int:
        """Largest per-digit plaintext value the parameter set can hold.
        Without a session (width unknown) assume the standard
        width = 2*msg_bits layout — conservative: a wider real window
        only makes the extra norms sound, never wrong."""
        w = self.width if self.width is not None else 2 * self.spec.msg_bits
        return (1 << w) - 1

    def norm(self) -> "EncryptedInt":
        """Carry-propagate back below base (PBS rounds); no-op when the
        digits are already normalized."""
        base = 1 << self.spec.msg_bits
        if self.max_val <= base - 1:
            return self
        return EncryptedInt(
            self.t.radix_norm(self.spec.msg_bits, self.max_val),
            self.spec, self.width)

    # -- arithmetic (each one radix node over the digit axis) ---------------
    def _coerce(self, other) -> "EncryptedInt":
        if not isinstance(other, EncryptedInt):
            raise TypeError(
                f"EncryptedInt ops need EncryptedInt or int operands, got "
                f"{type(other).__name__} (encrypt non-integer plaintext "
                f"as program inputs)")
        assert other.spec == self.spec, (self.spec, other.spec)
        return other

    def _addc(self, const: int) -> "EncryptedInt":
        c = int(const) % self.spec.modulus
        if c == 0:
            return self
        m = self.spec.msg_bits
        base = 1 << m
        cmax = max((c >> (i * m)) & (base - 1)
                   for i in range(self.spec.n_digits))
        s = self if self.max_val + cmax <= self._window else self.norm()
        out_max = s.max_val + cmax
        return EncryptedInt(s.t.radix_addc(c, m, out_max),
                            self.spec, self.width, max_val=out_max)

    def _mulc(self, const: int) -> "EncryptedInt":
        k = int(const)
        if k < 0:
            raise TypeError(
                "negative plaintext multipliers are not supported "
                "(digitwise scaling has no base complement) — encrypt "
                "the constant as a program input")
        if k == 1:
            return self
        base = 1 << self.spec.msg_bits
        s = self if k * self.max_val <= self._window else self.norm()
        if k * s.max_val > self._window:
            raise TypeError(
                f"plaintext multiplier {k} overflows the digit window "
                f"(ceiling {k * s.max_val} > {self._window}) — encrypt "
                f"it as a program input and use ct*ct multiply")
        out_max = k * s.max_val
        return EncryptedInt(s.t.radix_mulc(k, self.spec.msg_bits, out_max),
                            self.spec, self.width, max_val=out_max)

    def __add__(self, other):
        if isinstance(other, (int, np.integer)):
            return self._addc(other)
        o = self._coerce(other)
        a, b = self.norm(), o.norm()
        return EncryptedInt(a.t.radix_add(b.t, self.spec.msg_bits),
                            self.spec, self.width)

    __radd__ = __add__

    def __sub__(self, other):
        if isinstance(other, (int, np.integer)):
            return self._addc(-int(other))
        o = self._coerce(other)
        a, b = self.norm(), o.norm()
        return EncryptedInt(a.t.radix_sub(b.t, self.spec.msg_bits),
                            self.spec, self.width)

    def __mul__(self, other):
        if isinstance(other, (int, np.integer)):
            return self._mulc(other)
        o = self._coerce(other)
        a, b = self.norm(), o.norm()
        return EncryptedInt(a.t.radix_mul(b.t, self.spec.msg_bits),
                            self.spec, self.width)

    __rmul__ = __mul__

    def relu(self) -> "EncryptedInt":
        """Two's-complement max(x, 0)."""
        s = self.norm()
        return EncryptedInt(s.t.radix_relu(self.spec.msg_bits),
                            self.spec, self.width)

    def linear(self, W) -> "EncryptedInt":
        """Plaintext integer matmul across the integer-vector axis: for a
        vector of V encrypted integers and an integer (V, V_out) matrix,
        out[j] = sum_i W[i, j] * self[i] mod 2^bits (a `radix_linear`
        node — the quantize-to-radix linear layer of `repro.fhe_ml`).

        Example::

            prog = sess.trace(lambda x: x.linear(W).relu(),
                              IntSpec(16, shape=(4,)))
        """
        W = np.asarray(W, np.int64)
        if len(self.spec.shape) != 1:
            raise TypeError(
                f"linear needs a 1-D vector of encrypted integers "
                f"(IntSpec shape (V,)), got shape {self.spec.shape}")
        out_spec = dataclasses.replace(self.spec, shape=(int(W.shape[1]),))
        s = self.norm()
        return EncryptedInt(s.t.radix_linear(W, self.spec.msg_bits),
                            out_spec, self.width)

    # -- comparisons ---------------------------------------------------------
    def cmp(self, other) -> EncryptedValue:
        """Three-way compare: 0 equal / 1 less / 2 greater per integer."""
        o = self._coerce(other)
        a, b = self.norm(), o.norm()
        return EncryptedValue(a.t.radix_cmp(b.t, self.spec.msg_bits))

    def _cmp_bit(self, other, which: str) -> EncryptedValue:
        if self.width is None:
            raise TypeError(
                "boolean comparisons need the parameter width for their "
                "verdict LUT — trace through Session.trace (or use .cmp() "
                "for the raw three-way verdict)")
        return self.cmp(other).lut(_verdict_table(self.width, which),
                                   name=f"cmp_{which}")

    def __lt__(self, other):
        return self._cmp_bit(other, "lt")

    def __gt__(self, other):
        return self._cmp_bit(other, "gt")

    def __le__(self, other):
        return self._cmp_bit(other, "le")

    def __ge__(self, other):
        return self._cmp_bit(other, "ge")

    def __eq__(self, other):  # noqa: PLW3201 — traced, numpy-style
        return self._cmp_bit(other, "eq")

    def __ne__(self, other):  # noqa: PLW3201
        return self._cmp_bit(other, "ne")

    __hash__ = None  # traced values are not hashable (eq is symbolic)

    def out_spec(self) -> IntSpec:
        return self.spec


class EncryptedTensor:
    """Traced tensor of width-bit slots — delegates to `FheTensor` and
    re-wraps, so the fhe_ml linear/LUT programming model flows through
    the same Session front door as the radix integers."""

    def __init__(self, t: FheTensor, spec: Optional[TensorSpec] = None):
        self.t = t
        self.spec = spec if spec is not None else TensorSpec(tuple(t.shape))

    @property
    def shape(self):
        return self.t.shape

    def _wrap(self, t: FheTensor) -> "EncryptedTensor":
        return EncryptedTensor(t)

    def __add__(self, other):
        o = other.t if isinstance(other, EncryptedTensor) else other
        return self._wrap(self.t + o)

    def __sub__(self, other):
        o = other.t if isinstance(other, EncryptedTensor) else other
        return self._wrap(self.t - o)

    def __mul__(self, const):
        assert not isinstance(const, (EncryptedTensor, EncryptedInt)), \
            "ct*ct needs a bivariate LUT — use lut2()"
        return self._wrap(self.t * const)

    def linear(self, W, bias=None):
        return self._wrap(self.t.linear(np.asarray(W), bias))

    def lut(self, table, name: str = ""):
        return self._wrap(self.t.lut(np.asarray(table), name=name))

    def lut2(self, other: "EncryptedTensor", table, radix: int,
             name: str = ""):
        return self._wrap(self.t.lut2(other.t, np.asarray(table), radix,
                                      name=name))

    def reshape(self, *shape):
        return self._wrap(self.t.reshape(*shape))

    def out_spec(self) -> TensorSpec:
        return TensorSpec(tuple(self.shape))


def make_input(graph: Graph, spec, width: Optional[int] = None):
    """Create one traced input value for `spec` in `graph`."""
    if isinstance(spec, IntSpec):
        node = graph.add("input", (), spec.tensor_shape)
        return EncryptedInt(FheTensor(graph, node), spec, width)
    if isinstance(spec, TensorSpec):
        node = graph.add("input", (), spec.shape)
        return EncryptedTensor(FheTensor(graph, node), spec)
    raise TypeError(f"unknown input spec {spec!r}")
