"""Plaintext oracle for FHE IR graphs + the legacy executor shim.

`interpret(graph, inputs, width)` is the integer-semantics oracle (every
value lives mod 2^width, exactly like the torus encoding; radix nodes
operate on digit vectors mod 2^bits).

The real encrypted execution moved behind the `repro.api` front door:
`repro.api.EagerBackend` is the KS/ACC-dedup executor that used to live
here, and `Session(ctx, backend=...)` runs the same graph eagerly,
through the serving interpreter, or through the multi-tenant runtime.
`FheExecutor` remains as a deprecation shim over `EagerBackend` so
existing callers keep working.
"""
from __future__ import annotations

import warnings

import numpy as np

import jax

# Shared node evaluator: real home is repro.api.backends; re-exported
# here for the callers that predate the front door.
from repro.api.backends import EagerBackend, eval_linear_ct_op  # noqa: F401
from repro.compiler.ir import Graph, RADIX_OPS


# --------------------------------------------------------------------------
# plaintext integer oracle (defines correctness)
# --------------------------------------------------------------------------

def _interpret_radix(n, vals: dict) -> np.ndarray:
    """Integer semantics of one radix node: recombine digit vectors,
    apply the op mod 2^bits, re-digitize (cmp yields verdicts)."""
    m, d = n.attrs["msg_bits"], n.attrs["n_digits"]
    base, mod = 1 << m, 1 << (m * d)
    a = np.asarray(vals[n.inputs[0]]).reshape(-1, d)
    ints_a = [sum(int(dig) << (i * m) for i, dig in enumerate(vec)) % mod
              for vec in a]
    ints_b = None
    if len(n.inputs) == 2:
        b = np.asarray(vals[n.inputs[1]]).reshape(-1, d)
        ints_b = [sum(int(dig) << (i * m) for i, dig in enumerate(vec)) % mod
                  for vec in b]
    if n.op == "radix_cmp":
        return np.array([0 if x == y else (1 if x < y else 2)
                         for x, y in zip(ints_a, ints_b)], np.int64)
    if n.op == "radix_linear":
        W = np.asarray(n.attrs["W"], np.int64)
        res = [int(sum(int(W[i, j]) * ints_a[i]
                       for i in range(W.shape[0]))) % mod
               for j in range(W.shape[1])]
        return np.array([(v >> (i * m)) & (base - 1)
                         for v in res for i in range(d)], np.int64)
    if n.op == "radix_add":
        res = [(x + y) % mod for x, y in zip(ints_a, ints_b)]
    elif n.op == "radix_addc":
        res = [(x + int(n.attrs["const"])) % mod for x in ints_a]
    elif n.op == "radix_mulc":
        res = [(x * int(n.attrs["const"])) % mod for x in ints_a]
    elif n.op == "radix_norm":
        res = ints_a                    # value-preserving renormalization
    elif n.op == "radix_sub":
        res = [(x - y) % mod for x, y in zip(ints_a, ints_b)]
    elif n.op == "radix_mul":
        res = [(x * y) % mod for x, y in zip(ints_a, ints_b)]
    elif n.op == "radix_relu":
        res = [0 if x >= mod // 2 else x for x in ints_a]
    else:
        raise ValueError(n.op)
    return np.array([(v >> (i * m)) & (base - 1)
                     for v in res for i in range(d)], np.int64)


def interpret(g: Graph, inputs: list, width: int,
              check_range: bool = True) -> dict:
    """inputs: list of int arrays (flattened per input node; radix
    inputs are little-endian digit values).  Returns {node_id: int
    array} for every node, values mod 2^width.

    check_range enforces the Concrete compile-time guarantee: every value
    ENTERING a LUT must lie in [0, 2^width) *before* wrapping — outside
    that window real PBS negacyclically flips the result and the plain
    mod-2^w oracle would silently diverge from the encrypted run.
    Linear values are tracked UNBOUNDED for this check and reduced
    mod 2^width only at LUTs/outputs (torus decode semantics).
    """
    mod = 1 << width
    vals: dict = {}              # unbounded integer tracking
    it = iter(inputs)
    for n in g.nodes:
        if n.op == "input":
            vals[n.id] = np.asarray(next(it), np.int64)
        elif n.op == "add":
            vals[n.id] = vals[n.inputs[0]] + vals[n.inputs[1]]
        elif n.op == "sub":
            vals[n.id] = vals[n.inputs[0]] - vals[n.inputs[1]]
        elif n.op == "addc":
            vals[n.id] = vals[n.inputs[0]] + np.asarray(n.attrs["const"],
                                                        np.int64)
        elif n.op == "mulc":
            vals[n.id] = vals[n.inputs[0]] * np.asarray(n.attrs["const"],
                                                        np.int64)
        elif n.op == "linear":
            W = np.asarray(n.attrs["W"], np.int64)
            x = vals[n.inputs[0]].reshape(-1, W.shape[0])
            y = x @ W
            if n.attrs.get("bias") is not None:
                y = y + np.asarray(n.attrs["bias"], np.int64)
            vals[n.id] = y.reshape(-1)
        elif n.op == "lut":
            v = vals[n.inputs[0]]
            if check_range and (v.min() < 0 or v.max() >= mod):
                raise OverflowError(
                    f"LUT input out of [0, {mod}) at node {n.id} "
                    f"(range [{v.min()}, {v.max()}]): PBS would flip "
                    f"negacyclically — resize weights/activation widths")
            t = np.asarray(n.attrs["table"], np.int64)
            vals[n.id] = t[v % mod] % mod
        elif n.op in RADIX_OPS:
            vals[n.id] = _interpret_radix(n, vals)
        elif n.op in ("reshape", "concat"):
            vals[n.id] = vals[n.inputs[0]]
        else:
            raise ValueError(n.op)
    return {k: np.asarray(v) % mod for k, v in vals.items()}


# --------------------------------------------------------------------------
# legacy executor — deprecation shim over repro.api.EagerBackend
# --------------------------------------------------------------------------

class FheExecutor:
    """Deprecated: construct `repro.api.Session(ctx, backend="eager")`
    (or `repro.api.EagerBackend` directly).  This shim forwards to
    `EagerBackend` and preserves the historical surface (`run` returning
    {node_id: array}, `stats`, `encrypt_inputs`, `decrypt`)."""

    def __init__(self, ctx, *, ks_dedup: bool = True, acc_dedup: bool = True):
        self.ctx = ctx
        self.params = ctx.params
        self._backend = EagerBackend(ctx, ks_dedup=ks_dedup,
                                     acc_dedup=acc_dedup)

    @property
    def stats(self) -> dict:
        return self._backend.stats

    # -- client side --------------------------------------------------------
    def encrypt_inputs(self, key: jax.Array, inputs: list) -> list:
        out = []
        for arr in inputs:
            key, sub = jax.random.split(key)
            out.append(self.ctx.encrypt(sub, np.asarray(arr).reshape(-1)))
        return out

    def decrypt(self, ct):
        return np.asarray(self.ctx.decrypt(ct))

    # -- run ------------------------------------------------------------------
    def run(self, g: Graph, enc_inputs: list) -> dict:
        warnings.warn(
            "FheExecutor.run is deprecated — use repro.api.Session"
            "(ctx, backend='eager') / EagerBackend.run",
            DeprecationWarning, stacklevel=2)
        return self._backend.run(g, enc_inputs)
