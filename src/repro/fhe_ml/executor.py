"""Execute FHE IR graphs — plaintext integer oracle + real encrypted run.

`interpret(graph, inputs, width)` is the integer-semantics oracle (every
value lives mod 2^width, exactly like the torus encoding).

`FheExecutor` runs the same graph on REAL TFHE ciphertexts through the
batched TaurusEngine, with both compiler optimizations live:
  * KS-dedup — key-switch results cached per source node and reused by
    every LUT that reads that node (the engine counts them);
  * ACC-dedup — one GLWE test polynomial per unique table, shared across
    all ciphertext elements that apply it.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.compiler.ir import Graph
from repro.core import glwe, lwe, torus
from repro.core import batch as batch_mod
from repro.core.params import TFHEParams

U64 = jnp.uint64


# --------------------------------------------------------------------------
# plaintext integer oracle (defines correctness)
# --------------------------------------------------------------------------

def interpret(g: Graph, inputs: list, width: int,
              check_range: bool = True) -> dict:
    """inputs: list of int arrays (flattened per input node).
    Returns {node_id: int array} for every node, values mod 2^width.

    check_range enforces the Concrete compile-time guarantee: every value
    ENTERING a LUT must lie in [0, 2^width) *before* wrapping — outside
    that window real PBS negacyclically flips the result and the plain
    mod-2^w oracle would silently diverge from the encrypted run.
    Linear values are tracked UNBOUNDED for this check and reduced
    mod 2^width only at LUTs/outputs (torus decode semantics).
    """
    mod = 1 << width
    vals: dict = {}              # unbounded integer tracking
    it = iter(inputs)
    for n in g.nodes:
        if n.op == "input":
            vals[n.id] = np.asarray(next(it), np.int64)
        elif n.op == "add":
            vals[n.id] = vals[n.inputs[0]] + vals[n.inputs[1]]
        elif n.op == "sub":
            vals[n.id] = vals[n.inputs[0]] - vals[n.inputs[1]]
        elif n.op == "addc":
            vals[n.id] = vals[n.inputs[0]] + np.asarray(n.attrs["const"],
                                                        np.int64)
        elif n.op == "mulc":
            vals[n.id] = vals[n.inputs[0]] * np.asarray(n.attrs["const"],
                                                        np.int64)
        elif n.op == "linear":
            W = np.asarray(n.attrs["W"], np.int64)
            x = vals[n.inputs[0]].reshape(-1, W.shape[0])
            y = x @ W
            if n.attrs.get("bias") is not None:
                y = y + np.asarray(n.attrs["bias"], np.int64)
            vals[n.id] = y.reshape(-1)
        elif n.op == "lut":
            v = vals[n.inputs[0]]
            if check_range and (v.min() < 0 or v.max() >= mod):
                raise OverflowError(
                    f"LUT input out of [0, {mod}) at node {n.id} "
                    f"(range [{v.min()}, {v.max()}]): PBS would flip "
                    f"negacyclically — resize weights/activation widths")
            t = np.asarray(n.attrs["table"], np.int64)
            vals[n.id] = t[v % mod] % mod
        elif n.op in ("reshape", "concat"):
            vals[n.id] = vals[n.inputs[0]]
        else:
            raise ValueError(n.op)
    return {k: np.asarray(v) % mod for k, v in vals.items()}


# --------------------------------------------------------------------------
# encrypted executor
# --------------------------------------------------------------------------

def eval_linear_ct_op(n, vals: dict, p: TFHEParams):
    """Evaluate one PBS-free IR node on ciphertext tensors (LPU work:
    add/sub/addc/mulc/linear/reshape/concat).  Returns the result array,
    or None if `n` is not a linear op.  Shared by `FheExecutor` and
    `repro.serve.IrInterpreter` so their linear semantics cannot
    diverge."""
    delta = p.delta
    if n.op == "add":
        return lwe.add(vals[n.inputs[0]], vals[n.inputs[1]])
    if n.op == "sub":
        return lwe.sub(vals[n.inputs[0]], vals[n.inputs[1]])
    if n.op == "addc":
        c = torus.encode(jnp.asarray(
            np.asarray(n.attrs["const"], np.int64).reshape(-1)
            % (1 << p.width), dtype=U64), delta)
        x = vals[n.inputs[0]]
        c = jnp.broadcast_to(c, x.shape[:-1])
        return x.at[..., -1].add(c)
    if n.op == "mulc":
        c = np.asarray(n.attrs["const"], np.int64).reshape(-1)
        return vals[n.inputs[0]] * jnp.asarray(
            c, jnp.int64)[:, None].astype(U64)
    if n.op == "linear":
        W = jnp.asarray(np.asarray(n.attrs["W"], np.int64))
        x = vals[n.inputs[0]]                      # (in, big_n+1)
        y = jnp.einsum("io,id->od", W.astype(U64), x)
        if n.attrs.get("bias") is not None:
            b = torus.encode(jnp.asarray(
                np.asarray(n.attrs["bias"], np.int64).reshape(-1)
                % (1 << p.width), U64), delta)
            y = y.at[..., -1].add(b)
        return y
    if n.op in ("reshape", "concat"):
        return vals[n.inputs[0]]
    return None


class FheExecutor:
    """Runs a graph on real ciphertexts via the batched engine."""

    def __init__(self, ctx, *, ks_dedup: bool = True, acc_dedup: bool = True):
        self.ctx = ctx                      # TFHEContext (keys + params)
        self.params: TFHEParams = ctx.params
        self.ks_dedup = ks_dedup
        self.acc_dedup = acc_dedup
        self.stats = {"pbs": 0, "keyswitch": 0, "lut_polys": 0}
        self._lut_cache: dict = {}

    # -- client side --------------------------------------------------------
    def encrypt_inputs(self, key: jax.Array, inputs: list) -> list:
        out = []
        for i, arr in enumerate(inputs):
            key, sub = jax.random.split(key)
            out.append(self.ctx.encrypt(sub, np.asarray(arr).reshape(-1)))
        return out

    def decrypt(self, ct):
        return np.asarray(self.ctx.decrypt(ct))

    # -- helpers --------------------------------------------------------------
    def _lut_poly(self, table: np.ndarray):
        key = table.tobytes() if self.acc_dedup else object()
        if key not in self._lut_cache:
            self._lut_cache[key] = glwe.make_lut_poly(
                jnp.asarray(table, U64), self.params)
            self.stats["lut_polys"] += 1
        return self._lut_cache[key]

    def _pbs(self, cts, table, small_cache_key, ks_cache):
        """PBS with the KS-first order so key-switch results are reusable."""
        p = self.params
        if self.ks_dedup and small_cache_key in ks_cache:
            small = ks_cache[small_cache_key]
        else:
            small = batch_mod.keyswitch_batch(cts, self.ctx.ksk, p)
            self.stats["keyswitch"] += int(cts.shape[0])
            ks_cache[small_cache_key] = small
        ms = lwe.mod_switch(small, p.log2_N + 1)
        poly = self._lut_poly(table)
        luts = glwe.trivial(jnp.broadcast_to(poly, (cts.shape[0], p.N)), p.k)
        acc = batch_mod.blind_rotate_batch(luts, ms, self.ctx.bsk_f, p)
        self.stats["pbs"] += int(cts.shape[0])
        return glwe.sample_extract(acc)

    # -- run ------------------------------------------------------------------
    def run(self, g: Graph, enc_inputs: list) -> dict:
        vals: dict = {}
        ks_cache: dict = {}
        it = iter(enc_inputs)
        for n in g.nodes:
            if n.op == "input":
                vals[n.id] = next(it)
                continue
            out = eval_linear_ct_op(n, vals, self.params)
            if out is not None:
                vals[n.id] = out
            elif n.op == "lut":
                vals[n.id] = self._pbs(vals[n.inputs[0]],
                                       np.asarray(n.attrs["table"]),
                                       n.inputs[0], ks_cache)
            else:
                raise ValueError(n.op)
        return vals
