"""FHE-ML bridge: post-training quantization of model-zoo blocks, lowering
to the FHE IR, and real encrypted execution on the JAX TFHE engine —
the paper's GPT-2-under-FHE demonstration at laptop scale.

Two activation representations (see docs/ARCHITECTURE.md):

  narrow-LUT  `QuantSpec` affine activations in one width-bit ciphertext,
              requant PBS per layer (`lower_mlp`, `lower_gpt2_block`).
  radix       `RadixQuantSpec` 16/32-bit two's-complement activations as
              digit vectors; exact `radix_linear`/`radix_relu` layers
              (`lower_mlp_radix`, `lower_gpt2_block_radix`) that run on
              every `repro.api` backend, including the multi-tenant
              serving runtime.
"""
from repro.fhe_ml.quantize import (QuantSpec, RadixQuantSpec,  # noqa: F401
                                   calibrate_radix, check_radix_range,
                                   dequantize, dequantize_radix,
                                   quantize_affine, quantize_to_radix)
from repro.fhe_ml.lower import (lower_gpt2_block,  # noqa: F401
                                lower_gpt2_block_radix, lower_mlp,
                                lower_mlp_radix)
from repro.fhe_ml.executor import FheExecutor  # noqa: F401
