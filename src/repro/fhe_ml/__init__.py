"""FHE-ML bridge: post-training quantization of model-zoo blocks, lowering
to the FHE IR, and real encrypted execution on the JAX TFHE engine —
the paper's GPT-2-under-FHE demonstration at laptop scale."""
from repro.fhe_ml.quantize import QuantSpec, quantize_affine, dequantize  # noqa: F401
from repro.fhe_ml.lower import lower_mlp, lower_gpt2_block  # noqa: F401
from repro.fhe_ml.executor import FheExecutor  # noqa: F401
