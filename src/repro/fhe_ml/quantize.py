"""Post-training quantization for FHE execution.

Two schemes live here:

*Affine* (Concrete-ML style, the narrow-LUT path): activations and
weights quantize to `width`-bit unsigned integers with per-tensor
scale/zero-point; matmul accumulators re-quantize through a LUT (the
"requant" PBS every FHE DNN layer ends with).  `width` is the PBS
plaintext window, so activations top out at a few bits.

*Radix* (the wide-activation path): activations quantize onto W-bit
two's-complement radix integers (`repro.core.integer.RadixSpec` digit
vectors, W = 16/32), symmetric around zero so negation/relu keep their
two's-complement meaning.  Linear layers run EXACTLY in integers
(`radix_linear` nodes) — no requant LUT, no per-layer precision loss —
as long as every intermediate magnitude stays below 2^(W-1); the scale
is therefore chosen against the lowered block's accumulation headroom
(`calibrate_radix(..., qmax=...)`) and `check_radix_range` is the
compile-time certificate that the bound holds.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    width: int
    scale: float
    zero: int

    @property
    def qmax(self) -> int:
        return (1 << self.width) - 1


def calibrate(x: np.ndarray, width: int) -> QuantSpec:
    lo, hi = float(np.min(x)), float(np.max(x))
    lo = min(lo, 0.0)
    hi = max(hi, lo + 1e-8)
    scale = (hi - lo) / ((1 << width) - 1)
    zero = int(round(-lo / scale))
    return QuantSpec(width, scale, zero)


def quantize_affine(x: np.ndarray, spec: QuantSpec) -> np.ndarray:
    q = np.round(x / spec.scale) + spec.zero
    return np.clip(q, 0, spec.qmax).astype(np.int64)


def dequantize(q: np.ndarray, spec: QuantSpec) -> np.ndarray:
    return (q.astype(np.float64) - spec.zero) * spec.scale


# ---------------------------------------------------------------------------
# radix quantization (16/32-bit encrypted activations)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RadixQuantSpec:
    """Symmetric quantization onto W-bit two's-complement radix integers.

    float x maps to q = round(x / scale), a signed integer encrypted as
    a `bits`-wide little-endian digit vector of `msg_bits`-bit digits
    (`repro.core.integer.RadixSpec` layout — msg_bits must divide bits
    and satisfy the parameter set's 2*msg_bits <= width carry budget).
    There is no zero-point: zero maps to zero, so `radix_relu`'s
    two's-complement sign test IS the float relu.

    scale is chosen by `calibrate_radix` against the headroom the
    lowered block needs (its `input_qmax`), not against the full
    2^(bits-1) range — integer linear algebra is exact only while no
    intermediate wraps past 2^(bits-1).  The calibrated cap is RECORDED
    on the spec (`qmax_cal`), and `quantize_to_radix` saturates at it:
    an out-of-calibration serving-time input clips to the certified
    range instead of silently voiding the overflow certificate.
    """
    bits: int
    msg_bits: int
    scale: float
    qmax_cal: int | None = None       # calibrated magnitude cap

    def __post_init__(self):
        assert self.bits % self.msg_bits == 0, (
            "integer width must be a whole number of digits")

    @property
    def n_digits(self) -> int:
        return self.bits // self.msg_bits

    @property
    def modulus(self) -> int:
        return 1 << self.bits

    @property
    def qmax(self) -> int:
        """Largest representable magnitude (two's-complement symmetric)."""
        return (1 << (self.bits - 1)) - 1

    @property
    def clip_max(self) -> int:
        """The quantization saturation point: the calibrated cap when
        one was recorded, else the full two's-complement range."""
        return self.qmax_cal if self.qmax_cal is not None else self.qmax


def calibrate_radix(x: np.ndarray, bits: int, msg_bits: int,
                    qmax: int | None = None) -> RadixQuantSpec:
    """Choose the radix scale for calibration data `x`.

    qmax caps the quantized magnitude; pass the lowered block's
    `input_qmax` (from `lower_mlp_radix` / `lower_gpt2_block_radix`
    meta) so the block's worst-case accumulators provably fit in
    2^(bits-1) — the radix analogue of the affine path's requant-LUT
    range discipline.  Defaults to the full two's-complement range.
    """
    amax = float(np.max(np.abs(x))) if np.size(x) else 0.0
    amax = max(amax, 1e-12)
    cap = int(qmax) if qmax is not None else (1 << (bits - 1)) - 1
    assert 1 <= cap < (1 << (bits - 1)), cap
    return RadixQuantSpec(bits, msg_bits, amax / cap, qmax_cal=cap)


def quantize_to_radix(x: np.ndarray, rq: RadixQuantSpec) -> np.ndarray:
    """float -> signed integers (int64), saturating at the CALIBRATED
    cap (`rq.clip_max`) so out-of-calibration inputs cannot exceed the
    magnitude the lowering's range certificate was proven for.  Values
    are SIGNED here; the client encrypts them mod 2^bits (two's
    complement) digit by digit."""
    cap = rq.clip_max
    q = np.round(np.asarray(x, np.float64) / rq.scale)
    return np.clip(q, -cap, cap).astype(np.int64)


def dequantize_radix(q: np.ndarray, rq: RadixQuantSpec) -> np.ndarray:
    """Decrypted residues mod 2^bits -> floats (two's-complement decode
    then * scale).  Accepts signed values too (they reduce mod 2^bits
    first, so both raw decrypts and oracle integers round-trip)."""
    q = np.asarray(q, np.int64) % rq.modulus
    signed = np.where(q >= rq.modulus // 2, q - rq.modulus, q)
    return signed.astype(np.float64) * rq.scale


def check_radix_range(bits: int, bound: float, what: str = "value") -> None:
    """The radix range certificate: raise OverflowError unless the
    worst-case magnitude `bound` fits two's-complement `bits`-bit
    integers.  Mod-2^bits digit arithmetic silently wraps past
    2^(bits-1) — relu would then flip sign and decrypted outputs would
    diverge from the float model, so lowerings call this on every
    intermediate interval bound before emitting a graph."""
    if bound >= float(1 << (bits - 1)):
        raise OverflowError(
            f"{what} bound {bound:g} overflows signed {bits}-bit radix "
            f"range (< {1 << (bits - 1)}): widen `bits` or narrow the "
            f"input quantization (lower `qmax` in calibrate_radix)")


def requant_table(in_scale: float, in_zero: float, out: QuantSpec,
                  in_width: int, fn=None) -> np.ndarray:
    """LUT mapping an accumulator value (in_width bits) to the next
    layer's quantized activation, optionally through `fn` (e.g. GELU)."""
    n = 1 << in_width
    xs = (np.arange(n, dtype=np.float64) - in_zero) * in_scale
    if fn is not None:
        xs = fn(xs)
    q = np.round(xs / out.scale) + out.zero
    return np.clip(q, 0, out.qmax).astype(np.uint64)
