"""Post-training affine quantization (Concrete-ML style).

Activations and weights quantize to `width`-bit unsigned integers with
per-tensor scale/zero-point; matmul accumulators re-quantize through a
LUT (the "requant" PBS every FHE DNN layer ends with).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    width: int
    scale: float
    zero: int

    @property
    def qmax(self) -> int:
        return (1 << self.width) - 1


def calibrate(x: np.ndarray, width: int) -> QuantSpec:
    lo, hi = float(np.min(x)), float(np.max(x))
    lo = min(lo, 0.0)
    hi = max(hi, lo + 1e-8)
    scale = (hi - lo) / ((1 << width) - 1)
    zero = int(round(-lo / scale))
    return QuantSpec(width, scale, zero)


def quantize_affine(x: np.ndarray, spec: QuantSpec) -> np.ndarray:
    q = np.round(x / spec.scale) + spec.zero
    return np.clip(q, 0, spec.qmax).astype(np.int64)


def dequantize(q: np.ndarray, spec: QuantSpec) -> np.ndarray:
    return (q.astype(np.float64) - spec.zero) * spec.scale


def requant_table(in_scale: float, in_zero: float, out: QuantSpec,
                  in_width: int, fn=None) -> np.ndarray:
    """LUT mapping an accumulator value (in_width bits) to the next
    layer's quantized activation, optionally through `fn` (e.g. GELU)."""
    n = 1 << in_width
    xs = (np.arange(n, dtype=np.float64) - in_zero) * in_scale
    if fn is not None:
        xs = fn(xs)
    q = np.round(xs / out.scale) + out.zero
    return np.clip(q, 0, out.qmax).astype(np.uint64)
