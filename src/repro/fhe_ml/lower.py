"""Lower quantized NN blocks to the FHE IR.

Two lowering families:

*Narrow-LUT* (Concrete-ML style, `lower_mlp` / `lower_gpt2_block`):
activations are single width-bit ciphertexts; every layer ends in a
requant PBS.  RANGE DISCIPLINE: every value entering a LUT must lie in
[0, 2^width) — one padding bit — otherwise programmable bootstrapping
negacyclically flips the result (dec = 2^w - T[x]).  Lowerings keep
signed accumulators as OFFSET-shifted unsigned values
(offset = 2^(width-1)) and size weights / activation widths so the
bound holds; `executor.interpret(..., check_range=True)` verifies it on
every run.

*Quantize-to-radix* (`lower_mlp_radix` / `lower_gpt2_block_radix`): the
paper's 16/32-bit encrypted-activation path.  Activations are radix
digit vectors (`repro.core.integer`), linear layers lower to tensor-
level `radix_linear` nodes (exact integer matmul — NO requant LUT) and
the activation is two's-complement `radix_relu`.  RANGE DISCIPLINE:
interval arithmetic propagates worst-case magnitudes through the block
and `quantize.check_radix_range` certifies every intermediate stays
below 2^(bits-1); the largest input magnitude that passes is returned
as meta["input_qmax"], which `calibrate_radix` turns into the
quantization scale.  These graphs carry ready-made IntSpec in/out specs
so `Session.compile(graph, **specs)` runs them on ANY backend —
including `backend="serve"`, where one block's radix rounds fuse with
every other in-flight request's (encrypted-LLM traffic on the
multi-tenant runtime).
"""
from __future__ import annotations

import numpy as np

from repro.api.tracing import IntSpec
from repro.compiler.ir import Graph, FheTensor, trace
from repro.fhe_ml.quantize import QuantSpec, check_radix_range


def _gelu(x):
    return x * 0.5 * (1 + np.tanh(0.7978845608 * (x + 0.044715 * x ** 3)))


def _clip_w(w, mag=1):
    """Quantize weights to small ints {-mag..mag}; returns (W, scale)."""
    s = (np.max(np.abs(w)) + 1e-9) / mag
    return np.clip(np.round(w / s), -mag, mag).astype(np.int64), s


def _requant_lut(width: int, offset: int, acc_scale: float, out_qmax: int,
                 out_zero: int, out_scale: float, fn=None) -> np.ndarray:
    """Index i = acc + offset; signed acc = i - offset; output quantized
    to [0, out_qmax]."""
    n = 1 << width
    acc = np.arange(n) - offset
    xs = acc * acc_scale
    if fn is not None:
        xs = fn(xs)
    q = np.round(xs / out_scale) + out_zero
    return np.clip(q, 0, out_qmax).astype(np.uint64)


def lower_mlp(w1: np.ndarray, w2: np.ndarray, in_spec: QuantSpec,
              width: int, act="gelu"):
    """x -> requant(GELU(x@W1)) @ W2 -> requant, range-safe for `width`.

    Bounds: inputs q in [0, in_qmax], weights in {-1,0,1}:
      |acc1| <= in_qmax * d_in   and   |acc2| <= h_qmax * d_h,
    both required < 2^(width-1).
    """
    offset = 1 << (width - 1)
    fn = _gelu if act == "gelu" else (lambda x: np.maximum(x, 0))
    W1, s1 = _clip_w(w1)
    W2, s2 = _clip_w(w2)
    d_in, d_h = W1.shape

    h_qmax = 3                               # 2-bit hidden activations
    assert in_spec.qmax * d_in < offset, "acc1 overflows the padding bit"
    assert h_qmax * d_h < offset, "acc2 overflows the padding bit"

    acc1_scale = in_spec.scale * s1
    h_scale = acc1_scale * in_spec.qmax * d_in / (2 * h_qmax)
    h_spec = QuantSpec(width, h_scale, h_qmax // 2 + 1)
    acc2_scale = h_scale * s2
    out_qmax = (1 << width) - 1
    out_scale = acc2_scale * h_qmax * d_h / out_qmax
    out_spec = QuantSpec(width, out_scale, offset // 2)

    t1 = _requant_lut(width, offset, acc1_scale, h_qmax, h_spec.zero,
                      h_spec.scale, fn)
    t2 = _requant_lut(width, offset, acc2_scale, out_qmax, out_spec.zero,
                      out_spec.scale, None)

    def f(x):
        a = x.linear(W1) + (offset - in_spec.zero * W1.sum(axis=0))
        h = a.lut(t1, name="gelu_requant")
        b = h.linear(W2) + (offset - h_spec.zero * W2.sum(axis=0))
        return b.lut(t2, name="out_requant")
    g = trace(f, (d_in,))
    meta = {"in_spec": in_spec, "h_spec": h_spec, "out_spec": out_spec,
            "W1": W1, "W2": W2, "s1": s1, "s2": s2, "offset": offset}
    return g, meta


# ---------------------------------------------------------------------------
# quantize-to-radix lowerings (16/32-bit encrypted activations)
# ---------------------------------------------------------------------------

def _interval_linear(lo, hi, W):
    """Interval bounds of x @ W for elementwise x in [lo, hi]."""
    Wp, Wn = np.clip(W, 0, None), np.clip(-W, 0, None)
    return lo @ Wp - hi @ Wn, hi @ Wp - lo @ Wn


def _interval_mul(la, ha, lb, hb):
    """Interval bounds of the elementwise product a * b."""
    cands = np.stack([la * lb, la * hb, ha * lb, ha * hb])
    return cands.min(axis=0), cands.max(axis=0)


def _max_input_qmax(bound_fn, bits: int, what: str) -> int:
    """Largest integer input magnitude A whose worst-case intermediate
    (bound_fn(A), monotone in A) stays below 2^(bits-1)."""
    half = float(1 << (bits - 1))
    check_radix_range(bits, bound_fn(1), what)     # raises if even A=1 fails
    a = 1
    while bound_fn(2 * a) < half:
        a *= 2
    lo, hi = a, 2 * a
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if bound_fn(mid) < half:
            lo = mid
        else:
            hi = mid
    return lo


def lower_mlp_radix(w1: np.ndarray, w2: np.ndarray, bits: int,
                    msg_bits: int):
    """x -> relu(x @ W1) @ W2 on `bits`-wide radix activations.

    Weights quantize to {-1, 0, 1} (scales s1/s2 ride along in meta);
    the linear layers are EXACT integer `radix_linear` nodes and the
    activation is two's-complement `radix_relu` — no requant LUT, so
    the only approximation error is the input quantization itself.
    Returns (graph, meta) with:

      input_qmax   largest |q| the interval certificate admits — pass to
                   `calibrate_radix(x, bits, msg_bits, qmax=...)`
      in_specs / out_specs   IntSpec lists for `Session.compile`
      int_fn       exact integer oracle q -> q_out
      float_fn     the clipped-weight float model x -> y
      out_scale_mul  s1*s2: y_hat = dequant(q_out) with scale
                   rq.scale * out_scale_mul
      tol_fn       rq -> per-output |y_hat - float_fn(x)| bound
    """
    W1, s1 = _clip_w(w1)
    W2, s2 = _clip_w(w2)
    d_in, d_h = W1.shape
    d_out = W2.shape[1]
    n_digits = bits // msg_bits

    def bound(a):
        lo, hi = np.full(d_in, -float(a)), np.full(d_in, float(a))
        l1, h1 = _interval_linear(lo, hi, W1)
        lr, hr = np.clip(l1, 0, None), np.clip(h1, 0, None)
        l2, h2 = _interval_linear(lr, hr, W2)
        return float(max(np.abs(np.concatenate([l1, h1, l2, h2])).max(), a))

    input_qmax = _max_input_qmax(bound, bits, "MLP accumulator")
    check_radix_range(bits, bound(input_qmax), "MLP accumulator")

    def f(x):
        return x.radix_linear(W1, msg_bits).radix_relu(msg_bits) \
                .radix_linear(W2, msg_bits)
    g = trace(f, (d_in, n_digits))

    def int_fn(q):
        return np.maximum(np.asarray(q, np.int64) @ W1, 0) @ W2

    def float_fn(xf):
        return np.maximum(np.asarray(xf, np.float64) @ (W1 * s1), 0) \
            @ (W2 * s2)

    def tol_fn(rq):
        # |dx| <= scale/2 per input propagates through |W1| then |W2|
        # (relu is 1-Lipschitz); + scale slack for the clip at qmax
        units = np.ones(d_in) @ np.abs(W1) @ np.abs(W2)
        return rq.scale * s1 * s2 * (0.5 * units + 1e-9) + 1e-12

    meta = {"W1": W1, "W2": W2, "s1": s1, "s2": s2,
            "input_qmax": input_qmax,
            "in_specs": [IntSpec(bits, msg_bits, (d_in,))],
            "out_specs": [IntSpec(bits, msg_bits, (d_out,))],
            "int_fn": int_fn, "float_fn": float_fn,
            "out_scale_mul": s1 * s2, "tol_fn": tol_fn}
    return g, meta


def lower_gpt2_block_radix(d: int, bits: int, msg_bits: int, seed=0):
    """Reduced single-head GPT-2-style block on `bits`-wide radix
    activations: ct*ct attention via exact `radix_mul`, ReLU MLP — the
    encrypted-LLM workload the serving runtime carries (ISSUE 4 / the
    paper's GPT-2 demonstration on wide encrypted activations).

    Unlike the narrow-LUT `lower_gpt2_block`, nothing here requantizes:
    q/k/v projections, attention products and the MLP all run as exact
    integer radix ops, and the interval certificate proves every
    intermediate fits signed `bits`-bit integers for inputs up to
    meta["input_qmax"].  Output values carry scale rq.scale**3 (two
    ct*ct products), exposed as meta["out_scale_pow"].

    Returns (graph, meta); run it with::

        g, meta = lower_gpt2_block_radix(4, bits=16, msg_bits=2)
        rq = calibrate_radix(x, 16, 2, qmax=meta["input_qmax"])
        prog = sess.compile(g, meta["in_specs"], meta["out_specs"])
    """
    rng = np.random.default_rng(seed)
    Wq = rng.integers(-1, 2, (d, d)).astype(np.int64)
    Wk = rng.integers(-1, 2, (d, d)).astype(np.int64)
    Wv = rng.integers(-1, 2, (d, d)).astype(np.int64)
    W1 = rng.integers(-1, 2, (d, 2 * d)).astype(np.int64)
    W2 = rng.integers(-1, 2, (2 * d, d)).astype(np.int64)
    n_digits = bits // msg_bits

    def bound(a):
        lo, hi = np.full(d, -float(a)), np.full(d, float(a))
        lq, hq = _interval_linear(lo, hi, Wq)
        lk, hk = _interval_linear(lo, hi, Wk)
        lv, hv = _interval_linear(lo, hi, Wv)
        ls, hs = _interval_mul(lq, hq, lk, hk)        # attention scores
        lp, hp = _interval_mul(ls, hs, lv, hv)        # score-weighted v
        l1, h1 = _interval_linear(lp, hp, W1)
        lr, hr = np.clip(l1, 0, None), np.clip(h1, 0, None)
        l2, h2 = _interval_linear(lr, hr, W2)
        every = np.concatenate([lq, hq, lk, hk, lv, hv, ls, hs,
                                lp, hp, l1, h1, l2, h2])
        return float(max(np.abs(every).max(), a))

    input_qmax = _max_input_qmax(bound, bits, "GPT-2 block accumulator")
    check_radix_range(bits, bound(input_qmax), "GPT-2 block accumulator")

    def f(x):
        q = x.radix_linear(Wq, msg_bits)
        k = x.radix_linear(Wk, msg_bits)
        v = x.radix_linear(Wv, msg_bits)
        s = q.radix_mul(k, msg_bits)                  # ct*ct attention
        pv = s.radix_mul(v, msg_bits)
        h = pv.radix_linear(W1, msg_bits).radix_relu(msg_bits)
        return h.radix_linear(W2, msg_bits)
    g = trace(f, (d, n_digits))

    def int_fn(q):
        q = np.asarray(q, np.int64)
        qq, kk, vv = q @ Wq, q @ Wk, q @ Wv
        pv = (qq * kk) * vv
        return np.maximum(pv @ W1, 0) @ W2

    def float_fn(xf):
        xf = np.asarray(xf, np.float64)
        qq, kk, vv = xf @ Wq, xf @ Wk, xf @ Wv
        pv = (qq * kk) * vv
        return np.maximum(pv @ W1, 0) @ W2

    meta = {"Wq": Wq, "Wk": Wk, "Wv": Wv, "W1": W1, "W2": W2,
            "input_qmax": input_qmax,
            "in_specs": [IntSpec(bits, msg_bits, (d,))],
            "out_specs": [IntSpec(bits, msg_bits, (d,))],
            "int_fn": int_fn, "float_fn": float_fn, "out_scale_pow": 3}
    return g, meta


def lower_gpt2_block(d: int, in_spec: QuantSpec, width: int, seed=0):
    """Reduced single-head GPT-2-style block under FHE: ct*ct attention
    via requantized square LUTs, GELU MLP.  All LUT inputs provably in
    [0, 2^width) for 3-bit activations and {-1,0,1} weights (see asserts).
    """
    rng = np.random.default_rng(seed)
    offset = 1 << (width - 1)
    n = 1 << width
    a_qmax = 7                               # 3-bit activation lattice

    Wq = rng.integers(-1, 2, (d, d)).astype(np.int64)
    Wk = rng.integers(-1, 2, (d, d)).astype(np.int64)
    Wv = rng.integers(-1, 2, (d, d)).astype(np.int64)
    W1 = rng.integers(-1, 2, (d, 2 * d)).astype(np.int64)
    W2 = rng.integers(-1, 2, (2 * d, d)).astype(np.int64)
    assert in_spec.qmax * d < offset
    assert a_qmax * d < offset and a_qmax * 2 * d < 2 * offset

    # 3-bit requant of a signed accumulator
    req3 = np.clip((np.arange(n) - offset) // 8 + 4, 0, a_qmax).astype(np.uint64)
    # requantized square: ((i-offset)^2 >> 3), clipped to 3 bits
    sq3 = np.clip(((np.arange(n) - offset) ** 2) >> 3, 0, a_qmax).astype(np.uint64)
    # gelu-ish on the shifted lattice, 2-bit output (keeps acc2 in range)
    gel2 = np.clip(np.round(_gelu((np.arange(n) - offset) / 8.0)) + 1,
                   0, 3).astype(np.uint64)

    def ct_mul(a: FheTensor, b: FheTensor):
        """Square-trick product, requantized to 3 bits.
        inputs in [0,7] => a+b in [0,14], a-b in [-7,7]: both +offset are
        in range; sq3 outputs [0,7]; s-dif in [-7,7] => final in range."""
        s = (a + b + (offset - 7)).lut(sq3, name="sq+")
        dif = (a - b + offset).lut(sq3, name="sq-")
        return (s - dif + offset).lut(req3, name="req_mul")

    def f(x):
        q = (x.linear(Wq) + offset).lut(req3, name="req_q")
        k = (x.linear(Wk) + offset).lut(req3, name="req_k")
        v = (x.linear(Wv) + offset).lut(req3, name="req_v")
        s = ct_mul(q, k)
        pv = ct_mul(s, v)
        h = (pv.linear(W1) + offset).lut(gel2, name="gelu")
        o = (h.linear(W2) + offset).lut(req3, name="req_out")
        return o
    g = trace(f, (d,))
    return g, {"Wq": Wq, "Wk": Wk, "Wv": Wv, "W1": W1, "W2": W2,
               "offset": offset}
