"""Lower quantized NN blocks to the FHE IR (Concrete-ML style).

RANGE DISCIPLINE (what Concrete's optimizer guarantees at compile time):
every value entering a LUT must lie in [0, 2^width) — one padding bit —
otherwise programmable bootstrapping negacyclically flips the result
(dec = 2^w - T[x]).  Lowerings here keep signed accumulators as
OFFSET-shifted unsigned values (offset = 2^(width-1)) and size weights /
activation widths so the bound holds; `executor.interpret(...,
check_range=True)` verifies it on every run.
"""
from __future__ import annotations

import numpy as np

from repro.compiler.ir import Graph, FheTensor, trace
from repro.fhe_ml.quantize import QuantSpec


def _gelu(x):
    return x * 0.5 * (1 + np.tanh(0.7978845608 * (x + 0.044715 * x ** 3)))


def _clip_w(w, mag=1):
    """Quantize weights to small ints {-mag..mag}; returns (W, scale)."""
    s = (np.max(np.abs(w)) + 1e-9) / mag
    return np.clip(np.round(w / s), -mag, mag).astype(np.int64), s


def _requant_lut(width: int, offset: int, acc_scale: float, out_qmax: int,
                 out_zero: int, out_scale: float, fn=None) -> np.ndarray:
    """Index i = acc + offset; signed acc = i - offset; output quantized
    to [0, out_qmax]."""
    n = 1 << width
    acc = np.arange(n) - offset
    xs = acc * acc_scale
    if fn is not None:
        xs = fn(xs)
    q = np.round(xs / out_scale) + out_zero
    return np.clip(q, 0, out_qmax).astype(np.uint64)


def lower_mlp(w1: np.ndarray, w2: np.ndarray, in_spec: QuantSpec,
              width: int, act="gelu"):
    """x -> requant(GELU(x@W1)) @ W2 -> requant, range-safe for `width`.

    Bounds: inputs q in [0, in_qmax], weights in {-1,0,1}:
      |acc1| <= in_qmax * d_in   and   |acc2| <= h_qmax * d_h,
    both required < 2^(width-1).
    """
    offset = 1 << (width - 1)
    fn = _gelu if act == "gelu" else (lambda x: np.maximum(x, 0))
    W1, s1 = _clip_w(w1)
    W2, s2 = _clip_w(w2)
    d_in, d_h = W1.shape

    h_qmax = 3                               # 2-bit hidden activations
    assert in_spec.qmax * d_in < offset, "acc1 overflows the padding bit"
    assert h_qmax * d_h < offset, "acc2 overflows the padding bit"

    acc1_scale = in_spec.scale * s1
    h_scale = acc1_scale * in_spec.qmax * d_in / (2 * h_qmax)
    h_spec = QuantSpec(width, h_scale, h_qmax // 2 + 1)
    acc2_scale = h_scale * s2
    out_qmax = (1 << width) - 1
    out_scale = acc2_scale * h_qmax * d_h / out_qmax
    out_spec = QuantSpec(width, out_scale, offset // 2)

    t1 = _requant_lut(width, offset, acc1_scale, h_qmax, h_spec.zero,
                      h_spec.scale, fn)
    t2 = _requant_lut(width, offset, acc2_scale, out_qmax, out_spec.zero,
                      out_spec.scale, None)

    def f(x):
        a = x.linear(W1) + (offset - in_spec.zero * W1.sum(axis=0))
        h = a.lut(t1, name="gelu_requant")
        b = h.linear(W2) + (offset - h_spec.zero * W2.sum(axis=0))
        return b.lut(t2, name="out_requant")
    g = trace(f, (d_in,))
    meta = {"in_spec": in_spec, "h_spec": h_spec, "out_spec": out_spec,
            "W1": W1, "W2": W2, "s1": s1, "s2": s2, "offset": offset}
    return g, meta


def lower_gpt2_block(d: int, in_spec: QuantSpec, width: int, seed=0):
    """Reduced single-head GPT-2-style block under FHE: ct*ct attention
    via requantized square LUTs, GELU MLP.  All LUT inputs provably in
    [0, 2^width) for 3-bit activations and {-1,0,1} weights (see asserts).
    """
    rng = np.random.default_rng(seed)
    offset = 1 << (width - 1)
    n = 1 << width
    a_qmax = 7                               # 3-bit activation lattice

    Wq = rng.integers(-1, 2, (d, d)).astype(np.int64)
    Wk = rng.integers(-1, 2, (d, d)).astype(np.int64)
    Wv = rng.integers(-1, 2, (d, d)).astype(np.int64)
    W1 = rng.integers(-1, 2, (d, 2 * d)).astype(np.int64)
    W2 = rng.integers(-1, 2, (2 * d, d)).astype(np.int64)
    assert in_spec.qmax * d < offset
    assert a_qmax * d < offset and a_qmax * 2 * d < 2 * offset

    # 3-bit requant of a signed accumulator
    req3 = np.clip((np.arange(n) - offset) // 8 + 4, 0, a_qmax).astype(np.uint64)
    # requantized square: ((i-offset)^2 >> 3), clipped to 3 bits
    sq3 = np.clip(((np.arange(n) - offset) ** 2) >> 3, 0, a_qmax).astype(np.uint64)
    # gelu-ish on the shifted lattice, 2-bit output (keeps acc2 in range)
    gel2 = np.clip(np.round(_gelu((np.arange(n) - offset) / 8.0)) + 1,
                   0, 3).astype(np.uint64)

    def ct_mul(a: FheTensor, b: FheTensor):
        """Square-trick product, requantized to 3 bits.
        inputs in [0,7] => a+b in [0,14], a-b in [-7,7]: both +offset are
        in range; sq3 outputs [0,7]; s-dif in [-7,7] => final in range."""
        s = (a + b + (offset - 7)).lut(sq3, name="sq+")
        dif = (a - b + offset).lut(sq3, name="sq-")
        return (s - dif + offset).lut(req3, name="req_mul")

    def f(x):
        q = (x.linear(Wq) + offset).lut(req3, name="req_q")
        k = (x.linear(Wk) + offset).lut(req3, name="req_k")
        v = (x.linear(Wv) + offset).lut(req3, name="req_v")
        s = ct_mul(q, k)
        pv = ct_mul(s, v)
        h = (pv.linear(W1) + offset).lut(gel2, name="gelu")
        o = (h.linear(W2) + offset).lut(req3, name="req_out")
        return o
    g = trace(f, (d,))
    return g, {"Wq": Wq, "Wk": Wk, "Wv": Wv, "W1": W1, "W2": W2,
               "offset": offset}
