"""Atomic, versioned checkpointing for arbitrary pytrees (no orbax).

Layout:  <dir>/step_<N>/   arrays.npz  tree.json   (+ .done marker)
Writes go to a tmp dir first and are renamed into place — a crash mid-save
never corrupts the latest checkpoint (fault-tolerance requirement).
Restore re-shards onto the CURRENT mesh (elastic restart: the device set
may have changed between save and load).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        leaves, treedef = _flatten(tree)
        arrays = {f"a{i}": np.asarray(jax.device_get(x)) for i, x in enumerate(leaves)}
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "tree.json"), "w") as f:
                json.dump({"treedef": str(treedef), "n": len(leaves),
                           "step": step}, f)
            with open(os.path.join(tmp, ".done"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    # -- restore ------------------------------------------------------------
    def latest_step(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and \
                    os.path.exists(os.path.join(self.dir, name, ".done")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, like, step: int | None = None, shardings=None):
        """`like` provides the pytree structure; values are replaced from
        disk and device_put with `shardings` (or like's shardings)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(data.files), "checkpoint/model mismatch"
        restored = []
        for i, ref in enumerate(leaves):
            arr = data[f"a{i}"]
            if shardings is not None:
                sh = jax.tree.leaves(shardings)[i]
                restored.append(jax.device_put(arr, sh))
            elif hasattr(ref, "sharding"):
                restored.append(jax.device_put(arr, ref.sharding))
            else:
                restored.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, restored), step

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
