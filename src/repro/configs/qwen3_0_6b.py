"""qwen3-0.6b [dense] — qk_norm, GQA kv=8, tied embeddings.

[hf:Qwen/Qwen3-8B (family); hf]  28L d_model=1024 16H (GQA kv=8)
d_ff=3072 vocab=151936, head_dim=128 (projected: 16*128 = 2048 != d_model).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    act="silu",
    gated_mlp=True,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, act="silu", gated_mlp=True, qk_norm=True,
        tie_embeddings=True, dtype="float32",
    )
