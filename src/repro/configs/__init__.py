"""Assigned architecture registry — importing this package registers all 10.

Each `<arch>.py` holds the exact published config plus `reduced()` — the
same family at smoke-test scale (small layers/width/experts/vocab).
"""
from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, REGISTRY, get, applicable_shapes  # noqa: F401

from repro.configs import (  # noqa: F401  (registration side effects)
    pixtral_12b,
    gemma_7b,
    starcoder2_15b,
    deepseek_coder_33b,
    qwen3_0_6b,
    recurrentgemma_2b,
    qwen2_moe_a2_7b,
    moonshot_v1_16b_a3b,
    mamba2_130m,
    musicgen_large,
)

ARCH_IDS = [
    "pixtral-12b", "gemma-7b", "starcoder2-15b", "deepseek-coder-33b",
    "qwen3-0.6b", "recurrentgemma-2b", "qwen2-moe-a2.7b",
    "moonshot-v1-16b-a3b", "mamba2-130m", "musicgen-large",
]
