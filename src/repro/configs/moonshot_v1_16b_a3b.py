"""moonshot-v1-16b-a3b [moe] — kimi/moonlight family, 64 routed top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
per-expert d_ff=1408, vocab=163840, head_dim=128, 2 shared experts.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    act="silu",
    gated_mlp=True,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    moe_d_ff=1408,
    moe_dispatch="gather",   # §Perf B: scatter/gather beats (T,E,C) einsum
    moe_capacity_factor=1.0,  # §Perf B iter 3: 20% smaller expert buffers
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b-reduced", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=256, act="silu", gated_mlp=True,
        moe_num_experts=8, moe_top_k=3, moe_num_shared=1, moe_d_ff=96, moe_capacity_factor=16.0,  # dropless: decode==prefill
        dtype="float32",
    )
