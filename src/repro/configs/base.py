"""Architecture configs for the assigned 10-arch pool + shape specs.

Every field is explicit (no HF dependency); values follow the assignment
table and the cited sources.  `repro.models.model.build(config)` turns a
config into init/apply/train/serve callables.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 => attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    act: str = "silu"           # silu | gelu
    gated_mlp: bool = True      # SwiGLU/GeGLU vs plain MLP
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    embed_scale: bool = False   # gemma-style sqrt(d_model) embedding scale
    tie_embeddings: bool = False

    # MoE
    moe_num_experts: int = 0    # routed experts
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0           # per-expert hidden
    moe_capacity_factor: float = 1.25  # large => dropless (exact routing)
    moe_dispatch: str = "einsum"       # einsum | gather  (§Perf B)

    # SSM (mamba2 / SSD)
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # layer pattern, cycled over depth: entries in
    # {"attn", "local", "rglru", "ssd"}; MLP follows every entry.
    layer_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048
    rglru_width: int = 0        # 0 => d_model

    # modality frontend stub (assignment: precomputed embeddings)
    frontend: str = "none"      # none | patch | frame
    frontend_dim: int = 0
    frontend_len: int = 0       # number of prefix positions fed by frontend

    # numeric
    dtype: str = "bfloat16"

    @property
    def attn_free(self) -> bool:
        return all(p == "ssd" for p in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (no full-attn KV cache)."""
        return all(p in ("ssd", "rglru", "local") for p in self.layer_pattern)

    @property
    def is_moe(self) -> bool:
        return self.moe_num_experts > 0

    def pattern_at(self, layer: int) -> str:
        return self.layer_pattern[layer % len(self.layer_pattern)]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn = sum(1 for i in range(L) if self.pattern_at(i) in ("attn", "local"))
        n_rglru = sum(1 for i in range(L) if self.pattern_at(i) == "rglru")
        n_ssd = sum(1 for i in range(L) if self.pattern_at(i) == "ssd")
        qkv = d * self.head_dim * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * self.head_dim * d
        per_layer += n_attn * qkv / max(L, 1)
        if n_rglru:
            di = d  # rg-lru width ~ d_model
            per_layer += n_rglru * (3 * d * di + 4 * di) / L
        if n_ssd:
            di = self.ssm_expand * d
            nh = di // self.ssm_head_dim
            per_layer += n_ssd * (d * (2 * di + 2 * self.ssm_state_dim + nh) + di * d) / L
        if self.is_moe:
            ff = (2 if self.gated_mlp else 1) * self.moe_d_ff + self.moe_d_ff
            per_expert = d * ff
            per_layer += (self.moe_num_experts + self.moe_num_shared) * per_expert \
                + d * self.moe_num_experts
        else:
            per_layer += d * self.d_ff * (3 if self.gated_mlp else 2)
        return int(emb + L * per_layer)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        qkv = d * self.head_dim * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * self.head_dim * d
        ff = (2 if self.gated_mlp else 1) * self.moe_d_ff + self.moe_d_ff
        active = (self.moe_top_k + self.moe_num_shared) * d * ff + d * self.moe_num_experts
        return int(emb + L * (qkv + active))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if name not in REGISTRY:
        import repro.configs  # noqa: F401  (triggers registration)
    return REGISTRY[name]


def applicable_shapes(cfg: ArchConfig) -> list:
    """Shape cells for this arch per the assignment contract."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")  # skip for pure full-attention archs
    return out
