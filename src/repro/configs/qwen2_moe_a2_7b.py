"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (GQA kv=16)
per-expert d_ff=1408, vocab=151936, head_dim=128.
Shared experts total hidden = 4 * 1408 = 5632 (matches HF config).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    act="silu",
    gated_mlp=True,
    moe_num_experts=60,
    moe_top_k=4,
    moe_num_shared=4,
    moe_d_ff=1408,
    moe_dispatch="gather",   # §Perf B: scatter/gather beats (T,E,C) einsum
    moe_capacity_factor=1.0,  # §Perf B iter 3: 20% smaller expert buffers
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b-reduced", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=256, act="silu", gated_mlp=True,
        moe_num_experts=8, moe_top_k=2, moe_num_shared=2, moe_d_ff=96, moe_capacity_factor=16.0,  # dropless: decode==prefill
        dtype="float32",
    )
