"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1) head_dim=256
d_ff=7680 vocab=256000; layer pattern (rglru, rglru, local) cycled,
local window 2048.  Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    act="gelu",
    gated_mlp=True,
    embed_scale=True,
    tie_embeddings=True,
    layer_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    rglru_width=2560,
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b-reduced", family="hybrid",
        num_layers=5, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=256, act="gelu", gated_mlp=True,
        embed_scale=True, tie_embeddings=True,
        layer_pattern=("rglru", "rglru", "local"), local_window=16,
        rglru_width=64, dtype="float32",
    )
