"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  24L d_model=768, ssm_state=128,
expand=2 (inner 1536), head_dim=64 (24 SSD heads), vocab=50280.
Attention-free => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,               # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # no separate MLP: SSD mixer only
    vocab_size=50280,
    tie_embeddings=True,
    layer_pattern=("ssd",),
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m-reduced", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=256, tie_embeddings=True, layer_pattern=("ssd",),
        ssm_state_dim=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
        dtype="float32",
    )
