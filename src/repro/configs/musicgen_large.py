"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32, full MHA) d_ff=8192
vocab=2048 (EnCodec codebook), head_dim=64.  The EnCodec frontend is a
STUB per the assignment: `input_specs()` feeds precomputed conditioning
frame embeddings (dim 768, e.g. T5 text conditioning) as a prefix.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    gated_mlp=False,
    frontend="frame",
    frontend_dim=768,
    frontend_len=64,
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large-reduced", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, act="gelu", gated_mlp=False,
        frontend="frame", frontend_dim=32, frontend_len=8,
        dtype="float32",
    )
