"""pixtral-12b [vlm] — Pixtral ViT frontend (stub) + Mistral-NeMo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072.
The vision frontend is a STUB per the assignment: `input_specs()` feeds
precomputed patch embeddings (dim 1024) for the image prefix.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000_000.0,
    frontend="patch",
    frontend_dim=1024,
    frontend_len=256,          # 256 patch tokens prefix
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b-reduced", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, act="silu", gated_mlp=True,
        frontend="patch", frontend_dim=32, frontend_len=8,
        dtype="float32",
    )
