"""gemma-7b [dense] — GeGLU, head_dim=256, tied embeddings, 256k vocab.

[arXiv:2403.08295; hf]  28L d_model=3072 16H (GQA kv=16) d_ff=24576.
Gemma conventions: sqrt(d_model) embedding scale, (1 + w) RMSNorm weights.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    act="gelu",
    gated_mlp=True,            # GeGLU
    embed_scale=True,
    tie_embeddings=True,
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=256, act="gelu", gated_mlp=True,
        embed_scale=True, tie_embeddings=True, dtype="float32",
    )
