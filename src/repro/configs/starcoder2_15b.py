"""starcoder2-15b [dense] — GQA kv=4, RoPE, non-gated GELU MLP.

[arXiv:2402.19173; hf]  40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, head_dim=128.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
    gated_mlp=False,           # classic MLP (StarCoder2 uses gelu MLP)
    rope_theta=100_000.0,
))


def reduced() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=256, vocab_size=256, act="gelu", gated_mlp=False,
        dtype="float32",
    )
