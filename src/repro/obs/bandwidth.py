"""Bandwidth ledger: bytes of key material streamed per fused PBS round
vs. the unfused counterfactual.

Taurus's central claim is that multi-bit FHE throughput is a memory-
bandwidth problem: a fused round streams the decomposed bootstrapping
key (and the key-switching key) ONCE for every participating request,
where a per-request server would stream it once per request (paper
§III-B / Fig. 13; MATCHA and HEAX make the same argument).  This ledger
makes that saving a first-class measured quantity instead of a slogan:
`FusedLutScheduler` accounts every dispatched group here, and the
`bsk_bytes_saved` column in BENCH_serve.json is read straight off the
snapshot.

Accounting model (per fused round over one engine group):

  streamed        = bsk_bytes + ksk_bytes          (one stream, everyone)
  counterfactual  = participants * (bsk_bytes + ksk_bytes)
                    (each of the `participants` blocked requests
                    dispatching its own lut_batch)
  saved           = counterfactual - streamed

Dedup savings are tracked separately as rows (`rows_logical` vs
`rows_dispatched`): dedup removes blind-rotation *work*, not key
streams, so it must not be conflated with the key-reuse column.
"""
from __future__ import annotations

import threading


def engine_key_bytes(engine) -> tuple:
    """(bsk_bytes, ksk_bytes) of an engine's evaluation keys as laid out
    in memory (the decomposed fourier BSK actually streamed per round)."""
    bsk, ksk = engine.bsk_f, engine.ksk
    return (int(bsk.size) * bsk.dtype.itemsize,
            int(ksk.size) * ksk.dtype.itemsize)


class BandwidthLedger:
    """Thread-safe accumulator for per-round key-traffic accounting."""

    def __init__(self):
        self._lock = threading.Lock()
        self.fused_rounds = 0
        self.participants = 0             # sum of round participant counts
        self.rows_logical = 0
        self.rows_dispatched = 0
        self.rows_padded = 0
        self.bsk_bytes_streamed = 0
        self.ksk_bytes_streamed = 0
        self.bsk_bytes_unfused = 0
        self.ksk_bytes_unfused = 0

    def account_round(self, *, participants: int, rows_logical: int,
                      rows_dispatched: int, rows_padded: int,
                      bsk_bytes: int, ksk_bytes: int) -> None:
        """Record one dispatched engine group of a fused round."""
        with self._lock:
            self.fused_rounds += 1
            self.participants += participants
            self.rows_logical += rows_logical
            self.rows_dispatched += rows_dispatched
            self.rows_padded += rows_padded
            self.bsk_bytes_streamed += bsk_bytes
            self.ksk_bytes_streamed += ksk_bytes
            self.bsk_bytes_unfused += participants * bsk_bytes
            self.ksk_bytes_unfused += participants * ksk_bytes

    @property
    def bsk_bytes_saved(self) -> int:
        return self.bsk_bytes_unfused - self.bsk_bytes_streamed

    @property
    def ksk_bytes_saved(self) -> int:
        return self.ksk_bytes_unfused - self.ksk_bytes_streamed

    @property
    def rows_deduped(self) -> int:
        return self.rows_logical - self.rows_dispatched

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "fused_rounds": self.fused_rounds,
                "participants": self.participants,
                "rows_logical": self.rows_logical,
                "rows_dispatched": self.rows_dispatched,
                "rows_padded": self.rows_padded,
                "rows_deduped": self.rows_logical - self.rows_dispatched,
                "bsk_bytes_streamed": self.bsk_bytes_streamed,
                "ksk_bytes_streamed": self.ksk_bytes_streamed,
                "bsk_bytes_unfused": self.bsk_bytes_unfused,
                "ksk_bytes_unfused": self.ksk_bytes_unfused,
                "bsk_bytes_saved":
                    self.bsk_bytes_unfused - self.bsk_bytes_streamed,
                "ksk_bytes_saved":
                    self.ksk_bytes_unfused - self.ksk_bytes_streamed,
            }


class NullLedger:
    """No-op twin for fully disabled telemetry."""

    fused_rounds = 0
    participants = 0
    rows_logical = 0
    rows_dispatched = 0
    rows_padded = 0
    bsk_bytes_streamed = 0
    ksk_bytes_streamed = 0
    bsk_bytes_unfused = 0
    ksk_bytes_unfused = 0
    bsk_bytes_saved = 0
    ksk_bytes_saved = 0
    rows_deduped = 0

    def account_round(self, **kw) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


NULL_LEDGER = NullLedger()
