"""Typed metrics: counters, gauges, and latency histograms behind one
`MetricsRegistry` with a single `snapshot()` contract.

Every layer of the serve path publishes here — `ServeRuntime` request
outcomes, `FusedLutScheduler` round composition, `IntegerContext` /
`TaurusEngine.lut_batch` PBS accounting — so one snapshot shows the
whole stack.  Instruments are cheap (one small lock each, no
allocation on the hot path) and process-local; nothing is exported
anywhere unless a caller reads `snapshot()`.

Histograms answer tail-latency questions (p50/p95/p99) through a
streaming quantile sketch: exact up to `max_samples` observations,
then uniform reservoir sampling (Vitter's algorithm R with a seeded
RNG, so summaries are reproducible).  `count`/`sum`/`min`/`max` are
always exact regardless of reservoir state.

`StatsView` is the backward-compatibility bridge: the serve layer's
historical ad-hoc ``stats`` dicts (`ServeRuntime.stats`,
`FusedLutScheduler.stats`) are now read-only mapping views over
registry counters (plus the bounded observability logs), so existing
key names keep working while `snapshot()` is the one source of truth.
"""
from __future__ import annotations

import random
import threading
from collections.abc import Mapping
from typing import Iterator, Optional


class Counter:
    """Monotonic counter; `inc` is thread-safe and exact."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._value})"


class Gauge:
    """Last-write-wins sampled value (e.g. current queue depth)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self._value})"


class Histogram:
    """Streaming distribution sketch: exact count/sum/min/max, quantiles
    from a bounded reservoir (exact until `max_samples` observations)."""

    __slots__ = ("name", "_lock", "count", "total", "min", "max",
                 "_cap", "_samples", "_rng")

    def __init__(self, name: str, max_samples: int = 4096):
        self.name = name
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._cap = max_samples
        self._samples: list = []
        # seeded so repeated runs summarize identically (reproducible
        # benchmarks); the reservoir only engages past `max_samples`
        self._rng = random.Random(0x5EED)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v
            if len(self._samples) < self._cap:
                self._samples.append(v)
            else:                       # reservoir: keep a uniform sample
                j = self._rng.randrange(self.count)
                if j < self._cap:
                    self._samples[j] = v

    def quantile(self, q: float) -> Optional[float]:
        """Estimated q-quantile (exact while count <= max_samples)."""
        with self._lock:
            if not self._samples:
                return None
            s = sorted(self._samples)
        idx = min(len(s) - 1, max(0, int(q * len(s))))
        return s[idx]

    def sample_state(self) -> Optional[tuple]:
        """(count, samples-in-observation-order) while the sketch is
        still exact (count <= max_samples), else None.  `Snapshot.diff`
        slices two exact states into interval quantiles; once the
        reservoir engages, sample order no longer matches observation
        order and interval quantiles are unsupported."""
        with self._lock:
            if self.count > self._cap:
                return None
            return self.count, tuple(self._samples)

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }

    def __repr__(self) -> str:
        return f"Histogram({self.name}, n={self.count})"


def _interval_summary(later_state: Optional[tuple],
                      earlier_count: int) -> Optional[dict]:
    """Summary of the observations made BETWEEN two exact sample states.

    Histograms are append-only until the reservoir engages, so the
    interval's observations are precisely `later_samples[earlier_count:]`
    — exact interval quantiles, not a subtraction heuristic.  Returns
    None when the later sketch is no longer exact (reservoir engaged)."""
    if later_state is None:
        return None
    _, samples = later_state
    window = list(samples[earlier_count:])
    if not window:
        return {"count": 0, "sum": 0.0, "mean": None, "min": None,
                "max": None, "p50": None, "p95": None, "p99": None}
    window.sort()
    n = len(window)

    def q(p: float) -> float:
        return window[min(n - 1, max(0, int(p * n)))]

    total = sum(window)
    return {"count": n, "sum": total, "mean": total / n,
            "min": window[0], "max": window[-1],
            "p50": q(0.50), "p95": q(0.95), "p99": q(0.99)}


class Snapshot(dict):
    """`MetricsRegistry.snapshot()`'s return type: a plain dict (JSON-
    serializable, existing ``snap["histograms"][...]["p99"]`` consumers
    unaffected) that additionally supports windowed deltas via `diff`.

    `diff(earlier)` is what per-phase SLO evaluation needs: counters
    subtract, gauges pass through the later sample, histograms report
    the INTERVAL's quantiles where supported (both snapshots taken
    while the sketch was exact; otherwise count/sum/mean still subtract
    but quantiles are None), and a ``bandwidth`` key — attached by
    `Telemetry.snapshot` — subtracts numeric leaves."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        # hist name -> (count, samples) while exact; not a dict item so
        # json.dump and == against plain dicts behave unchanged
        self.raw_samples: dict = {}

    def diff(self, earlier: "Snapshot") -> "Snapshot":
        out = Snapshot()
        e_counters = earlier.get("counters", {})
        out["counters"] = {n: v - e_counters.get(n, 0)
                           for n, v in self.get("counters", {}).items()}
        # gauges are point-in-time samples; the later value IS the
        # window's reading (subtracting queue depths is meaningless)
        out["gauges"] = dict(self.get("gauges", {}))
        hists = {}
        e_hists = earlier.get("histograms", {})
        for name, s in self.get("histograms", {}).items():
            e = e_hists.get(name, {"count": 0, "sum": 0.0})
            interval = _interval_summary(self.raw_samples.get(name),
                                         e.get("count", 0))
            if interval is None:
                # reservoir engaged: exact totals, no interval quantiles
                n = s["count"] - e.get("count", 0)
                total = s["sum"] - e.get("sum", 0.0)
                interval = {"count": n, "sum": total,
                            "mean": total / n if n else None,
                            "min": None, "max": None,
                            "p50": None, "p95": None, "p99": None}
            hists[name] = interval
        out["histograms"] = hists
        if "bandwidth" in self:
            e_bw = earlier.get("bandwidth", {})
            out["bandwidth"] = {
                k: (v - e_bw.get(k, 0)
                    if isinstance(v, (int, float)) else v)
                for k, v in self["bandwidth"].items()}
        return out


class MetricsRegistry:
    """Named instrument registry; `counter`/`gauge`/`histogram` are
    get-or-create (same name -> same instrument, so publishers in
    different layers can share one series)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, max_samples)
            return h

    def snapshot(self) -> Snapshot:
        """One structured view of every instrument: counters as ints,
        gauges as floats, histograms as p50/p95/p99 summaries.  The
        returned `Snapshot` supports `.diff(earlier)` for windowed
        per-phase deltas."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        snap = Snapshot({
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.summary() for n, h in sorted(hists.items())},
        })
        snap.raw_samples = {n: h.sample_state() for n, h in hists.items()}
        return snap


# ---------------------------------------------------------------------------
# no-op twins (Telemetry.disabled(): the hot path pays a method call)
# ---------------------------------------------------------------------------

class _NullCounter:
    __slots__ = ()
    name = "null"
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    name = "null"
    value = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    name = "null"
    count = 0
    total = 0.0
    min = None
    max = None
    mean = None

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> None:
        return None

    def summary(self) -> dict:
        return {"count": 0, "sum": 0.0, "mean": None, "min": None,
                "max": None, "p50": None, "p95": None, "p99": None}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class NullRegistry:
    """Registry twin whose instruments are shared no-op singletons."""

    def counter(self, name: str) -> _NullCounter:
        return _NULL_COUNTER

    def gauge(self, name: str) -> _NullGauge:
        return _NULL_GAUGE

    def histogram(self, name: str, max_samples: int = 4096) -> _NullHistogram:
        return _NULL_HISTOGRAM

    def snapshot(self) -> Snapshot:
        return Snapshot({"counters": {}, "gauges": {}, "histograms": {}})


NULL_REGISTRY = NullRegistry()


class StatsView(Mapping):
    """Read-only mapping over live metric sources — the backward-
    compatible face of the serve layer's historical ``stats`` dicts.

    Sources may be `Counter`s (read as ints), callables (evaluated on
    access), or any other object (returned as-is; the bounded
    ``admitted`` / ``occupancy`` observability logs stay deques)."""

    __slots__ = ("_sources",)

    def __init__(self, sources: dict):
        self._sources = sources

    def __getitem__(self, key: str):
        src = self._sources[key]
        if isinstance(src, (Counter, _NullCounter)):
            return src.value
        if callable(src):
            return src()
        return src

    def __iter__(self) -> Iterator[str]:
        return iter(self._sources)

    def __len__(self) -> int:
        return len(self._sources)

    def as_dict(self) -> dict:
        return {k: self[k] for k in self}

    def __repr__(self) -> str:
        return f"StatsView({self.as_dict()!r})"
