"""repro.obs — end-to-end tracing, metrics, and bandwidth accounting
for the FHE serving stack.

One `Telemetry` object threads through every layer of the serve path:

  metrics    typed counters/gauges/latency histograms in a
             `MetricsRegistry` (p50/p95/p99 from streaming quantile
             sketches), published by `ServeRuntime`,
             `FusedLutScheduler`, `IrInterpreter`, `IntegerContext`,
             and `TaurusEngine.lut_batch`; read through one
             `snapshot()` (also `ServeRuntime.metrics()`).
  tracing    request spans — submit -> queue-wait -> admit -> per-PBS-
             round (fused batch id, occupancy, dedup hits) ->
             complete/retry/fail — via a lock-cheap per-thread
             `TraceRecorder`, exportable as Chrome-trace JSON
             (Perfetto / chrome://tracing) or inspected in-memory.
  bandwidth  a `BandwidthLedger` accounting BSK/KSK bytes streamed per
             fused round vs. the unfused counterfactual — the paper's
             key-reuse saving as a measured quantity
             (`bsk_bytes_saved` in BENCH_serve.json).

Tracing is DISABLED by default: `Telemetry()` keeps the metrics
registry live (it replaced the serve layer's ad-hoc stats dicts) but
hands out a no-op recorder, so the hot path pays ~nothing when nobody
is looking.  `Telemetry(trace=True)` turns the recorder on;
`Telemetry.disabled()` is the fully inert twin (no-op metrics too).

    from repro.obs import Telemetry

    tel = Telemetry(trace=True)
    rt = ServeRuntime(ctx, telemetry=tel)          # or Session(..., telemetry=tel)
    ...serve traffic...
    snap = rt.metrics()                            # == tel.snapshot()
    tel.write_chrome_trace("trace.json")           # open in Perfetto

See docs/ARCHITECTURE.md ("Observability") for the span model and the
metrics catalog; `examples/trace_serve.py` writes a real trace from a
mixed radix + GPT-2-block serving run.
"""
from __future__ import annotations

from repro.obs.bandwidth import (NULL_LEDGER, BandwidthLedger, NullLedger,
                                 engine_key_bytes)
from repro.obs.metrics import (NULL_REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry, NullRegistry, Snapshot,
                               StatsView)
from repro.obs.trace import (NOOP_RECORDER, NoopRecorder, SpanEvent,
                             TraceRecorder, validate_chrome_trace)


class Telemetry:
    """The one telemetry handle every serve-path layer accepts.

    trace:   record spans (default False — no-op recorder).
    metrics: keep a live registry + bandwidth ledger (default True).
    """

    def __init__(self, *, trace: bool = False, metrics: bool = True):
        self.registry = MetricsRegistry() if metrics else NULL_REGISTRY
        self.recorder = TraceRecorder() if trace else NOOP_RECORDER
        self.bandwidth = BandwidthLedger() if metrics else NULL_LEDGER

    @classmethod
    def disabled(cls) -> "Telemetry":
        """Fully inert telemetry: every instrument is a shared no-op."""
        return cls(trace=False, metrics=False)

    @property
    def tracing(self) -> bool:
        return self.recorder.enabled

    # -- tracing -------------------------------------------------------------
    def span(self, name: str, cat: str = "serve", **args):
        return self.recorder.span(name, cat, **args)

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        self.recorder.instant(name, cat, **args)

    def record(self, name: str, cat: str, ts: float, dur: float,
               **args) -> None:
        self.recorder.record(name, cat, ts, dur, **args)

    def chrome_trace(self) -> dict:
        return self.recorder.chrome_trace()

    def write_chrome_trace(self, path: str) -> str:
        import json
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    # -- metrics -------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(name)

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        return self.registry.histogram(name, max_samples)

    def snapshot(self) -> Snapshot:
        """The single structured view: registry instruments plus the
        bandwidth ledger.  A `Snapshot`, so two phase-boundary calls
        diff into a windowed delta: ``later.diff(earlier)``."""
        snap = self.registry.snapshot()
        snap["bandwidth"] = self.bandwidth.snapshot()
        return snap


__all__ = [
    "BandwidthLedger", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NOOP_RECORDER", "NULL_LEDGER", "NULL_REGISTRY", "NoopRecorder",
    "NullLedger", "NullRegistry", "Snapshot", "SpanEvent", "StatsView",
    "Telemetry",
    "TraceRecorder", "engine_key_bytes", "validate_chrome_trace",
]
