"""Span tracing with a Chrome-trace exporter.

`TraceRecorder` gives every layer of the serve path a lock-cheap way to
record what happened when: each OS thread appends to its own buffer
(registered once per thread under a lock, then append-only with no
further locking), so tracing a fused serving wave does not serialize
the worker fleet.  Spans carry a name, a category, wall-clock interval
(`time.perf_counter` timebase) and a small args dict; `instant()`
records point events (submit/complete/retry markers) and `record()`
backfills an interval measured elsewhere (e.g. a request's queue wait,
whose endpoints were stamped by other threads).

Two export forms:

  * `events()` / `spans()` — the structured in-memory form tests
    assert against (sorted `SpanEvent`s);
  * `chrome_trace()` / `write(path)` — Chrome trace-event JSON
    (`{"traceEvents": [...]}`), loadable in Perfetto
    (https://ui.perfetto.dev) or chrome://tracing.  Complete events
    ("ph": "X") carry microsecond ts/dur; per-thread metadata events
    name the lanes.

`validate_chrome_trace` checks an exported file the way the CI smoke
lane does: valid JSON, required keys per event, and — per thread lane
— properly nested spans (intervals either disjoint or contained, never
partially overlapping).

The no-op twin (`NOOP_RECORDER`) is what a disabled `Telemetry` hands
out: `span()` returns a shared do-nothing context manager, so the hot
path pays one method call and a kwargs dict when tracing is off.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One recorded event: a span (dur is not None) or an instant."""
    name: str
    cat: str
    ts: float                 # perf_counter seconds (recorder timebase)
    dur: Optional[float]      # seconds; None for instant events
    tid: int                  # small per-recorder thread lane id
    thread: str               # thread name at first record
    args: dict


class _SpanCtx:
    """Context manager recording one span on the current thread."""

    __slots__ = ("_rec", "name", "cat", "args", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str, cat: str, args: dict):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.args = args

    def set(self, **kw) -> None:
        """Attach args discovered mid-span (e.g. the fused batch id a
        round landed in, known only once the leader dispatched)."""
        self.args.update(kw)

    def __enter__(self) -> "_SpanCtx":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        self._rec._append(self.name, self.cat, self._t0, t1 - self._t0,
                          self.args)


class _NoopSpan:
    """Shared do-nothing span for disabled tracing."""

    __slots__ = ()

    def set(self, **kw) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class NoopRecorder:
    """Recorder twin that records nothing (tracing disabled)."""

    enabled = False

    def span(self, name: str, cat: str = "serve", **args) -> _NoopSpan:
        return _NOOP_SPAN

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        pass

    def record(self, name: str, cat: str, ts: float, dur: float,
               **args) -> None:
        pass

    def events(self) -> list:
        return []

    def spans(self) -> list:
        return []

    def chrome_trace(self) -> dict:
        return {"traceEvents": []}


NOOP_RECORDER = NoopRecorder()


class TraceRecorder:
    """Per-thread-buffered span recorder (see module docstring)."""

    enabled = True

    def __init__(self):
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._buffers: list = []          # [(tid, thread_name, list)]
        self._tls = threading.local()

    # -- recording -----------------------------------------------------------
    def _buf(self) -> list:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = []
            with self._lock:
                tid = len(self._buffers)
                self._buffers.append(
                    (tid, threading.current_thread().name, buf))
            self._tls.buf = buf
            self._tls.tid = tid
        return buf

    def _append(self, name: str, cat: str, ts: float, dur: Optional[float],
                args: dict) -> None:
        # list.append on a thread-owned list: no lock on the hot path
        self._buf().append((name, cat, ts, dur, args))

    def span(self, name: str, cat: str = "serve", **args) -> _SpanCtx:
        """Open a span on the current thread::

            with recorder.span("fused_round", cat="sched", round=7) as sp:
                ...
                sp.set(rows=48)
        """
        return _SpanCtx(self, name, cat, args)

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        self._append(name, cat, time.perf_counter(), None, args)

    def record(self, name: str, cat: str, ts: float, dur: float,
               **args) -> None:
        """Backfill an interval whose endpoints were measured elsewhere
        (perf_counter timebase); lands on the calling thread's lane."""
        self._append(name, cat, ts, dur, args)

    # -- structured export (the in-memory form tests assert against) --------
    def events(self) -> list:
        """Every recorded event as `SpanEvent`s, sorted by start time."""
        with self._lock:
            snap = [(tid, tname, list(buf))
                    for tid, tname, buf in self._buffers]
        out = []
        for tid, tname, buf in snap:
            for name, cat, ts, dur, args in buf:
                out.append(SpanEvent(name, cat, ts, dur, tid, tname,
                                     dict(args)))
        out.sort(key=lambda e: e.ts)
        return out

    def spans(self) -> list:
        """Only the duration events (instants filtered out)."""
        return [e for e in self.events() if e.dur is not None]

    # -- Chrome trace-event export -------------------------------------------
    def chrome_trace(self) -> dict:
        """The recording as a Chrome trace-event object (Perfetto /
        chrome://tracing load it directly)."""
        trace_events = []
        seen_tids = set()
        for e in self.events():
            if e.tid not in seen_tids:
                seen_tids.add(e.tid)
                trace_events.append({
                    "name": "thread_name", "ph": "M", "pid": 1,
                    "tid": e.tid, "args": {"name": e.thread},
                })
            ev = {
                "name": e.name, "cat": e.cat, "pid": 1, "tid": e.tid,
                "ts": (e.ts - self._t0) * 1e6,
                "args": e.args,
            }
            if e.dur is None:
                ev["ph"] = "i"
                ev["s"] = "t"              # thread-scoped instant
            else:
                ev["ph"] = "X"
                ev["dur"] = e.dur * 1e6
            trace_events.append(ev)
        return {"traceEvents": trace_events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def validate_chrome_trace(trace) -> int:
    """Validate a Chrome trace: `trace` is a path, a JSON string, or an
    already-decoded object.  Checks JSON shape, per-event required keys,
    and per-lane span nesting (no partial overlaps).  Returns the number
    of trace events; raises ValueError on any violation."""
    if isinstance(trace, str):
        if trace.lstrip().startswith(("{", "[")):
            obj = json.loads(trace)
        else:
            with open(trace) as f:
                obj = json.load(f)
    else:
        obj = trace
    events = obj["traceEvents"] if isinstance(obj, dict) else obj
    if not isinstance(events, list):
        raise ValueError("trace must be a list or {'traceEvents': [...]}")
    lanes: dict = {}
    for i, ev in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing {key!r}: {ev!r}")
        if ev["ph"] == "X":
            if "ts" not in ev or "dur" not in ev or ev["dur"] < 0:
                raise ValueError(f"complete event {i} needs ts/dur: {ev!r}")
            lanes.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(ev["dur"]),
                 ev["name"]))
    eps = 1e-3                             # 1ns in trace microseconds
    for lane, spans in lanes.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list = []
        for start, end, name in spans:
            while stack and start >= stack[-1][0] - eps:
                stack.pop()
            if stack and end > stack[-1][0] + eps:
                raise ValueError(
                    f"lane {lane}: span {name!r} [{start}, {end}] partially "
                    f"overlaps enclosing {stack[-1][1]!r} ending at "
                    f"{stack[-1][0]}")
            stack.append((end, name))
    return len(events)
