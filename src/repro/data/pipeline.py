"""Deterministic, resumable, sharded synthetic LM data pipeline.

Production shape without external deps: every batch is a pure function of
(seed, step), so any worker can regenerate any batch — exactly the
property elastic restarts and straggler re-execution need (no data-state
checkpointing beyond the step counter).

The token stream is a mixture of Zipf-distributed unigrams and short
Markov motifs, giving a non-degenerate loss curve (a pure-uniform stream
has constant CE and hides training bugs).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64


class SyntheticLMData:
    """batch(step) -> {"tokens", "labels"} (next-token LM pairs)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Zipf unigram table (clipped to vocab)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = jnp.asarray(p / p.sum(), jnp.float32)
        self._motifs = jnp.asarray(
            rng.integers(0, cfg.vocab_size,
                         (cfg.n_motifs, cfg.motif_len)), jnp.int32)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S = cfg.global_batch, cfg.seq_len
        toks = jax.random.categorical(
            k1, jnp.log(self._probs)[None, None, :],
            shape=(B, S + 1)).astype(jnp.int32)  # dtype-stable under x64
        # overwrite random windows with motifs (learnable structure)
        n_inj = max(1, S // (4 * cfg.motif_len))
        starts = jax.random.randint(k2, (B, n_inj), 0, S - cfg.motif_len,
                                    dtype=jnp.int32)
        which = jax.random.randint(k3, (B, n_inj), 0, cfg.n_motifs,
                                   dtype=jnp.int32)

        def inject_row(row, st, wh):
            def one(row, args):
                s, w = args
                return jax.lax.dynamic_update_slice(
                    row, self._motifs[w], (s,)), None
            row, _ = jax.lax.scan(one, row, (st, wh))
            return row
        toks = jax.vmap(inject_row)(toks, starts, which)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}
