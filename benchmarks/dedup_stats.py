"""Compiler §V: KS-dedup / ACC-dedup savings across workloads (paper:
up to 47.12% fewer key-switches, 91.54% less GLWE storage)."""
from __future__ import annotations


def run() -> list:
    from repro.compiler import workloads, passes

    out = []
    print("\n== §V dedup: key-switch + accumulator savings ==")
    print(f"{'workload':16s} {'ks_before':>9s} {'ks_after':>8s} {'saved':>6s} "
          f"{'acc_before':>10s} {'acc_after':>9s} {'saved':>7s}")
    for name, w in workloads.build_all().items():
        _, s = passes.lower_to_physical(w.graph)
        print(f"{w.name:16s} {s.ks_before:9d} {s.ks_after:8d} "
              f"{s.ks_saved_frac:6.1%} {s.acc_before:10d} {s.acc_after:9d} "
              f"{s.acc_saved_frac:7.2%}")
        out.append({"bench": "dedup", "workload": name,
                    "ks_saved": s.ks_saved_frac,
                    "acc_saved": s.acc_saved_frac})
    return out
