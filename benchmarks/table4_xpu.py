"""Table IV: Taurus vs the Morphling-style XPU variant (same compiler)."""
from __future__ import annotations


def run() -> list:
    from repro.compiler import workloads, passes, build_schedule, TaurusModel
    from repro.compiler.cost import xpu_model

    out = []
    print("\n== Table IV: Taurus vs Taurus_XPU (systolic-array baseline) ==")
    print(f"{'workload':16s} {'taurus_ms':>10s} {'xpu_ms':>10s} "
          f"{'speedup':>8s} {'paper':>6s}")
    for name, w in workloads.build_all().items():
        ops, _ = passes.lower_to_physical(w.graph)
        sched = build_schedule(ops)
        t, _ = TaurusModel(w.params).bandwidth_bound_runtime(sched)
        tx, _ = xpu_model(w.params).bandwidth_bound_runtime(sched)
        paper = w.paper_xpu_ms / w.paper_taurus_ms
        print(f"{w.name:16s} {t * 1e3:10.1f} {tx * 1e3:10.1f} "
              f"{tx / t:8.2f} {paper:6.2f}")
        out.append({"bench": "table4", "workload": name,
                    "taurus_ms": t * 1e3, "xpu_ms": tx * 1e3,
                    "speedup": tx / t, "paper_speedup": paper})
    return out
