"""Traffic-simulation SLO suite: the `repro.sim` scenario harness end
to end on REAL ciphertexts.

    PYTHONPATH=src python -m benchmarks.sim_slo [--smoke]

For every scenario in `repro.sim.standard_suite` (steady / burst /
overload / mixed_tenant / closed_loop):

  1. replay it twice through the deterministic virtual-time simulator
     and assert the two reports are identical field for field (the
     seeded-determinism contract);
  2. drive it against a real `ServeRuntime` — arrival times paced onto
     the wall clock, every request a compiled radix program over
     big-key ciphertexts, every completed payload decrypted and checked
     against the workload's integer oracle;
  3. evaluate the SLO targets per phase from `Snapshot.diff` metric
     windows and record the verdict.

Arrival rates anchor to measured capacity (one warm radix-add request
timed through the interpreter), so "overload" is 3x THIS machine's
capacity, not a magic number.  The overload scenario is EXPECTED to
breach its SLO (expect_ok=False) and ends through the fail-fast
`close(drain=False)` path; a run is healthy when every scenario's
verdict matches its expectation.

Outputs: rows in benchmarks/BENCH_sim.json (measured SLO columns per
scenario) and the full per-phase reports — real and virtual — in
benchmarks/SIM_SLO_REPORT.json (the CI artifact).

--smoke runs one tiny 5-second scenario (cheap const-op analytics plus
a few PBS adds) plus the virtual determinism sweep — the CI smoke-lane
entry.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

MAX_INFLIGHT = 4

# SLO columns every sim row carries (checked by benchmarks/run.py
# --dry-run, same contract as the serve benchmarks' OBS columns)
BENCH_COLUMNS = ("p50_s", "p99_s", "queue_wait_p99_s", "abandon_rate",
                 "goodput_rps", "slo_ok", "as_expected",
                 "virtual_deterministic")


def write_bench_json(rows: list, path: str | None = None) -> str:
    """Merge sim rows into benchmarks/BENCH_sim.json by scenario name
    (re-running a subset must not clobber the other scenarios' rows)."""
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "BENCH_sim.json")
    rows = [r for r in rows if r.get("bench") == "sim"]
    existing = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = []
    fresh = {r.get("scenario") for r in rows}
    keep = [r for r in existing if r.get("scenario") not in fresh]
    with open(path, "w") as f:
        json.dump(keep + rows, f, indent=1, default=float)
    return path


def write_report_json(reports: list, path: str | None = None) -> str:
    """Full per-phase SLO reports (real + virtual) — the CI artifact."""
    if path is None:
        path = os.path.join(os.path.dirname(__file__),
                            "SIM_SLO_REPORT.json")
    with open(path, "w") as f:
        json.dump(reports, f, indent=1, default=float)
    return path


def _measure_capacity(ctx, engine, bits: int, msg_bits: int) -> float:
    """Serving capacity anchor: push a small fleet of arith-mix
    requests (2 adds : 1 mul, the suite's PBS-heavy mix) through a
    throwaway `ServeRuntime` at full concurrency and measure the WARM
    fused throughput.  A single-request probe would overestimate badly
    — the mix's muls are several times an add, and concurrent rounds
    share fused batches — so the anchor must be the fleet rate the
    runtime actually sustains.  Derated 20% for scheduling headroom and
    clamped so scenario request counts stay bounded on extreme
    machines."""
    import random

    import jax
    from repro.core.integer import IntegerContext
    from repro.serve import ServeRuntime
    from repro.sim.workloads import radix_add, radix_mul

    rt = ServeRuntime(ctx, engine, max_inflight=MAX_INFLIGHT)
    try:
        ic = IntegerContext.create(ctx, rt.engine)
        rng = random.Random(0)
        add, mul = radix_add(bits, msg_bits), radix_mul(bits, msg_bits)
        jobs = [add, mul, add] * 2 + [add, mul]      # 2:1 mix, 8 requests
        enc = []
        for i, w in enumerate(jobs):
            enc.append(w.encrypt(ic, jax.random.key(1 + i),
                                 w.sample_values(rng)))
        # warm: one add + one mul compile every XLA shape on the path
        for w, e in zip(jobs[:2], enc[:2]):
            rt.submit(w.build()[0], e, client_id="warm").wait()
        t0 = time.perf_counter()
        handles = [rt.submit(w.build()[0], e,
                             client_id=f"probe-{i % MAX_INFLIGHT}")
                   for i, (w, e) in enumerate(zip(jobs, enc))]
        for h in handles:
            h.wait()
        rate = len(handles) / (time.perf_counter() - t0)
    finally:
        rt.close()
    return max(0.4, min(4.0, 0.8 * rate))


def _row(scenario, real_report: dict, det: bool) -> dict:
    o = real_report["overall"]
    return {
        "bench": "sim", "scenario": scenario.name,
        "requests": o["requests"], "done": o["done"],
        "timeout": o["timeout"], "abandoned": o["abandoned"],
        "failed": o["failed"],
        "p50_s": o["p50_s"], "p99_s": o["p99_s"],
        "queue_wait_p99_s": o["queue_wait_p99_s"],
        "abandon_rate": o["abandon_rate"],
        "goodput_rps": o["goodput_rps"],
        "slo_ok": real_report["ok"],
        "expect_ok": real_report["expect_ok"],
        "as_expected": real_report["as_expected"],
        "virtual_deterministic": det,
        "max_inflight": real_report["max_inflight"],
    }


def run(smoke: bool = False, out_dir: str | None = None) -> list:
    import jax
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext
    from repro.sim import (Poisson, Scenario, SLOTargets, WorkloadMix,
                           run_scenario, simulate_scenario,
                           standard_suite)

    bits, msg_bits = 8, 2
    ctx = TFHEContext.create(jax.random.PRNGKey(0), TEST_PARAMS_4BIT)
    engine = TaurusEngine.from_context(ctx)

    # the seeded-determinism sweep is free (no crypto): always check the
    # FULL suite virtually, even in smoke mode
    det_suite = standard_suite(capacity_rps=2.0, duration_s=18.0)
    det_ok = all(
        simulate_scenario(sc, max_inflight=MAX_INFLIGHT).report
        == simulate_scenario(sc, max_inflight=MAX_INFLIGHT).report
        for sc in det_suite)
    print(f"[sim_slo] virtual determinism sweep "
          f"({len(det_suite)} scenarios): "
          f"{'identical' if det_ok else 'DIVERGED'}")

    cap = _measure_capacity(ctx, engine, bits, msg_bits)
    print(f"[sim_slo] measured capacity anchor: {cap:.2f} req/s "
          f"(max_inflight={MAX_INFLIGHT})")

    if smoke:
        mix = WorkloadMix.of({"analytics_const": 3.0, "radix_add": 1.0},
                             bits=bits, msg_bits=msg_bits)
        suite = [Scenario("smoke_steady", Poisson(1.2), mix,
                          duration_s=5.0, deadline_s=8.0,
                          slo=SLOTargets(p99_s=8.0, abandon_rate=0.2),
                          seed=11)]
    else:
        suite = standard_suite(capacity_rps=cap, duration_s=12.0)

    rows, reports = [], []
    for sc in suite:
        v1 = simulate_scenario(sc, max_inflight=MAX_INFLIGHT)
        v2 = simulate_scenario(sc, max_inflight=MAX_INFLIGHT)
        det = det_ok and v1.report == v2.report
        real = run_scenario(sc, ctx, engine, max_inflight=MAX_INFLIGHT,
                            validate=True)
        bad_payload = sum(1 for r in real.records
                          if r.record.ok_payload is False)
        if bad_payload:
            raise AssertionError(
                f"{sc.name}: {bad_payload} decrypted payloads diverged "
                f"from the integer oracle")
        rows.append(_row(sc, real.report, det))
        reports.append({"scenario": sc.name, "real": real.report,
                        "virtual": v1.report})
        o = real.report["overall"]
        print(f"[sim_slo] {sc.name:13s} req={o['requests']:4d} "
              f"done={o['done']:4d} abandoned={o['abandoned']:3d} "
              f"timeout={o['timeout']:3d} "
              f"p99={0 if o['p99_s'] is None else o['p99_s']:.3f}s "
              f"goodput={o['goodput_rps']:.2f}rps "
              f"slo={'PASS' if real.report['ok'] else 'FAIL'} "
              f"(expected "
              f"{'PASS' if sc.expect_ok else 'FAIL'})")

    write_report_json(reports,
                      path=None if out_dir is None else
                      os.path.join(out_dir, "SIM_SLO_REPORT.json"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny 5-second scenario + the virtual "
                         "determinism sweep (CI smoke lane)")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke, out_dir=args.out_dir)
    path = write_bench_json(
        rows, path=None if args.out_dir is None else
        os.path.join(args.out_dir, "BENCH_sim.json"))
    print(f"[sim_slo] {len(rows)} scenario rows -> {path}")
    bad = [r["scenario"] for r in rows
           if not (r["as_expected"] and r["virtual_deterministic"])]
    if bad:
        print(f"[sim_slo] FAILED scenarios: {bad}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
