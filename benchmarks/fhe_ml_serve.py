"""Encrypted-transformer traffic on the serve path (ISSUE 4).

    PYTHONPATH=src python -m benchmarks.fhe_ml_serve

Each of N_CLIENTS concurrent clients submits a quantized-to-radix GPT-2
block program (16-bit two's-complement activations: exact radix_linear
q/k/v projections, ct*ct attention via radix_mul, ReLU MLP) through the
multi-tenant `ServeRuntime` — the encrypted-LLM workload the ROADMAP's
serving follow-up asked for.  The last client replays client 0's
ciphertexts (a retried/replayed query), so the online (ciphertext,
table) dedup case is always present in the fused rounds.

One warm wave compiles every pbs_batch shape the block touches, then a
measured wave records requests/sec, fused-round occupancy and dedup
hit-rate.  The row lands in benchmarks/BENCH_serve.json (workload
"fhe_ml_gpt2_block") next to the radix-add serving row, so the
encrypted-ML serving trajectory is tracked machine-readably alongside
the integer one.
"""
from __future__ import annotations

import time

N_CLIENTS = 3
D_MODEL = 2
BITS = 16
MSG_BITS = 2
WORKLOAD = "fhe_ml_gpt2_block"

# same observability columns as serve_throughput (run.py --dry-run
# checks both modules declare them)
from benchmarks.serve_throughput import OBS_COLUMNS as BENCH_COLUMNS  # noqa: E402,F401


def run() -> list:
    import jax
    import numpy as np
    from repro.api import Session
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext
    from repro.fhe_ml import lower
    from repro.fhe_ml.quantize import calibrate_radix, quantize_to_radix

    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    client = Session(ctx, engine, backend="local")

    g, meta = lower.lower_gpt2_block_radix(D_MODEL, bits=BITS,
                                           msg_bits=MSG_BITS, seed=1)
    prog = client.compile(g, meta["in_specs"], meta["out_specs"])

    print(f"\n== Encrypted-transformer serving throughput "
          f"({N_CLIENTS} GPT-2-block clients, {BITS}-bit radix "
          f"activations, {params.name}) ==")
    print(f"   graph: {len(g.nodes)} nodes, "
          f"{g.lut_applications()} planned PBS applications/request")

    rng = np.random.default_rng(7)
    jobs = []
    for i in range(N_CLIENTS - 1):
        xf = rng.uniform(-1, 1, D_MODEL)
        rq = calibrate_radix(xf, BITS, MSG_BITS, qmax=meta["input_qmax"])
        q = quantize_to_radix(xf, rq)
        enc = client.encrypt_inputs(jax.random.key(100 + i), [q], prog)
        jobs.append((f"client-{i}", enc, meta["int_fn"](q) % (1 << BITS)))
    # the last client replays client 0 — the online-dedup case
    jobs.append((f"client-{N_CLIENTS - 1}", jobs[0][1], jobs[0][2]))

    def wave():
        sess = Session(ctx, engine, backend="serve",
                       max_inflight=N_CLIENTS, start_paused=True)
        handles = [sess.submit(prog, enc, client_id=c)
                   for c, enc, _ in jobs]
        rt = sess.backend.runtime
        t0 = time.perf_counter()
        rt.resume()
        rt.drain()
        dt = time.perf_counter() - t0
        for h, (_, _, want) in zip(handles, jobs):
            got = np.asarray(sess.decrypt_outputs(prog, h.outputs())[0])
            assert np.array_equal(got % (1 << BITS), want), "FHE != oracle"
        return dt, rt

    t_warm, _ = wave()                     # compiles the pbs_batch shapes
    print(f"   warm wave {t_warm:5.1f}s (XLA compilation)")
    dt, rt = wave()
    sched = rt.scheduler
    row = {
        "bench": "serve", "workload": WORKLOAD,
        "clients": N_CLIENTS, "bits": BITS, "d_model": D_MODEL,
        "params": params.name,
        "requests_per_s_fused": N_CLIENTS / dt,
        "dedup_hit_rate": sched.dedup_hit_rate,
        "fused_occupancy": sched.mean_occupancy,
        "fused_rounds": sched.stats["fused_rounds"],
        "logical_luts": sched.stats["logical_luts"],
        "dispatched_luts": sched.stats["dispatched_luts"],
    }
    from benchmarks.serve_throughput import obs_columns
    row.update(obs_columns(rt))
    print(f"   measured wave {dt:5.1f}s: "
          f"{row['requests_per_s_fused']:.3f} req/s, "
          f"{row['fused_rounds']} fused rounds, occupancy "
          f"{row['fused_occupancy']:.0%}, dedup hit-rate "
          f"{row['dedup_hit_rate']:.1%}")
    print(f"   latency p50 {row['p50_s']:.2f}s p99 {row['p99_s']:.2f}s, "
          f"BSK saved {row['bsk_bytes_saved'] / 1e6:.1f} MB")
    assert row["dedup_hit_rate"] > 0, "replayed client must dedup"
    return [row]


if __name__ == "__main__":
    from benchmarks.serve_throughput import write_bench_json
    out = run()
    p = write_bench_json(out)          # merges by workload
    print(f"[fhe_ml_serve] wrote {p}")
