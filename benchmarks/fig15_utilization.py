"""Figure 15: cluster utilization vs input batch size (Observation 7).

Serial workloads (KNN, Decision Tree) gain the most from batching:
independent queries fill the idle clusters of dependent levels."""
from __future__ import annotations


def run() -> list:
    from repro.compiler import workloads, passes, build_schedule, TaurusModel
    from repro.compiler.passes import PhysOp

    out = []
    print("\n== Fig. 15: utilization vs input batch size ==")
    names = ["knn", "decision_tree", "xgboost", "gpt2"]
    print(f"{'workload':16s}" + "".join(f"  b={b:>2d}" for b in (1, 2, 4, 8)))
    W = workloads.build_all()
    for name in names:
        w = W[name]
        ops, _ = passes.lower_to_physical(w.graph)
        row = []
        for bsz in (1, 2, 4, 8):
            # batch-of-queries: replicate the op stream per query; same
            # levels, b x the ciphertexts per level
            b_ops = [PhysOp(o.kind, o.node, o.count * bsz, o.level, o.macs * bsz,
                            o.table_id) for o in ops]
            sched = build_schedule(b_ops)
            _, util = TaurusModel(w.params).runtime(sched)
            row.append(util)
        print(f"{w.name:16s}" + "".join(f" {u:5.2f}" for u in row))
        out.append({"bench": "fig15", "workload": name,
                    "util_by_batch": row})
    return out
