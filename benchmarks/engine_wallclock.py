"""Wall-clock of the REAL JAX TFHE engine on CPU: batched PBS throughput
and the round-robin (batched BSK reuse) vs XPU-style (per-ciphertext)
comparison — the paper's core architectural claim, measured."""
from __future__ import annotations

import time

import numpy as np


def _bench(fn, *args, reps=3):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    r.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run() -> list:
    import jax
    import jax.numpy as jnp
    from repro.core import batch as batch_mod, glwe
    from repro.core.params import TEST_PARAMS, TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext

    out = []
    print("\n== Engine wall-clock (CPU, real ciphertexts) ==")
    print(f"{'params':12s} {'B':>3s} {'batched_ms':>11s} {'per_ct_ms':>10s} "
          f"{'xpu_ms':>9s} {'reuse_gain':>10s}")
    for params in (TEST_PARAMS, TEST_PARAMS_4BIT):
        ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
        for B in (4, 12):
            key = jax.random.PRNGKey(1)
            msgs = np.arange(B) % params.plaintext_modulus
            cts = jnp.stack([ctx.encrypt(jax.random.fold_in(key, i), m)
                             for i, m in enumerate(msgs)])
            table = jnp.arange(params.plaintext_modulus, dtype=jnp.uint64)
            poly = glwe.make_lut_poly(table, params)
            polys = jnp.broadcast_to(poly, (B, params.N))

            t_b = _bench(lambda c, p: batch_mod.pbs_batch(
                c, p, ctx.bsk_f, ctx.ksk, params), cts, polys)
            t_x = _bench(lambda c, p: batch_mod.pbs_unbatched_loop(
                c, p, ctx.bsk_f, ctx.ksk, params), cts, polys)
            print(f"{params.name:12s} {B:3d} {t_b * 1e3:11.1f} "
                  f"{t_b / B * 1e3:10.2f} {t_x * 1e3:9.1f} {t_x / t_b:10.2f}")
            out.append({"bench": "engine", "params": params.name, "B": B,
                        "batched_ms": t_b * 1e3, "xpu_ms": t_x * 1e3,
                        "reuse_gain": t_x / t_b})
    return out
