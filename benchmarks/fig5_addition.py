"""Figure 5: 6-bit integer addition under three TFHE representations.

Boolean TFHE (ripple-carry of bootstrapped gates), 5-bit radix (segments
+ one bivariate-LUT carry PBS), 8-bit direct (pure linear, no PBS).
Costs come from the calibrated Taurus/CPU models; wall-clock of the
linear path is measured on the real JAX engine.
"""
from __future__ import annotations

import time

import numpy as np


def run() -> list:
    from repro.core.params import _paper
    from repro.compiler.cost import CpuModel, TaurusModel
    from repro.compiler.schedule import Batch

    rows = []
    # --- Boolean TFHE: ripple-carry adder measured on the REAL engine ------
    # paper: 5 gates/bit x 11 ms/gate = 253 ms on EPYC 7R13; our engine
    # uses the 3-bootstrap full adder (2 XOR + MAJ) at toy parameters.
    import jax
    from repro.core.boolean import BooleanContext
    from repro.core.params import TEST_PARAMS
    from repro.core.pbs import TFHEContext
    import jax.numpy as jnp
    bctx = BooleanContext(TFHEContext.create(jax.random.PRNGKey(0),
                                             TEST_PARAMS))
    key = jax.random.PRNGKey(1)
    enc = lambda bits, s: jnp.stack([
        bctx.encrypt(jax.random.fold_in(key, s + i), b)
        for i, b in enumerate(bits)])
    ca = enc([1, 0, 1, 1, 0, 1], 0)
    cb = enc([0, 1, 1, 0, 1, 0], 8)
    bctx.add_ripple(ca, cb)[0].block_until_ready()      # warm compile
    t0 = time.perf_counter()
    bctx.add_ripple(ca, cb)[0].block_until_ready()
    t_bool = (time.perf_counter() - t0) * 1e3
    rows.append(("boolean (real)", 3 * 6 - 1, t_bool, 253.0))

    # --- 5-bit radix: two segments + one carry PBS --------------------------
    p5 = _paper("fig5-5bit", 800, 16384, 1, 5)
    cpu5 = CpuModel(p5)
    t5 = cpu5.t_ct_pbs * 1e3         # one bivariate-LUT PBS dominates
    rows.append(("5-bit radix", 1, t5, 47.0))

    # --- 8-bit direct: one linear op, NO PBS --------------------------------
    import jax
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext
    ctx = TFHEContext.create(jax.random.PRNGKey(0), TEST_PARAMS_4BIT)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = ctx.encrypt(k1, 3)
    b = ctx.encrypt(k2, 9)
    (a + b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(100):
        c = a + b
    c.block_until_ready()
    t_lin = (time.perf_counter() - t0) / 100 * 1e3
    rows.append(("8-bit direct", 0, t_lin, 0.008))

    out = []
    print("\n== Fig. 5: 6-bit addition across representations ==")
    print(f"{'repr':14s} {'PBS':>4s} {'model_ms':>10s} {'paper_ms':>9s}")
    for name, pbs, ms, paper in rows:
        print(f"{name:14s} {pbs:4d} {ms:10.3f} {paper:9.3f}")
        out.append({"bench": "fig5", "repr": name, "n_pbs": pbs,
                    "model_ms": ms, "paper_ms": paper})
    return out
