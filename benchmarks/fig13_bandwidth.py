"""Figure 13: (a) clusters vs required bandwidth; (b) round-robin depth vs
throughput/bandwidth-deficit/buffer size."""
from __future__ import annotations

import dataclasses


def run() -> list:
    from repro.core.params import PAPER_PARAMS
    from repro.compiler.cost import TaurusModel, HBM_BW, ACC_BUF_BYTES

    out = []
    p = PAPER_PARAMS["gpt2"]

    print("\n== Fig. 13a: clusters vs required bandwidth (GPT-2 params) ==")
    print(f"{'clusters':>8s} {'bsk_GB/s':>9s} {'lwe_GB/s':>9s} {'total_GB/s':>10s} {'fits_2xHBM2E':>13s}")
    for n_cl in (2, 4, 6, 8):
        m = TaurusModel(p, clusters=n_cl)
        bw = m.batch_bandwidth()
        # keys are shared (constant); LWE/GLWE traffic scales with clusters
        lwe = bw["lwe"] * n_cl / 4
        total = bw["bsk"] + bw["ksk"] + lwe
        print(f"{n_cl:8d} {bw['bsk'] / 1e9:9.1f} {lwe / 1e9:9.1f} "
              f"{total / 1e9:10.1f} {'yes' if total < HBM_BW else 'NO':>13s}")
        out.append({"bench": "fig13a", "clusters": n_cl,
                    "total_gbs": total / 1e9, "fits": total < HBM_BW})

    print("\n== Fig. 13b: round-robin ciphertexts vs throughput/buffer ==")
    print(f"{'rr':>3s} {'throughput':>11s} {'bw_deficit':>11s} {'buf_KB':>8s} "
          f"{'paper_buf@12':>12s}")
    for rr in (2, 4, 8, 12, 16, 24):
        m = TaurusModel(p)
        t_batch = rr * m.t_ct_br
        bsk_bw = m.bsk_bytes / t_batch
        deficit = max(0.0, bsk_bw + m.ksk_bytes / t_batch - HBM_BW)
        buf = rr * m.acc_bytes_per_ct / 1024
        # throughput saturates once bandwidth is satisfied (paper: 12)
        thr = min(1.0, HBM_BW / (bsk_bw + m.ksk_bytes / t_batch))
        note = "9216" if rr == 12 else ""
        print(f"{rr:3d} {thr:11.2f} {deficit / 1e9:11.1f} {buf:8.0f} "
              f"{note:>12s}")
        out.append({"bench": "fig13b", "rr": rr, "throughput": thr,
                    "deficit_gbs": deficit / 1e9, "buf_kb": buf})
    return out
