"""Radix wide-integer throughput: digits/sec of batched carry rounds.

The paper's round-robin BSK reuse (§III-B) is what makes per-digit PBS
cheap enough for multi-digit integers: one carry-propagation round over
all D digits is ONE `lut_batch` (the BSK streams once), where the
Morphling-XPU-style baseline bootstraps the D digits independently.
This benchmark measures that gap on the real CPU engine, then times
whole `add`/`mul` ops end to end at 16 bits.
"""
from __future__ import annotations

import time

import numpy as np


def _bench(fn, *args, reps=3):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    r.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run() -> list:
    import jax
    import jax.numpy as jnp
    from repro.api import IntSpec, Session
    from repro.core.engine import TaurusEngine
    from repro.core.integer import carry_table, msg_table
    from repro.core.params import TEST_PARAMS, TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext

    out = []
    print("\n== Radix wide-integer throughput (CPU, real ciphertexts) ==")
    print(f"{'params':12s} {'bits':>4s} {'D':>3s} {'batched_ms':>11s} "
          f"{'xpu_ms':>9s} {'dig/s':>8s} {'reuse_gain':>10s}")
    for params, bits in ((TEST_PARAMS, 16), (TEST_PARAMS_4BIT, 16)):
        ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
        eng = TaurusEngine.from_context(ctx)
        # eager backend: direct IntegerContext, unpadded rounds (the
        # microbench measures raw round cost, not shape reuse)
        sess = Session(ctx, eng, backend="eager", pad_batches=False)
        ic = sess.backend.int_ctx
        a = ic.encrypt(jax.random.PRNGKey(1), 0xBEEF, bits)
        b = ic.encrypt(jax.random.PRNGKey(2), 0x1234, bits)
        spec = a.spec
        d = spec.n_digits
        # one carry round: (msg, carry) extraction over all digits = one
        # 2D-ciphertext batch vs 2D independent XPU bootstraps
        batch = jnp.concatenate([a.digits, a.digits], axis=0)
        tables = np.concatenate(
            [np.tile(msg_table(params.width, spec.msg_bits), (d, 1)),
             np.tile(carry_table(params.width, spec.msg_bits), (d, 1))])
        polys = ic._polys(tables)
        t_b = _bench(eng.lut_batch, batch, polys)
        t_x = _bench(eng.lut_batch_xpu, batch, polys)
        print(f"{params.name:12s} {bits:4d} {d:3d} {t_b * 1e3:11.1f} "
              f"{t_x * 1e3:9.1f} {d / t_b:8.0f} {t_x / t_b:10.2f}")
        out.append({"bench": "radix", "params": params.name, "bits": bits,
                    "digits": d, "round_batched_ms": t_b * 1e3,
                    "round_xpu_ms": t_x * 1e3, "digits_per_s": d / t_b,
                    "reuse_gain": t_x / t_b})
        # end-to-end ops as TRACED programs through the api front door
        # (carry strategy auto: lookahead/ripple at width 2, prefix at
        # width >= 4) — the same Program would run on "local"/"serve"
        enc = [a.digits, b.digits]
        for opname, fn in (("add", lambda x, y: x + y),
                           ("mul", lambda x, y: x * y)):
            prog = sess.trace(fn, IntSpec(bits), IntSpec(bits))
            sess.run(prog, enc)            # compile + warm
            ic.reset_stats()
            t0 = time.perf_counter()
            res = sess.run(prog, enc)[0]
            res.block_until_ready()
            dt = time.perf_counter() - t0
            print(f"  {opname}{bits}: {dt * 1e3:9.1f} ms, "
                  f"{ic.stats['lut_batches']} batches, "
                  f"{ic.stats['pbs']} PBS, "
                  f"min batch {min(ic.stats['batch_sizes'])}")
            out.append({"bench": "radix_op", "params": params.name,
                        "op": opname, "bits": bits, "ms": dt * 1e3,
                        "pbs": ic.stats["pbs"],
                        "batches": ic.stats["lut_batches"]})
    return out


if __name__ == "__main__":
    run()
