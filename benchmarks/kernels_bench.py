"""Pallas engine-room benchmark: per-kernel and fused `lut_batch` wall
clock, reference-vs-pallas speedup, and bytes streamed vs the
`launch/roofline.py` per-round bandwidth bound.

Writes benchmarks/BENCH_kernels.json (merged by workload, like
BENCH_serve.json) so the kernel perf trajectory is tracked across PRs.

NB: this container runs the Pallas kernels in INTERPRET mode on CPU, so
the measured "speedup" is a correctness-weighted proxy, not TPU perf —
the roofline gate (`bytes_ok`) is the hardware-relevant number: the
fused path's streamed bytes must sit within the key-reuse bound or the
residency story (and the paper's 2600x ride on it) is broken.
"""
from __future__ import annotations

import json
import os
import time

# every BENCH_kernels.json row carries these (run.py --dry-run pins them)
BENCH_COLUMNS = ("workload", "params", "B", "ref_ms", "pallas_ms",
                 "speedup", "bytes_streamed", "bytes_bound", "bytes_ok",
                 "reuse_factor", "t_memory_bound_s")


def _bench(fn, *args, reps=3):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    r.block_until_ready()
    return (time.perf_counter() - t0) / reps


def write_bench_json(rows: list, path: str | None = None) -> str:
    """Merge kernel rows into benchmarks/BENCH_kernels.json by workload
    (re-running one workload must not clobber the others' rows)."""
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")
    rows = [r for r in rows if r.get("bench") == "kernels"]
    existing = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = []
    fresh = {r.get("workload") for r in rows}
    keep = [r for r in existing if r.get("workload") not in fresh]
    with open(path, "w") as f:
        json.dump(keep + rows, f, indent=1, default=float)
    return path


def run() -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import batch as batch_mod, glwe
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS
    from repro.core.pbs import TFHEContext
    from repro.kernels import external_product, fourstep_fft, keyswitch, ref
    from repro.launch.roofline import pbs_round_model

    out = []
    params = TEST_PARAMS
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)

    # -- per-kernel wall clock vs the reference oracle ----------------------
    print("\n== Pallas kernels (interpret mode) vs reference oracles ==")
    print(f"{'kernel':18s} {'ref_ms':>8s} {'pallas_ms':>10s} {'speedup':>8s}")
    key = jax.random.PRNGKey(7)
    B, N = 8, params.N
    x = jax.random.randint(key, (B, N), 0, 1 << 30, dtype=jnp.int64
                           ).astype(jnp.float64)
    per_kernel = [
        ("fft_forward",
         lambda v: jnp.asarray(ref.fft_forward_ref(v)),
         lambda v: fourstep_fft.fft_forward(v, dtype=jnp.float64), (x,)),
        ("fft_inverse",
         lambda s: jnp.asarray(ref.fft_inverse_ref(s)),
         lambda s: fourstep_fft.fft_inverse(s, dtype=jnp.float64),
         (fourstep_fft.fft_forward(x, dtype=jnp.float64),)),
    ]
    J, K, M = (params.k + 1) * params.pbs_level, params.k + 1, N // 2
    dig = jax.random.normal(key, (B, 2, J, M), dtype=jnp.float64)
    bsk1 = jax.random.normal(jax.random.fold_in(key, 1), (2, J, K, M),
                             dtype=jnp.float64)
    per_kernel.append((
        "external_product",
        lambda d, w: jnp.asarray(ref.external_product_mac_ref(d, w)),
        lambda d, w: external_product.external_product_mac(
            d, w, block_f=min(2048, M), dtype=jnp.float64), (dig, bsk1)))
    S, T = params.big_n * params.ks_level, params.n + 1
    digs = jax.random.randint(key, (B, S), -16, 16, dtype=jnp.int32)
    ksk_flat = ctx.ksk.reshape(S, T)
    khi, klo = ref.split_u64(ksk_flat)
    per_kernel.append((
        "keyswitch_mac",
        lambda d: ref.keyswitch_mac_ref(d, ksk_flat),
        lambda d: ref.merge_u64(*keyswitch.keyswitch_mac(d, khi, klo)),
        (digs,)))

    for name, ref_fn, pal_fn, args in per_kernel:
        t_ref = _bench(ref_fn, *args)
        t_pal = _bench(pal_fn, *args)
        print(f"{name:18s} {t_ref * 1e3:8.2f} {t_pal * 1e3:10.2f} "
              f"{t_ref / t_pal:8.2f}")
        out.append({"bench": "kernels", "workload": f"kernel_{name}",
                    "params": params.name, "B": B,
                    "ref_ms": t_ref * 1e3, "pallas_ms": t_pal * 1e3,
                    "speedup": t_ref / t_pal, "bytes_streamed": None,
                    "bytes_bound": None, "bytes_ok": True,
                    "reuse_factor": None, "t_memory_bound_s": None})

    # -- end-to-end fused lut_batch: reference vs pallas engine -------------
    print("\n== Fused lut_batch: reference vs pallas engine room ==")
    print(f"{'B':>3s} {'ref_ms':>8s} {'pallas_ms':>10s} {'speedup':>8s} "
          f"{'bytes_frac':>10s} {'reuse':>6s}")
    eng_ref = TaurusEngine.from_context(ctx)
    eng_pal = TaurusEngine.from_context(ctx, kernel_backend="pallas")
    table = jnp.arange(params.plaintext_modulus, dtype=jnp.uint64)
    poly = glwe.make_lut_poly(table, params)
    for B in (4, 12):
        k2 = jax.random.PRNGKey(1)
        msgs = np.arange(B) % params.plaintext_modulus
        cts = jnp.stack([ctx.encrypt(jax.random.fold_in(k2, i), m)
                         for i, m in enumerate(msgs)])
        polys = jnp.broadcast_to(poly, (B, params.N))
        t_ref = _bench(eng_ref.lut_batch, cts, polys)
        t_pal = _bench(eng_pal.lut_batch, cts, polys)
        # decrypt-parity gate: a fast wrong kernel must not post a row
        d_ref = [int(ctx.decrypt(v)) for v in eng_ref.lut_batch(cts, polys)]
        d_pal = [int(ctx.decrypt(v)) for v in eng_pal.lut_batch(cts, polys)]
        assert d_ref == d_pal, f"decrypt mismatch: {d_ref} vs {d_pal}"

        model = pbs_round_model(params, B)
        streamed = eng_pal.fused_pack.bytes_streamed_per_round(B)
        bytes_ok = streamed <= model.fused_bytes
        assert bytes_ok, (f"fused path streams {streamed} B/round, over the "
                          f"roofline bound {model.fused_bytes}")
        print(f"{B:3d} {t_ref * 1e3:8.1f} {t_pal * 1e3:10.1f} "
              f"{t_ref / t_pal:8.2f} {streamed / model.fused_bytes:10.3f} "
              f"{model.reuse_factor:6.1f}")
        out.append({"bench": "kernels", "workload": f"lut_batch_B{B}",
                    "params": params.name, "B": B,
                    "ref_ms": t_ref * 1e3, "pallas_ms": t_pal * 1e3,
                    "speedup": t_ref / t_pal,
                    "bytes_streamed": streamed,
                    "bytes_bound": model.fused_bytes,
                    "bytes_ok": bytes_ok,
                    "reuse_factor": model.reuse_factor,
                    "t_memory_bound_s": model.t_memory})
    return out


if __name__ == "__main__":
    rows = run()
    path = write_bench_json(rows)
    print(f"[kernels_bench] {len(rows)} rows -> {path}")
