"""Table II: end-to-end workload runtimes — Taurus cost model vs the
paper's reported Taurus/CPU/GPU numbers."""
from __future__ import annotations


def run() -> list:
    from repro.compiler import (workloads, passes, build_schedule,
                                TaurusModel, CpuModel)

    out = []
    print("\n== Table II: workload runtimes (model vs paper) ==")
    print(f"{'workload':16s} {'PBS':>7s} {'model_ms':>9s} {'paper_ms':>9s} "
          f"{'ratio':>6s} | {'spd_cpu':>8s} {'paper':>6s} | {'cpu_model_s':>11s} {'paper_s':>8s}")
    for name, w in workloads.build_all().items():
        ops, stats = passes.lower_to_physical(w.graph)
        sched = build_schedule(ops)
        t, util = TaurusModel(w.params).bandwidth_bound_runtime(sched)
        cpu_model = CpuModel(w.params).runtime(sched)
        # faithful comparison: paper-measured CPU seconds / our Taurus model
        spd = w.paper_cpu_s / t
        paper_spd = w.paper_cpu_s * 1e3 / w.paper_taurus_ms
        print(f"{w.name:16s} {sched.total_pbs:7d} {t * 1e3:9.1f} "
              f"{w.paper_taurus_ms:9.1f} {t * 1e3 / w.paper_taurus_ms:6.2f} | "
              f"{spd:8.0f} {paper_spd:6.0f} | {cpu_model:11.1f} "
              f"{w.paper_cpu_s:8.1f}")
        out.append({"bench": "table2", "workload": name,
                    "n_pbs": sched.total_pbs, "model_ms": t * 1e3,
                    "paper_ms": w.paper_taurus_ms,
                    "speedup_vs_paper_cpu": spd, "paper_speedup": paper_spd,
                    "cpu_model_s": cpu_model, "paper_cpu_s": w.paper_cpu_s,
                    "util": util})
    return out
