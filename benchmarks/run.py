"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table2,...]

Writes benchmarks/results.json and prints each table with paper
comparisons inline.  Serving rows additionally land in
benchmarks/BENCH_serve.json (requests/sec, fused-batch occupancy, dedup
hit-rate) so the serving perf trajectory is tracked machine-readably.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

ALL = ["fig5", "table2", "table4", "fig13", "fig15", "dedup", "engine",
       "radix", "serve", "fhe_ml"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--dry-run", action="store_true",
                    help="import every benchmark module and resolve its "
                         "run() entry point without executing (CI: keeps "
                         "the entry points from bit-rotting)")
    args = ap.parse_args(argv)
    which = args.only.split(",") if args.only else ALL

    from benchmarks import (fig5_addition, table2_workloads, table4_xpu,
                            fig13_bandwidth, fig15_utilization, dedup_stats,
                            engine_wallclock, fhe_ml_serve, radix_throughput,
                            serve_throughput)
    mods = {"fig5": fig5_addition, "table2": table2_workloads,
            "table4": table4_xpu, "fig13": fig13_bandwidth,
            "fig15": fig15_utilization, "dedup": dedup_stats,
            "engine": engine_wallclock, "radix": radix_throughput,
            "serve": serve_throughput, "fhe_ml": fhe_ml_serve}

    if args.dry_run:
        bad = [n for n in which if not callable(getattr(mods[n], "run", None))]
        print(f"[benchmarks] dry-run: {len(which)} modules importable, "
              f"{len(bad)} missing run() {bad}")
        return 1 if bad else 0

    results, failed = [], []
    for name in which:
        try:
            results.extend(mods[name].run())
        except Exception:
            traceback.print_exc()
            failed.append(name)
    path = os.path.join(os.path.dirname(__file__), "results.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=float)
    if any(r.get("bench") == "serve" for r in results):
        spath = serve_throughput.write_bench_json(results)
        print(f"[benchmarks] serving rows -> {spath}")
    print(f"\n[benchmarks] {len(results)} rows -> {path}; "
          f"{len(failed)} failed {failed}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
