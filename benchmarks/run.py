"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table2,...]

Writes benchmarks/results.json and prints each table with paper
comparisons inline.  Serving rows additionally land in
benchmarks/BENCH_serve.json (requests/sec, fused-batch occupancy, dedup
hit-rate, p50/p99 latency, BSK bytes saved) so the serving perf
trajectory is tracked machine-readably.

Exit code: non-zero when ANY selected benchmark module fails (partial
results are still written so the surviving rows aren't lost, but a
partial run must never look green to CI) — `tests/test_obs.py` pins
this contract.  `--dry-run` additionally checks that both serve
benchmarks declare the observability columns and that the Chrome-trace
exporter round-trips.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

ALL = ["fig5", "table2", "table4", "fig13", "fig15", "dedup", "engine",
       "kernels", "radix", "serve", "fhe_ml", "sim"]

# the observability columns every serve-bench row gained in the
# repro.obs PR; the dry run fails if a serve benchmark stops declaring
# them (BENCH_serve.json consumers key on these)
SERVE_OBS_COLUMNS = ("p50_s", "p99_s", "bsk_bytes_saved")
SERVE_BENCH_NAMES = ("serve", "fhe_ml")

# the columns every point of the serve benchmark's shard_scaling row
# must carry (the sharded-serving PR's dry-run contract; the nightly
# shard sweep's BENCH_serve.json consumers key on these)
SERVE_SCALING_COLUMNS = ("shards", "requests_per_s", "per_shard_occupancy",
                         "occupancy_ratio")

# the SLO columns every sim row must carry (BENCH_sim.json consumers
# key on these; the repro.sim PR's dry-run contract)
SIM_SLO_COLUMNS = ("p50_s", "p99_s", "queue_wait_p99_s", "abandon_rate",
                   "goodput_rps", "slo_ok", "virtual_deterministic")

# the columns every kernel row must carry (BENCH_kernels.json consumers
# key on these; the Pallas engine-room PR's dry-run contract)
KERNEL_COLUMNS = ("ref_ms", "pallas_ms", "speedup", "bytes_streamed",
                  "bytes_bound", "bytes_ok", "reuse_factor")


def _default_mods() -> dict:
    from benchmarks import (fig5_addition, table2_workloads, table4_xpu,
                            fig13_bandwidth, fig15_utilization, dedup_stats,
                            engine_wallclock, fhe_ml_serve, kernels_bench,
                            radix_throughput, serve_throughput, sim_slo)
    return {"fig5": fig5_addition, "table2": table2_workloads,
            "table4": table4_xpu, "fig13": fig13_bandwidth,
            "fig15": fig15_utilization, "dedup": dedup_stats,
            "engine": engine_wallclock, "kernels": kernels_bench,
            "radix": radix_throughput,
            "serve": serve_throughput, "fhe_ml": fhe_ml_serve,
            "sim": sim_slo}


def _dry_run_checks(mods: dict, which: list) -> list:
    """Entry-point + observability checks, no benchmark execution.
    Returns a list of problems (empty == pass)."""
    bad = [f"{n}: missing run()" for n in which
           if not callable(getattr(mods[n], "run", None))]
    for n in SERVE_BENCH_NAMES:
        if n not in which:
            continue
        cols = tuple(getattr(mods[n], "BENCH_COLUMNS", ()))
        missing = [c for c in SERVE_OBS_COLUMNS if c not in cols]
        if missing:
            bad.append(f"{n}: BENCH_COLUMNS missing {missing}")
    if "serve" in which:
        cols = tuple(getattr(mods["serve"], "SCALING_COLUMNS", ()))
        missing = [c for c in SERVE_SCALING_COLUMNS if c not in cols]
        if missing:
            bad.append(f"serve: SCALING_COLUMNS missing {missing}")
    if "sim" in which:
        cols = tuple(getattr(mods["sim"], "BENCH_COLUMNS", ()))
        missing = [c for c in SIM_SLO_COLUMNS if c not in cols]
        if missing:
            bad.append(f"sim: BENCH_COLUMNS missing {missing}")
    if "kernels" in which:
        cols = tuple(getattr(mods["kernels"], "BENCH_COLUMNS", ()))
        missing = [c for c in KERNEL_COLUMNS if c not in cols]
        if missing:
            bad.append(f"kernels: BENCH_COLUMNS missing {missing}")
        # the roofline model the kernel rows are gated by must build
        try:
            from repro.core.params import TEST_PARAMS
            from repro.launch.roofline import pbs_round_model
            model = pbs_round_model(TEST_PARAMS, 12)
            assert model.fused_bytes < model.unfused_bytes
        except Exception as err:  # noqa: BLE001 — any breakage fails the check
            bad.append(f"kernels roofline model: {err!r}")
    # the trace exporter the CI smoke lane relies on must round-trip
    try:
        from repro.obs import Telemetry, validate_chrome_trace
        tel = Telemetry(trace=True)
        with tel.span("dry_run_check", cat="bench"):
            pass
        validate_chrome_trace(json.dumps(tel.chrome_trace()))
    except Exception as err:  # noqa: BLE001 — any breakage fails the check
        bad.append(f"chrome-trace exporter: {err!r}")
    return bad


def main(argv=None, mods: dict | None = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--dry-run", action="store_true",
                    help="import every benchmark module, resolve its run() "
                         "entry point, and check the serve benchmarks' "
                         "observability columns + trace exporter without "
                         "executing (CI: keeps the entry points from "
                         "bit-rotting)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for results.json / BENCH_serve.json "
                         "(default: the benchmarks package directory)")
    args = ap.parse_args(argv)
    which = args.only.split(",") if args.only else ALL

    if mods is None:
        mods = _default_mods()
    unknown = [n for n in which if n not in mods]
    if unknown:
        print(f"[benchmarks] unknown benchmark(s) {unknown} "
              f"(have {sorted(mods)})")
        return 2

    if args.dry_run:
        bad = _dry_run_checks(mods, which)
        print(f"[benchmarks] dry-run: {len(which)} modules checked, "
              f"{len(bad)} problems {bad}")
        return 1 if bad else 0

    out_dir = args.out_dir or os.path.dirname(__file__)
    results, failed = [], []
    for name in which:
        try:
            results.extend(mods[name].run())
        except Exception:
            traceback.print_exc()
            failed.append(name)
    path = os.path.join(out_dir, "results.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=float)
    if any(r.get("bench") == "serve" for r in results):
        from benchmarks.serve_throughput import write_bench_json
        spath = write_bench_json(
            results, path=os.path.join(out_dir, "BENCH_serve.json"))
        print(f"[benchmarks] serving rows -> {spath}")
    if any(r.get("bench") == "sim" for r in results):
        from benchmarks.sim_slo import write_bench_json as write_sim_json
        spath = write_sim_json(
            results, path=os.path.join(out_dir, "BENCH_sim.json"))
        print(f"[benchmarks] sim SLO rows -> {spath}")
    if any(r.get("bench") == "kernels" for r in results):
        from benchmarks.kernels_bench import write_bench_json as write_k_json
        spath = write_k_json(
            results, path=os.path.join(out_dir, "BENCH_kernels.json"))
        print(f"[benchmarks] kernel rows -> {spath}")
    print(f"\n[benchmarks] {len(results)} rows -> {path}; "
          f"{len(failed)} failed {failed}")
    # a partial run keeps its rows but must exit non-zero: CI treats any
    # failed module as a red run, not a quieter green one
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
