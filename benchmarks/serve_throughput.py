"""Serving throughput: cross-request fused PBS rounds vs per-request
sequential execution.

Eight concurrent clients each submit an 8-bit encrypted radix-add
program (two of them are an identical retry pair — the online-dedup
case).  Baseline: the same programs executed sequentially, one request
at a time, through the same IR interpreter and engine.  Fused: the
`ServeRuntime` round scheduler, which barriers the 8 requests' carry
rounds into single `lut_batch` dispatches.

The structural win: one request's carry rounds cover only 4-8
ciphertexts, far below the engine's quantized batch floor
(`integer._pad_batch`), so a sequential server bootstraps 2-4x padding
per round and pays the per-dispatch fixed cost 8x — while the fused
rounds fill the batch with REAL work from the whole fleet, stream the
BSK once per round for everyone, and bootstrap duplicate rows (the
retry pair) exactly once.

Acceptance (ISSUE 2): fused >= 2x requests/sec, dedup hit-rate > 0,
recorded machine-readably in benchmarks/BENCH_serve.json.
"""
from __future__ import annotations

import json
import os
import time

N_CLIENTS = 8
BITS = 8


def write_bench_json(rows: list, path: str | None = None) -> str:
    """Write the serve rows to benchmarks/BENCH_serve.json."""
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump([r for r in rows if r.get("bench") == "serve"], f,
                  indent=1, default=float)
    return path


def run() -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.engine import TaurusEngine
    from repro.core.integer import IntegerContext
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext
    from repro.serve import (IrInterpreter, ServeRuntime,
                             decrypt_radix_output, encrypt_request_inputs,
                             radix_binop_program)

    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    ic = IntegerContext.create(ctx, engine)
    msg_bits = ic.spec(BITS).msg_bits
    g = radix_binop_program("radix_add", BITS, msg_bits)

    rng = np.random.default_rng(7)
    jobs = []
    for i in range(N_CLIENTS - 1):
        a, b = int(rng.integers(0, 1 << BITS)), int(rng.integers(0, 1 << BITS))
        enc = encrypt_request_inputs(ic, jax.random.key(100 + i), [a, b], BITS)
        jobs.append((f"client-{i}", enc, (a + b) % (1 << BITS)))
    # the last client is a retry of client-0: identical ciphertexts — the
    # cross-request dedup case (a replayed/retried query)
    jobs.append((f"client-{N_CLIENTS - 1}", jobs[0][1], jobs[0][2]))

    # warm the compiled pbs_batch shapes both paths will hit, so the
    # measurement is execution, not XLA compilation
    d = ic.spec(BITS).n_digits
    warm_ct = jnp.tile(jobs[0][1][0][:1], (1, 1))
    ident = np.arange(params.plaintext_modulus, dtype=np.uint64)
    for size in (16, 2 * d * N_CLIENTS // 2, 2 * d * N_CLIENTS):
        engine.lut_batch_tables(jnp.tile(warm_ct, (size, 1)),
                                np.tile(ident, (size, 1)))

    print("\n== Multi-tenant serving throughput "
          f"({N_CLIENTS} radix-add clients, {BITS}-bit, "
          f"{params.name}) ==")

    # Interleave the two modes and take per-mode medians: on shared CPU
    # the machine's effective speed drifts over minutes, and measuring
    # the modes back-to-back once would fold that drift into the ratio.
    reps = 3
    interp = IrInterpreter(ctx, engine)
    interp.run(g, jobs[0][1])                       # warm remaining shapes
    t_seqs, t_fuseds, sched = [], [], None
    for rep in range(reps):
        # -- baseline: sequential per-request execution ---------------------
        t0 = time.perf_counter()
        seq_out = [interp.run_outputs(g, enc)[0] for _, enc, _ in jobs]
        for out in seq_out:
            out.block_until_ready()
        t_seqs.append(time.perf_counter() - t0)

        # -- fused: cross-request round scheduler ---------------------------
        rt = ServeRuntime(ctx, engine, max_inflight=N_CLIENTS,
                          start_paused=True)
        handles = [rt.submit(g, enc, client_id=c) for c, enc, _ in jobs]
        t0 = time.perf_counter()
        rt.resume()
        rt.drain()
        t_fuseds.append(time.perf_counter() - t0)
        sched = rt.scheduler
        print(f"  pass {rep + 1}/{reps}: sequential {t_seqs[-1]:5.1f}s, "
              f"fused {t_fuseds[-1]:5.1f}s")
        for out, (_, _, want) in zip(seq_out, jobs):
            assert decrypt_radix_output(ic, out, BITS)[0] == want
        for h, (_, _, want) in zip(handles, jobs):
            assert decrypt_radix_output(ic, h.outputs()[0], BITS)[0] == want

    t_seq = float(np.median(t_seqs))
    t_fused = float(np.median(t_fuseds))
    rps_seq = len(jobs) / t_seq
    rps_fused = len(jobs) / t_fused
    row = {
        "bench": "serve", "clients": len(jobs), "bits": BITS,
        "params": params.name,
        "requests_per_s_sequential": rps_seq,
        "requests_per_s_fused": rps_fused,
        "speedup": rps_fused / rps_seq,
        "dedup_hit_rate": sched.dedup_hit_rate,
        "fused_occupancy": sched.mean_occupancy,
        "fused_rounds": sched.stats["fused_rounds"],
        "logical_luts": sched.stats["logical_luts"],
        "dispatched_luts": sched.stats["dispatched_luts"],
    }
    print(f"  sequential: {t_seq:6.1f}s  {rps_seq:5.2f} req/s")
    print(f"  fused:      {t_fused:6.1f}s  {rps_fused:5.2f} req/s  "
          f"({row['speedup']:.2f}x; target >= 2x)")
    print(f"  fused rounds {row['fused_rounds']}, occupancy "
          f"{row['fused_occupancy']:.0%}, dedup hit-rate "
          f"{row['dedup_hit_rate']:.1%}")
    return [row]


if __name__ == "__main__":
    rows = run()
    path = write_bench_json(rows)
    print(f"[serve] wrote {path}")
