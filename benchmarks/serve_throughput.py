"""Serving throughput: cross-request fused PBS rounds vs per-request
sequential execution, plus intra-request fusion of tensor-level radix
nodes — all through the `repro.api` Session front door.

Eight concurrent clients each submit an 8-bit encrypted radix-add
program traced by `Session.trace` (two of them are an identical retry
pair — the online-dedup case).  Baseline: the same programs executed
sequentially through a `LocalBackend` session sharing the engine.
Fused: a `ServeBackend` session over the `ServeRuntime` round
scheduler, which barriers the 8 requests' carry rounds into single
`lut_batch` dispatches.

The structural win: one request's carry rounds cover only 4-8
ciphertexts, far below the engine's quantized batch floor
(`integer._pad_batch`), so a sequential server bootstraps 2-4x padding
per round and pays the per-dispatch fixed cost 8x — while the fused
rounds fill the batch with REAL work from the whole fleet, stream the
BSK once per round for everyone, and bootstrap duplicate rows (the
retry pair) exactly once.

A second fused wave submits VECTOR programs (each request adds a
(2,)-tensor of integers): the interpreter flattens the tensor-level
radix node into per-vector round streams that fuse through the same
scheduler (ISSUE 3: intra-request fusion), so per-request round counts
halve while occupancy holds.

A third wave sweeps the SHARDED router (ISSUE 10): the same 16-client
radix-add fleet served by 1 / 2 / 4 `EngineShard` workers with a fixed
per-shard `max_inflight`, one reused runtime per shard count so the
sweep measures serving, not engine construction.  Per-shard fused-round
shapes match the single-shard baseline, so the per-shard occupancy
ratio isolates routing dilution from batch-size effects.

Acceptance (ISSUE 2): fused >= 2x requests/sec, dedup hit-rate > 0.
Acceptance (ISSUE 3): intra-request fused occupancy >= the
cross-request-only occupancy.
Acceptance (ISSUE 10): per-shard occupancy >= 90% of the single-shard
baseline at every sweep point, and requests/sec monotonic 1 -> 2 -> 4
when the host has enough devices to back the shards (on a one-device
host the shards time-slice a single core, so the sweep instead checks
the router's overhead stays bounded and records the curve).  All
recorded machine-readably in benchmarks/BENCH_serve.json.

CI smoke lane: `python -m benchmarks.serve_throughput --smoke` runs one
2-shard decrypt-validated wave (no timing claims, no JSON write).
"""
from __future__ import annotations

import json
import os
import time

N_CLIENTS = 8
BITS = 8

# observability columns every serve-bench row must carry (checked by
# benchmarks/run.py --dry-run): tail latency from the runtime's
# serve.request_latency_s histogram, queue pressure, and the bandwidth
# ledger's key-reuse saving (BSK bytes the fused rounds did NOT stream
# vs. a per-request server)
OBS_COLUMNS = ("p50_s", "p99_s", "queue_wait_p99_s", "queue_depth_max",
               "bsk_bytes_saved", "bsk_bytes_streamed")
BENCH_COLUMNS = OBS_COLUMNS

# columns every point in the shard_scaling row's "scaling" list carries
# (checked by benchmarks/run.py --dry-run; BENCH_serve.json consumers
# key on these)
SCALING_COLUMNS = ("shards", "clients", "requests_per_s",
                   "per_shard_occupancy", "occupancy_ratio")
SHARD_SWEEP = (1, 2, 4)
N_SCALE_CLIENTS = 16     # fixed fleet: strong scaling across the sweep
SHARD_INFLIGHT = 4       # per-shard admission ceiling, constant per point


def obs_columns(runtime) -> dict:
    """The shared observability columns off one runtime's telemetry
    snapshot (used by this module and `fhe_ml_serve`)."""
    snap = runtime.metrics()
    lat = snap["histograms"]["serve.request_latency_s"]
    wait = snap["histograms"]["serve.queue_wait_s"]
    depth = snap["histograms"]["serve.queue_depth"]
    bw = snap["bandwidth"]
    return {
        "p50_s": lat["p50"], "p99_s": lat["p99"],
        "queue_wait_p99_s": wait["p99"],
        "queue_depth_max": depth["max"],
        "bsk_bytes_saved": bw["bsk_bytes_saved"],
        "bsk_bytes_streamed": bw["bsk_bytes_streamed"],
    }


def write_bench_json(rows: list, path: str | None = None) -> str:
    """Merge serve rows into benchmarks/BENCH_serve.json by workload.

    The file tracks the serving perf trajectory across PRs for SEVERAL
    workloads (this module's radix-add fleet, fhe_ml_serve's encrypted
    transformers); re-running one benchmark must not clobber the
    others' rows, so only rows whose "workload" is re-measured here are
    replaced."""
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")
    rows = [r for r in rows if r.get("bench") == "serve"]
    existing = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = []
    fresh = {r.get("workload") for r in rows}
    keep = [r for r in existing if r.get("workload") not in fresh]
    with open(path, "w") as f:
        json.dump(keep + rows, f, indent=1, default=float)
    return path


def run() -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.api import IntSpec, Session
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext

    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    local = Session(ctx, engine, backend="local")
    g = local.trace(lambda a, b: a + b, IntSpec(BITS), IntSpec(BITS))

    rng = np.random.default_rng(7)
    jobs = []
    for i in range(N_CLIENTS - 1):
        a, b = int(rng.integers(0, 1 << BITS)), int(rng.integers(0, 1 << BITS))
        enc = local.encrypt_inputs(jax.random.key(100 + i), [a, b], g)
        jobs.append((f"client-{i}", enc, (a + b) % (1 << BITS)))
    # the last client is a retry of client-0: identical ciphertexts — the
    # cross-request dedup case (a replayed/retried query)
    jobs.append((f"client-{N_CLIENTS - 1}", jobs[0][1], jobs[0][2]))

    # warm the compiled pbs_batch shapes both paths will hit, so the
    # measurement is execution, not XLA compilation
    d = local.int_ctx.spec(BITS).n_digits
    warm_ct = jnp.tile(jobs[0][1][0][:1], (1, 1))
    ident = np.arange(params.plaintext_modulus, dtype=np.uint64)
    # the last size is the intra wave's fused round: 2 vectors/request
    for size in (16, 2 * d * N_CLIENTS // 2, 2 * d * N_CLIENTS,
                 2 * d * N_CLIENTS * 2):
        engine.lut_batch_tables(jnp.tile(warm_ct, (size, 1)),
                                np.tile(ident, (size, 1)))
        # the scheduler's KS-level dedup splits rounds into
        # keyswitch + lut_batch_small — warm those shapes too
        from repro.core import glwe
        small = engine.keyswitch(jnp.tile(warm_ct, (size, 1)))
        engine.lut_batch_small(small, glwe.make_lut_polys_cached(
            np.tile(ident, (size, 1)), params))

    print("\n== Multi-tenant serving throughput "
          f"({N_CLIENTS} radix-add clients, {BITS}-bit, "
          f"{params.name}) ==")

    def fused_wave(prog, wave_jobs, *, label):
        sess = Session(ctx, engine, backend="serve",
                       max_inflight=len(wave_jobs), start_paused=True)
        handles = [sess.submit(prog, enc, client_id=c)
                   for c, enc, _ in wave_jobs]
        rt = sess.backend.runtime
        t0 = time.perf_counter()
        rt.resume()
        rt.drain()
        dt = time.perf_counter() - t0
        for h, (_, _, want) in zip(handles, wave_jobs):
            assert sess.decrypt_outputs(prog, h.outputs())[0] == want, label
        return dt, rt

    # Interleave the two modes and take per-mode medians: on shared CPU
    # the machine's effective speed drifts over minutes, and measuring
    # the modes back-to-back once would fold that drift into the ratio.
    reps = 3
    local.run(g, jobs[0][1])                        # warm remaining shapes
    t_seqs, t_fuseds, rt_fused = [], [], None
    for rep in range(reps):
        # -- baseline: sequential per-request execution ---------------------
        t0 = time.perf_counter()
        seq_out = [local.run(g, enc)[0] for _, enc, _ in jobs]
        for out in seq_out:
            out.block_until_ready()
        t_seqs.append(time.perf_counter() - t0)

        # -- fused: cross-request round scheduler ---------------------------
        t_f, rt_fused = fused_wave(g, jobs, label="fused")
        t_fuseds.append(t_f)
        print(f"  pass {rep + 1}/{reps}: sequential {t_seqs[-1]:5.1f}s, "
              f"fused {t_fuseds[-1]:5.1f}s")
        for out, (_, _, want) in zip(seq_out, jobs):
            assert local.decrypt_outputs(g, [out])[0] == want

    # -- intra-request fusion: each client submits ONE (2,)-vector add ------
    g2 = local.trace(lambda a, b: a + b,
                     IntSpec(BITS, shape=(2,)), IntSpec(BITS, shape=(2,)))
    jobs2 = []
    for i in range(N_CLIENTS):
        xs = [int(v) for v in rng.integers(0, 1 << BITS, 2)]
        ys = [int(v) for v in rng.integers(0, 1 << BITS, 2)]
        enc = local.encrypt_inputs(jax.random.key(500 + i), [xs, ys], g2)
        jobs2.append((f"client-{i}", enc,
                      np.array([(x + y) % (1 << BITS)
                                for x, y in zip(xs, ys)])))

    def intra_wave():
        sess = Session(ctx, engine, backend="serve",
                       max_inflight=N_CLIENTS, start_paused=True)
        handles = [sess.submit(g2, enc, client_id=c)
                   for c, enc, _ in jobs2]
        rt = sess.backend.runtime
        t0 = time.perf_counter()
        rt.resume()
        rt.drain()
        dt = time.perf_counter() - t0
        for h, (_, _, want) in zip(handles, jobs2):
            got = sess.decrypt_outputs(g2, h.outputs())[0]
            assert np.array_equal(got, want)
        return dt, rt

    # first pass warms any remaining shapes and is discarded; the median
    # of the measured passes matches the cross-request methodology
    intra_wave()
    intra_runs = [intra_wave() for _ in range(2)]
    t_intra = float(np.median([t for t, _ in intra_runs]))
    sched_intra = intra_runs[-1][1].scheduler

    t_seq = float(np.median(t_seqs))
    t_fused = float(np.median(t_fuseds))
    rps_seq = len(jobs) / t_seq
    rps_fused = len(jobs) / t_fused
    sched = rt_fused.scheduler
    occ_cross = sched.mean_occupancy
    occ_intra = sched_intra.mean_occupancy
    # ISSUE 3 acceptance: flattening one request's tensor-level radix
    # node into per-vector rounds must not dilute the fused batches
    assert occ_intra >= occ_cross - 1e-6, (occ_intra, occ_cross)
    row = {
        "bench": "serve", "workload": "radix_add_clients",
        "clients": len(jobs), "bits": BITS,
        "params": params.name,
        "requests_per_s_sequential": rps_seq,
        "requests_per_s_fused": rps_fused,
        "speedup": rps_fused / rps_seq,
        "dedup_hit_rate": sched.dedup_hit_rate,
        "ks_dedup_hits": sched.stats["ks_dedup_hits"],
        "fused_occupancy": occ_cross,
        "fused_rounds": sched.stats["fused_rounds"],
        "logical_luts": sched.stats["logical_luts"],
        "dispatched_luts": sched.stats["dispatched_luts"],
        "intra_vectors_per_request": 2,
        "intra_requests_per_s": len(jobs2) / t_intra,
        "intra_fused_occupancy": occ_intra,
        "intra_fused_rounds": sched_intra.stats["fused_rounds"],
        "intra_logical_luts": sched_intra.stats["logical_luts"],
    }
    # tail latency / queue / bandwidth columns from the LAST fused wave's
    # telemetry (each wave owns a fresh runtime, so the snapshot is one
    # wave's traffic, not an accumulation across reps)
    row.update(obs_columns(rt_fused))
    print(f"  sequential: {t_seq:6.1f}s  {rps_seq:5.2f} req/s")
    print(f"  fused:      {t_fused:6.1f}s  {rps_fused:5.2f} req/s  "
          f"({row['speedup']:.2f}x; target >= 2x)")
    print(f"  fused rounds {row['fused_rounds']}, occupancy "
          f"{occ_cross:.0%}, dedup hit-rate "
          f"{row['dedup_hit_rate']:.1%}")
    print(f"  intra-request (2-vector adds): {t_intra:5.1f}s "
          f"{row['intra_requests_per_s']:5.2f} req/s, "
          f"{row['intra_fused_rounds']} fused rounds, occupancy "
          f"{occ_intra:.0%} (>= cross-request {occ_cross:.0%})")
    print(f"  latency p50 {row['p50_s']:.2f}s p99 {row['p99_s']:.2f}s, "
          f"queue depth max {row['queue_depth_max']:.0f}, "
          f"BSK saved {row['bsk_bytes_saved'] / 1e6:.1f} MB "
          f"(streamed {row['bsk_bytes_streamed'] / 1e6:.1f} MB)")

    scaling_row = shard_sweep(ctx, engine, local, g)
    return [row, scaling_row]


def shard_sweep(ctx, engine, local, g, *, sweep=SHARD_SWEEP, reps=3) -> dict:
    """The ISSUE 10 scaling benchmark: one fixed fleet of
    `N_SCALE_CLIENTS` radix-add clients served by 1 / 2 / 4 shards with
    a constant per-shard `max_inflight` (strong scaling — concurrency
    grows with the shard count, per-shard fused-round shapes don't).

    One runtime per shard count is built up front and reused across
    reps (pause -> submit wave -> resume -> drain), so the measurement
    is serving, not per-wave engine/key construction; reps interleave
    the sweep points so machine drift hits all of them equally.  Every
    wave is decrypt-validated.

    The monotonic-rps acceptance only arms when the host has at least
    as many devices as the widest point: on a one-device host all
    shards time-slice one core (`launch.mesh.shard_devices`
    round-robins), total PBS compute is serialized, and the honest
    expectation is a flat curve whose router overhead stays bounded —
    asserted as rps within 25% of the single-shard baseline.  The
    per-shard occupancy ratio >= 0.9 acceptance always applies."""
    import jax
    import numpy as np
    from repro.api import Session

    rng = np.random.default_rng(11)
    jobs = []
    for i in range(N_SCALE_CLIENTS):
        a, b = int(rng.integers(0, 1 << BITS)), int(rng.integers(0, 1 << BITS))
        enc = local.encrypt_inputs(jax.random.key(900 + i), [a, b], g)
        jobs.append((f"client-{i}", enc, (a + b) % (1 << BITS)))

    n_devices = len(jax.devices())
    print(f"\n== Shard scaling sweep ({N_SCALE_CLIENTS} clients, "
          f"per-shard max_inflight={SHARD_INFLIGHT}, "
          f"{n_devices} device(s)) ==")
    sessions = {
        s: Session(ctx, engine, backend="serve", shards=s,
                   max_inflight=SHARD_INFLIGHT, start_paused=True)
        for s in sweep
    }

    def wave(n_shards):
        sess = sessions[n_shards]
        rt = sess.backend.runtime
        rt.pause()
        handles = [sess.submit(g, enc, client_id=c) for c, enc, _ in jobs]
        t0 = time.perf_counter()
        rt.resume()
        rt.drain()
        dt = time.perf_counter() - t0
        for h, (_, _, want) in zip(handles, jobs):
            got = sess.decrypt_outputs(g, h.outputs())[0]
            assert got == want, f"shards={n_shards}: {got} != {want}"
        return dt

    for s in sweep:                                 # warm pass, discarded
        wave(s)
    times = {s: [] for s in sweep}
    for _ in range(reps):
        for s in sweep:
            times[s].append(wave(s))

    points, base_occ = [], None
    for s in sweep:
        rt = sessions[s].backend.runtime
        occ = float(np.mean([sh.scheduler.mean_occupancy
                             for sh in rt.shards]))
        if base_occ is None:
            base_occ = occ
        dt = float(np.median(times[s]))
        point = {
            "shards": s, "clients": N_SCALE_CLIENTS,
            "requests_per_s": N_SCALE_CLIENTS / dt,
            "per_shard_occupancy": occ,
            "occupancy_ratio": occ / base_occ,
        }
        points.append(point)
        print(f"  shards={s}: {dt:5.1f}s  "
              f"{point['requests_per_s']:5.2f} req/s, per-shard occupancy "
              f"{occ:.0%} (ratio {point['occupancy_ratio']:.2f})")
        sessions[s].close()

    rps = [p["requests_per_s"] for p in points]
    monotonic = all(b >= a for a, b in zip(rps, rps[1:]))
    min_ratio = min(p["occupancy_ratio"] for p in points)
    expect_monotonic = n_devices >= max(sweep)
    assert min_ratio >= 0.9, f"per-shard occupancy ratio {min_ratio} < 0.9"
    if expect_monotonic:
        assert monotonic, f"rps not monotonic across shards: {rps}"
    else:
        # one device: shards time-slice it, so require bounded overhead
        assert min(rps) >= 0.75 * rps[0], \
            f"sharding overhead exceeds 25% on one device: {rps}"
        print(f"  ({n_devices} device(s) < {max(sweep)} shards: "
              f"monotonic-rps acceptance not armed, overhead bounded)")
    return {
        "bench": "serve", "workload": "shard_scaling",
        "bits": BITS, "params": ctx.params.name,
        "clients": N_SCALE_CLIENTS,
        "max_inflight_per_shard": SHARD_INFLIGHT,
        "devices": n_devices,
        "scaling": points,
        "monotonic_rps": monotonic,
        "monotonic_rps_armed": expect_monotonic,
        "min_occupancy_ratio": min_ratio,
    }


def smoke() -> None:
    """CI smoke lane: one 2-shard decrypt-validated wave through the
    full Session -> router -> EngineShard -> fused-scheduler stack.
    No timing claims, no JSON write — just proof the sharded serving
    path works end to end on this checkout."""
    import jax
    import numpy as np
    from repro.api import IntSpec, Session
    from repro.core.engine import TaurusEngine
    from repro.core.params import TEST_PARAMS_4BIT
    from repro.core.pbs import TFHEContext

    params = TEST_PARAMS_4BIT
    ctx = TFHEContext.create(jax.random.PRNGKey(0), params)
    engine = TaurusEngine.from_context(ctx)
    local = Session(ctx, engine, backend="local")
    g = local.trace(lambda a, b: a + b, IntSpec(BITS), IntSpec(BITS))

    rng = np.random.default_rng(3)
    jobs = []
    for i in range(4):
        a, b = int(rng.integers(0, 1 << BITS)), int(rng.integers(0, 1 << BITS))
        enc = local.encrypt_inputs(jax.random.key(700 + i), [a, b], g)
        jobs.append((f"client-{i}", enc, (a + b) % (1 << BITS)))

    sess = Session(ctx, engine, backend="serve", shards=2, max_inflight=2,
                   start_paused=True)
    handles = [sess.submit(g, enc, client_id=c) for c, enc, _ in jobs]
    rt = sess.backend.runtime
    rt.resume()
    rt.drain()
    for h, (_, _, want) in zip(handles, jobs):
        got = sess.decrypt_outputs(g, h.outputs())[0]
        assert got == want, (got, want)
    counters = rt.metrics()["counters"]
    admitted = [int(counters.get(f"serve.shard.{i}.admitted", 0))
                for i in range(2)]
    assert sum(admitted) == len(jobs) and all(admitted), admitted
    sess.close()
    print(f"[serve --smoke] 2-shard wave OK: {len(jobs)} requests "
          f"decrypt-identical, per-shard admitted={admitted}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="serving throughput + shard scaling benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="one quick 2-shard decrypt-validated wave "
                         "(CI smoke lane; no JSON write)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        rows = run()
        path = write_bench_json(rows)
        print(f"[serve] wrote {path}")
